"""Benchmarks: one per paper table/figure + framework integrations."""
