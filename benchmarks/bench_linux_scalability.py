"""Linux Scalability benchmark (paper Fig. 8; Lever & Boreham [22]).

Each of W concurrent actors performs OPS/W fixed-size alloc-then-free
iterations.  Lock-equivalent allocators serialize everything; the
non-blocking wavefront commits W-wide batches per round.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    WIDTHS,
    WavefrontAllocator,
    level_for,
    make_host_allocators,
    row,
)

TOTAL_MEM = 1 << 19     # bytes managed
MIN_SIZE = 8
ALLOC_SIZE = 64         # fixed request size
OPS = 20_000            # scaled 1000x down from the paper's 20M


def run() -> None:
    units_total = TOTAL_MEM // MIN_SIZE

    # --- host allocators (sequential = lock-equivalent cost model) -----
    for name, alloc in make_host_allocators(TOTAL_MEM, MIN_SIZE).items():
        t0 = time.perf_counter()
        for _ in range(OPS // 2):
            a = alloc.nb_alloc(ALLOC_SIZE)
            alloc.nb_free(a)
        dt = time.perf_counter() - t0
        row("linux_scalability", name, 1, OPS, dt)

    # --- wavefront: width-W batches of alloc then free ------------------
    level = level_for(units_total, ALLOC_SIZE // MIN_SIZE)
    for w in WIDTHS:
        wa = WavefrontAllocator(units_total, w)
        levels = np.full(w, level, np.int32)
        # narrow widths: cap op count (jit-dispatch-bound on CPU; the
        # scaling trend is the measurement, not the absolute count)
        ops_w = OPS if w >= 8 else min(OPS, 4_000)
        n_batches = ops_w // (2 * w)
        # warmup/compile
        nodes = wa.alloc_batch(levels)
        wa.free_batch_(nodes)
        wa.block()
        t0 = time.perf_counter()
        for _ in range(n_batches):
            nodes = wa.alloc_batch(levels)
            wa.free_batch_(nodes)
        wa.block()
        dt = time.perf_counter() - t0
        row("linux_scalability", "nb-wavefront", w, n_batches * 2 * w, dt)
        del wa


if __name__ == "__main__":
    run()
