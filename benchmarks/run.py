"""Benchmark runner: one benchmark per paper table/figure + the
framework-level integrations.  Prints CSV:
name,allocator,width,ops,seconds,ops_per_sec,extra

  PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""

import argparse
import sys
import traceback

from benchmarks import (
    bench_backend_comparison,
    bench_bunch_rmw,
    bench_constant_occupancy,
    bench_larson,
    bench_linux_scalability,
    bench_paged_serving,
    bench_roofline,
    bench_thread_test,
    bench_wavefront,
)

ALL = {
    "linux_scalability": bench_linux_scalability.run,   # paper Fig. 8
    "thread_test": bench_thread_test.run,               # paper Fig. 9
    "larson": bench_larson.run,                         # paper Fig. 10
    "constant_occupancy": bench_constant_occupancy.run, # paper Fig. 11
    "backend_comparison": bench_backend_comparison.run, # paper Fig. 12
    "bunch_rmw": bench_bunch_rmw.run,                   # paper §III-D
    "wavefront": bench_wavefront.run,                   # device substrate
    "paged_serving": bench_paged_serving.run,           # NBBS integration
    "roofline": bench_roofline.run,                     # §Roofline tables
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,allocator,width,ops,seconds,ops_per_sec,extra")
    failures = 0
    for name, fn in ALL.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---")
        try:
            fn()
        except Exception as e:
            failures += 1
            print(f"# FAILED {name}: {e}")
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
