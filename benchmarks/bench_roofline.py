"""Roofline table generator: aggregates the dry-run cell JSONs into the
docs/experiments.md §Roofline table (single-pod mesh per the spec; the
multi-pod pass proves the 'pod' axis shards)."""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_cells(mesh="single"):
    cells = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(fn) as f:
            cells.append(json.load(f))
    return cells


def fmt_row(c) -> str:
    if c.get("status") == "skipped":
        return (f"{c['arch']},{c['shape']},{c['mesh']},skipped,,,,,,,"
                f"\"{c['reason'][:60]}\"")
    if c.get("status") != "ok":
        return f"{c['arch']},{c['shape']},{c['mesh']},FAIL,,,,,,,"
    r = c["roofline"]
    w = c["hlo_walk_per_device"]
    return (
        f"{c['arch']},{c['shape']},{c['mesh']},ok,"
        f"{r['compute_s']:.4e},{r['memory_s']:.4e},{r['collective_s']:.4e},"
        f"{r['dominant']},{c['model_flops_global']:.3e},"
        f"{(c['useful_flops_ratio'] or 0):.3f},"
        f"coll_ag={w['per_collective'].get('all-gather', 0):.2e}"
    )


def run() -> None:
    print("arch,shape,mesh,status,compute_s,memory_s,collective_s,"
          "dominant,model_flops,useful_ratio,extra")
    for mesh in ("single", "multi"):
        for c in load_cells(mesh):
            print(fmt_row(c))


if __name__ == "__main__":
    run()
