"""Constant Occupancy benchmark (paper Fig. 11 — the paper's own test).

Each actor pre-allocates a pool of chunks with a size distribution
skewed towards small chunks (more allocations at smaller sizes), then
performs OPS random deallocate-reallocate pairs at the *same* size —
keeping the occupancy factor of the buddy system constant while
exercising splits/merges at many levels.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    WIDTHS,
    WavefrontAllocator,
    level_for,
    make_host_allocators,
    row,
)

TOTAL_MEM = 1 << 19
MIN_SIZE = 8
# skewed pool: many small, few large (paper: min sizes 8..1024, max 16x)
POOL_SPEC = [(8, 128), (16, 64), (32, 32), (64, 16), (128, 8), (1024, 4)]
OPS = 20_000


def run() -> None:
    units_total = TOTAL_MEM // MIN_SIZE
    rng = np.random.default_rng(1)

    for name, alloc in make_host_allocators(TOTAL_MEM, MIN_SIZE).items():
        pool = []
        for size, count in POOL_SPEC:
            for _ in range(count):
                a = alloc.nb_alloc(size)
                if a is not None:
                    pool.append((a, size))
        t0 = time.perf_counter()
        for _ in range(OPS // 2):
            i = int(rng.integers(len(pool)))
            addr, size = pool[i]
            alloc.nb_free(addr)
            pool[i] = (alloc.nb_alloc(size), size)
        dt = time.perf_counter() - t0
        row("constant_occupancy", name, 1, OPS, dt)

    for w in WIDTHS:
        wa = WavefrontAllocator(units_total, w)
        pool = []
        for size, count in POOL_SPEC:
            for _ in range(max(count // w, 1)):
                lv = np.full(w, level_for(units_total, size // MIN_SIZE),
                             np.int32)
                pool.append((wa.alloc_batch(lv), lv))
        wa.block()
        t0 = time.perf_counter()
        for _ in range(OPS // (2 * w)):
            i = int(rng.integers(len(pool)))
            nodes, lv = pool[i]
            wa.free_batch_(nodes)
            pool[i] = (wa.alloc_batch(lv), lv)
        wa.block()
        dt = time.perf_counter() - t0
        merged, logical = wa.free_stats
        row(
            "constant_occupancy", "nb-wavefront", w, OPS, dt,
            extra=f"free_merged={merged};free_logical={logical}",
        )


if __name__ == "__main__":
    run()
