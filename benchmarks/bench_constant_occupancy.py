"""Constant Occupancy benchmark (paper Fig. 11 — the paper's own test).

Each actor pre-allocates a pool of chunks with a size distribution
skewed towards small chunks (more allocations at smaller sizes), then
performs OPS random deallocate-reallocate pairs at the *same* size —
keeping the occupancy factor of the buddy system constant while
exercising splits/merges at many levels.

The fastpath sweep runs the same constant-occupancy churn at the fast
octave with the bitmap-slab front end (core/fastpath.py) on and off:
with the slab serving the churn, merged tree writes per op drop
strictly below the buddy-climb baseline and logical RMWs approach the
O(1) claim's 1/op.  Full runs write BENCH_FASTPATH.json; `BENCH_FAST=1`
shrinks everything for the CI smoke job and skips the JSON writes.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    WIDTHS,
    WavefrontAllocator,
    bench_envelope,
    bench_record,
    dump_bench_json,
    level_for,
    make_host_allocators,
    row,
)
from repro.core.concurrent import TreeConfig
from repro.core.fastpath import FastPathConfig
from repro.core.pool import PoolConfig, pool_wavefront_step

FAST = os.environ.get("BENCH_FAST") == "1"

TOTAL_MEM = (1 << 15) if FAST else (1 << 19)
MIN_SIZE = 8
# skewed pool: many small, few large (paper: min sizes 8..1024, max 16x)
POOL_SPEC = [(8, 128), (16, 64), (32, 32), (64, 16), (128, 8), (1024, 4)]
OPS = 2_000 if FAST else 20_000


def run() -> None:
    units_total = TOTAL_MEM // MIN_SIZE
    rng = np.random.default_rng(1)

    for name, alloc in make_host_allocators(TOTAL_MEM, MIN_SIZE).items():
        pool = []
        for size, count in POOL_SPEC:
            for _ in range(count):
                a = alloc.nb_alloc(size)
                if a is not None:
                    pool.append((a, size))
        t0 = time.perf_counter()
        for _ in range(OPS // 2):
            i = int(rng.integers(len(pool)))
            addr, size = pool[i]
            alloc.nb_free(addr)
            pool[i] = (alloc.nb_alloc(size), size)
        dt = time.perf_counter() - t0
        row("constant_occupancy", name, 1, OPS, dt)

    for w in WIDTHS:
        wa = WavefrontAllocator(units_total, w)
        pool = []
        for size, count in POOL_SPEC:
            for _ in range(max(count // w, 1)):
                lv = np.full(w, level_for(units_total, size // MIN_SIZE),
                             np.int32)
                pool.append((wa.alloc_batch(lv), lv))
        wa.block()
        t0 = time.perf_counter()
        for _ in range(OPS // (2 * w)):
            i = int(rng.integers(len(pool)))
            nodes, lv = pool[i]
            wa.free_batch_(nodes)
            pool[i] = (wa.alloc_batch(lv), lv)
        wa.block()
        dt = time.perf_counter() - t0
        merged, logical = wa.free_stats
        row(
            "constant_occupancy", "nb-wavefront", w, OPS, dt,
            extra=f"free_merged={merged};free_logical={logical}",
        )

    # ---- sharded-pool sweep: constant-occupancy churn vs shard count ----
    # The paper's own workload on the pool: a skewed long-lived pool of
    # bursts, then dealloc/reallocate-at-the-same-size steps through
    # pool_wavefront_step (frees and allocs in one mixed pool round) at
    # equal total capacity for every S.  Reports rounds per churn step
    # and the per-shard merged-vs-logical release ratio (Fig. 7 metric,
    # release side, extended to the pool).
    TOTAL_DEPTH = 10 if FAST else 12  # units constant across S
    W = 64                      # churn burst width
    CHURN_STEPS = 3 if FAST else 12
    shard_records = []
    for S in (1, 2, 4, 8):
        sd = TOTAL_DEPTH - (S.bit_length() - 1)
        pcfg = PoolConfig(TreeConfig(depth=sd), S)
        srng = np.random.default_rng(5)
        sizes = 2 ** srng.integers(0, 9, size=W)   # mixed octaves ~72%
        levels = jnp.asarray(sd - np.log2(sizes).astype(int), jnp.int32)
        active = jnp.ones(W, bool)
        trees = pcfg.empty_trees()
        # pre-allocate the long-lived pool (no frees on the first step)
        trees, nodes, shard, ok, _ = pool_wavefront_step(
            pcfg, trees, jnp.zeros(W, jnp.int32), jnp.zeros(W, jnp.int32),
            jnp.zeros(W, bool), levels, active,
        )
        jax.block_until_ready(trees)
        rounds_total = merged_total = logical_total = 0
        t0 = time.perf_counter()
        for _ in range(CHURN_STEPS):
            # constant occupancy: free the burst and re-allocate the
            # same levels in the same mixed pool step
            trees, nodes, shard, ok, stats = pool_wavefront_step(
                pcfg, trees, nodes, shard, ok, levels, active,
            )
            rounds_total += int(stats["rounds"])
            merged_total += int(stats["free_merged_writes"])
            logical_total += int(stats["free_logical_rmws"])
        jax.block_until_ready(trees)
        dt = time.perf_counter() - t0
        free_ratio = merged_total / max(logical_total, 1)
        rec = bench_record(
            dims={"n_shards": S, "shard_depth": sd, "width": W,
                  "churn_steps": CHURN_STEPS},
            metrics={
                "rounds_total": rounds_total,
                "ok_final": int(ok.sum()),
                "free_merged_writes": merged_total,
                "free_logical_rmws": logical_total,
                "free_ratio": free_ratio,
                "seconds": dt,
            },
        )
        shard_records.append(rec)
        row(
            "constant_occupancy_shard_sweep", f"pool-s{S}", W,
            2 * CHURN_STEPS * W, dt,
            extra=(
                f"rounds_total={rounds_total};"
                f"free_merged={merged_total};free_logical={logical_total};"
                f"ratio={free_ratio:.3f}"
            ),
        )
        assert merged_total < logical_total, (
            "merged pool release must beat per-free RMWs",
            merged_total, logical_total,
        )
    if not FAST:
        dump_bench_json(
            "BENCH_CONSTANT_OCCUPANCY_SHARDS.json",
            bench_envelope(
                "bench_constant_occupancy/shard_sweep",
                {"total_depth": TOTAL_DEPTH, "width": W,
                 "churn_steps": CHURN_STEPS},
                shard_records,
            ),
        )

    fastpath_sweep()
    magazine_sweep()


def fastpath_sweep() -> None:
    """Fast-octave constant-occupancy churn, slab front end on vs off.

    W leaf pages are freed and re-allocated each mixed pool step.  With
    the fastpath on, steady-state churn is slab claims/releases — one
    logical RMW per alloc and a couple of merged bitmap-word writes per
    burst — instead of O(depth) buddy climbs.  The JSON records both
    modes so the climb baseline is always alongside."""
    DEPTH = 6 if FAST else 8
    CHURN = 3 if FAST else 16
    records = []
    for S in (1, 4):
        per_mode = {}
        for use_fp in (False, True):
            fp = FastPathConfig(level=None, slab_level=2) if use_fp else None
            pcfg = PoolConfig(TreeConfig(depth=DEPTH), S, fastpath=fp)
            W = (S << DEPTH) // 8  # churn width: fits every shard's slab
            levels = jnp.full(W, DEPTH, jnp.int32)
            active = jnp.ones(W, bool)
            zeros = jnp.zeros(W, jnp.int32)
            trees = pcfg.empty_trees()
            trees, nodes, shard, ok, _ = pool_wavefront_step(
                pcfg, trees, zeros, zeros, jnp.zeros(W, bool), levels,
                active,
            )
            assert bool(ok.all())
            jax.block_until_ready(trees)
            tot = {"merged": 0, "logical": 0, "free_merged": 0,
                   "free_logical": 0, "hits": 0, "spills": 0}
            t0 = time.perf_counter()
            for _ in range(CHURN):
                trees, nodes, shard, ok, stats = pool_wavefront_step(
                    pcfg, trees, nodes, shard, ok, levels, active,
                )
                tot["merged"] += int(stats["merged_writes"])
                tot["logical"] += int(stats["logical_rmws"])
                tot["free_merged"] += int(stats["free_merged_writes"])
                tot["free_logical"] += int(stats["free_logical_rmws"])
                tot["hits"] += int(stats["fastpath_hits"])
                tot["spills"] += int(stats["fastpath_spills"])
            jax.block_until_ready(trees)
            dt = time.perf_counter() - t0
            assert bool(ok.all())
            ops = CHURN * W  # alloc ops (each paired with one free)
            rec = bench_record(
                dims={"n_shards": S, "fastpath": use_fp, "depth": DEPTH,
                      "width": W, "churn_steps": CHURN},
                metrics={
                    "merged_writes": tot["merged"],
                    "logical_rmws": tot["logical"],
                    "free_merged_writes": tot["free_merged"],
                    "free_logical_rmws": tot["free_logical"],
                    "fastpath_hits": tot["hits"],
                    "fastpath_spills": tot["spills"],
                    "merged_per_op": (
                        (tot["merged"] + tot["free_merged"]) / ops
                    ),
                    "logical_per_alloc": tot["logical"] / ops,
                    "seconds": dt,
                },
            )
            per_mode[use_fp] = rec["metrics"]
            records.append(rec)
            row(
                "constant_occupancy_fastpath",
                f"pool-s{S}-{'slab' if use_fp else 'climb'}", W, 2 * ops,
                dt,
                extra=(
                    f"merged/op={rec['metrics']['merged_per_op']:.3f};"
                    f"logical/alloc="
                    f"{rec['metrics']['logical_per_alloc']:.3f};"
                    f"hits={tot['hits']};spills={tot['spills']}"
                ),
            )
        # the tentpole claim: slab churn merges strictly fewer writes
        # per op than the buddy-climb baseline, at ~1 logical RMW/alloc
        assert (
            per_mode[True]["merged_per_op"]
            < per_mode[False]["merged_per_op"]
        ), per_mode
        assert per_mode[True]["fastpath_hits"] > 0
    if not FAST:
        dump_bench_json(
            "BENCH_FASTPATH.json",
            bench_envelope(
                "bench_constant_occupancy/fastpath_sweep",
                {"depth": DEPTH, "churn_steps": CHURN},
                records,
            ),
        )


def magazine_sweep() -> None:
    """Leaf-octave constant-occupancy churn vs magazine capacity.

    W lanes free and re-allocate one leaf page each per mixed pool
    step, four lanes sharing each magazine — so mag_cap=2 absorbs only
    half of every burst while mag_cap>=4 recycles all of it.  The
    sweep's claim: at mag_cap>=4, shared-state logical RMWs per op
    (alloc climbs + release climbs over all alloc+free ops) fall below
    0.25 — steady-state churn never touches the trees.  mag_cap=0 is
    the magazines-off buddy/slab baseline in the same JSON."""
    from repro.core.magazine import MagazineConfig
    from repro.core.pool import pool_init_magazines, pool_wavefront_step_mag

    DEPTH = 6 if FAST else 8
    CHURN = 3 if FAST else 16
    S, W = 1, 16
    LANES_PER_MAG = 4
    L = W // LANES_PER_MAG
    records = []
    per_cap = {}
    for mag_cap in (0, 2, 4, 8):
        mcfg = (
            MagazineConfig(mag_cap=mag_cap) if mag_cap else None
        )
        pcfg = PoolConfig(TreeConfig(depth=DEPTH), S, magazines=mcfg)
        levels = jnp.full(W, DEPTH, jnp.int32)
        active = jnp.ones(W, bool)
        zeros = jnp.zeros(W, jnp.int32)
        mag_lane = jnp.asarray(
            [i % L for i in range(W)], jnp.int32
        )
        trees = pcfg.empty_trees()
        tot = {"logical": 0, "free_logical": 0, "hits": 0, "spills": 0}
        if mag_cap:
            mags = pool_init_magazines(pcfg, L)
            trees, mags, nodes, shard, ok, _ = pool_wavefront_step_mag(
                pcfg, trees, mags, zeros, zeros, jnp.zeros(W, bool),
                levels, active,
            )
            assert bool(ok.all())
            jax.block_until_ready(trees)
            t0 = time.perf_counter()
            for _ in range(CHURN):
                trees, mags, nodes, shard, ok, stats = (
                    pool_wavefront_step_mag(
                        pcfg, trees, mags, nodes, shard, ok, levels,
                        active, 64, None, mag_lane, mag_lane,
                    )
                )
                tot["logical"] += int(stats["logical_rmws"])
                tot["free_logical"] += int(stats["free_logical_rmws"])
                tot["hits"] += int(stats["magazine_hits"])
                tot["spills"] += int(stats["magazine_spills"])
        else:
            trees, nodes, shard, ok, _ = pool_wavefront_step(
                pcfg, trees, zeros, zeros, jnp.zeros(W, bool), levels,
                active,
            )
            assert bool(ok.all())
            jax.block_until_ready(trees)
            t0 = time.perf_counter()
            for _ in range(CHURN):
                trees, nodes, shard, ok, stats = pool_wavefront_step(
                    pcfg, trees, nodes, shard, ok, levels, active,
                )
                tot["logical"] += int(stats["logical_rmws"])
                tot["free_logical"] += int(stats["free_logical_rmws"])
        jax.block_until_ready(trees)
        dt = time.perf_counter() - t0
        assert bool(ok.all())
        ops = 2 * CHURN * W  # one free + one alloc per lane per step
        rmws_per_op = (tot["logical"] + tot["free_logical"]) / ops
        rec = bench_record(
            dims={"mag_cap": mag_cap, "n_shards": S, "depth": DEPTH,
                  "width": W, "lanes_per_mag": LANES_PER_MAG,
                  "churn_steps": CHURN},
            metrics={
                "logical_rmws": tot["logical"],
                "free_logical_rmws": tot["free_logical"],
                "magazine_hits": tot["hits"],
                "magazine_spills": tot["spills"],
                "rmws_per_op": rmws_per_op,
                "seconds": dt,
            },
        )
        per_cap[mag_cap] = rec["metrics"]
        records.append(rec)
        row(
            "constant_occupancy_magazine", f"pool-mag{mag_cap}", W, ops,
            dt,
            extra=(
                f"rmws/op={rmws_per_op:.3f};hits={tot['hits']};"
                f"spills={tot['spills']}"
            ),
        )
    # the tentpole claim: a deep-enough magazine absorbs the whole
    # churn burst — shared-state RMWs per op collapse vs the baseline
    for cap in (4, 8):
        assert per_cap[cap]["rmws_per_op"] < 0.25, per_cap
        assert per_cap[cap]["magazine_hits"] > 0
    assert (
        per_cap[4]["rmws_per_op"] < per_cap[0]["rmws_per_op"]
    ), per_cap
    if not FAST:
        dump_bench_json(
            "BENCH_MAGAZINE.json",
            bench_envelope(
                "bench_constant_occupancy/magazine_sweep",
                {"depth": DEPTH, "churn_steps": CHURN, "width": W,
                 "lanes_per_mag": LANES_PER_MAG},
                records,
            ),
        )


if __name__ == "__main__":
    run()
