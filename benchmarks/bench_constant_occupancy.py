"""Constant Occupancy benchmark (paper Fig. 11 — the paper's own test).

Each actor pre-allocates a pool of chunks with a size distribution
skewed towards small chunks (more allocations at smaller sizes), then
performs OPS random deallocate-reallocate pairs at the *same* size —
keeping the occupancy factor of the buddy system constant while
exercising splits/merges at many levels.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    WIDTHS,
    WavefrontAllocator,
    dump_bench_json,
    level_for,
    make_host_allocators,
    row,
)
from repro.core.concurrent import TreeConfig
from repro.core.pool import PoolConfig, pool_wavefront_step

TOTAL_MEM = 1 << 19
MIN_SIZE = 8
# skewed pool: many small, few large (paper: min sizes 8..1024, max 16x)
POOL_SPEC = [(8, 128), (16, 64), (32, 32), (64, 16), (128, 8), (1024, 4)]
OPS = 20_000


def run() -> None:
    units_total = TOTAL_MEM // MIN_SIZE
    rng = np.random.default_rng(1)

    for name, alloc in make_host_allocators(TOTAL_MEM, MIN_SIZE).items():
        pool = []
        for size, count in POOL_SPEC:
            for _ in range(count):
                a = alloc.nb_alloc(size)
                if a is not None:
                    pool.append((a, size))
        t0 = time.perf_counter()
        for _ in range(OPS // 2):
            i = int(rng.integers(len(pool)))
            addr, size = pool[i]
            alloc.nb_free(addr)
            pool[i] = (alloc.nb_alloc(size), size)
        dt = time.perf_counter() - t0
        row("constant_occupancy", name, 1, OPS, dt)

    for w in WIDTHS:
        wa = WavefrontAllocator(units_total, w)
        pool = []
        for size, count in POOL_SPEC:
            for _ in range(max(count // w, 1)):
                lv = np.full(w, level_for(units_total, size // MIN_SIZE),
                             np.int32)
                pool.append((wa.alloc_batch(lv), lv))
        wa.block()
        t0 = time.perf_counter()
        for _ in range(OPS // (2 * w)):
            i = int(rng.integers(len(pool)))
            nodes, lv = pool[i]
            wa.free_batch_(nodes)
            pool[i] = (wa.alloc_batch(lv), lv)
        wa.block()
        dt = time.perf_counter() - t0
        merged, logical = wa.free_stats
        row(
            "constant_occupancy", "nb-wavefront", w, OPS, dt,
            extra=f"free_merged={merged};free_logical={logical}",
        )

    # ---- sharded-pool sweep: constant-occupancy churn vs shard count ----
    # The paper's own workload on the pool: a skewed long-lived pool of
    # bursts, then dealloc/reallocate-at-the-same-size steps through
    # pool_wavefront_step (frees and allocs in one mixed pool round) at
    # equal total capacity for every S.  Reports rounds per churn step
    # and the per-shard merged-vs-logical release ratio (Fig. 7 metric,
    # release side, extended to the pool).
    TOTAL_DEPTH = 12            # 4096 units, constant across S
    W = 64                      # churn burst width
    CHURN_STEPS = 12
    shard_records = []
    for S in (1, 2, 4, 8):
        sd = TOTAL_DEPTH - (S.bit_length() - 1)
        pcfg = PoolConfig(TreeConfig(depth=sd), S)
        srng = np.random.default_rng(5)
        sizes = 2 ** srng.integers(0, 9, size=W)   # mixed octaves ~72%
        levels = jnp.asarray(sd - np.log2(sizes).astype(int), jnp.int32)
        active = jnp.ones(W, bool)
        trees = pcfg.empty_trees()
        # pre-allocate the long-lived pool (no frees on the first step)
        trees, nodes, shard, ok, _ = pool_wavefront_step(
            pcfg, trees, jnp.zeros(W, jnp.int32), jnp.zeros(W, jnp.int32),
            jnp.zeros(W, bool), levels, active,
        )
        jax.block_until_ready(trees)
        rounds_total = merged_total = logical_total = 0
        t0 = time.perf_counter()
        for _ in range(CHURN_STEPS):
            # constant occupancy: free the burst and re-allocate the
            # same levels in the same mixed pool step
            trees, nodes, shard, ok, stats = pool_wavefront_step(
                pcfg, trees, nodes, shard, ok, levels, active,
            )
            rounds_total += int(stats["rounds"])
            merged_total += int(stats["free_merged_writes"])
            logical_total += int(stats["free_logical_rmws"])
        jax.block_until_ready(trees)
        dt = time.perf_counter() - t0
        rec = {
            "n_shards": S,
            "shard_depth": sd,
            "width": W,
            "churn_steps": CHURN_STEPS,
            "rounds_total": rounds_total,
            "ok_final": int(ok.sum()),
            "free_merged_writes": merged_total,
            "free_logical_rmws": logical_total,
            "free_ratio": merged_total / max(logical_total, 1),
            "seconds": dt,
        }
        shard_records.append(rec)
        row(
            "constant_occupancy_shard_sweep", f"pool-s{S}", W,
            2 * CHURN_STEPS * W, dt,
            extra=(
                f"rounds_total={rounds_total};"
                f"free_merged={merged_total};free_logical={logical_total};"
                f"ratio={rec['free_ratio']:.3f}"
            ),
        )
        assert merged_total < logical_total, (
            "merged pool release must beat per-free RMWs",
            merged_total, logical_total,
        )
    dump_bench_json(
        "BENCH_CONSTANT_OCCUPANCY_SHARDS.json", shard_records
    )


if __name__ == "__main__":
    run()
