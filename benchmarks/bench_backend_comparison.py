"""Back-end comparison at kernel-allocator granularity (paper Fig. 12).

The paper compares against the Linux kernel buddy (128KB chunks through
__get_free_pages); kernel modules are unavailable here, so the
list-based Linux-style buddy (`FreeListBuddy`) stands in, configured
with the same geometry (large chunks, page-sized units) — see
docs/design.md §7.  Tests: Linux Scalability and Thread Test patterns at
128KB, plus Constant Occupancy with 128KB max chunks.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    WavefrontAllocator,
    level_for,
    make_host_allocators,
    row,
)

TOTAL_MEM = 1 << 26   # 64 MB managed
MIN_SIZE = 1 << 12    # 4 KB pages
CHUNK = 1 << 17       # 128 KB requests
OPS = 8_000


def run() -> None:
    units_total = TOTAL_MEM // MIN_SIZE

    for name, alloc in make_host_allocators(TOTAL_MEM, MIN_SIZE).items():
        # linux-scalability pattern at 128KB
        t0 = time.perf_counter()
        for _ in range(OPS // 2):
            a = alloc.nb_alloc(CHUNK)
            alloc.nb_free(a)
        dt = time.perf_counter() - t0
        row("backend_128k_scalability", name, 1, OPS, dt)

        # thread-test pattern at 128KB
        batch = (TOTAL_MEM // CHUNK) // 2
        t0 = time.perf_counter()
        for _ in range(5):
            addrs = [alloc.nb_alloc(CHUNK) for _ in range(batch)]
            for a in addrs:
                if a is not None:
                    alloc.nb_free(a)
        dt = time.perf_counter() - t0
        row("backend_128k_thread_test", name, 1, 5 * 2 * batch, dt)

    level = level_for(units_total, CHUNK // MIN_SIZE)
    for w in (1, 8, 32):
        wa = WavefrontAllocator(units_total, w)
        levels = np.full(w, level, np.int32)
        nodes = wa.alloc_batch(levels)
        wa.free_batch_(nodes)
        wa.block()
        t0 = time.perf_counter()
        for _ in range(OPS // (2 * w)):
            nodes = wa.alloc_batch(levels)
            wa.free_batch_(nodes)
        wa.block()
        dt = time.perf_counter() - t0
        row("backend_128k_scalability", "nb-wavefront", w, OPS, dt)


if __name__ == "__main__":
    run()
