"""Serving integration benchmark (beyond-paper): continuous batching on
NBBS-paged KV memory — tokens/s, admission behaviour and fragmentation
under request churn, versus a fixed-slot (no-buddy) pool baseline that
must reserve worst-case contiguous slots per sequence."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row
from repro.configs import get_config
from repro.memory.kv_cache import PagedKVManager
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine


def run() -> None:
    cfg = get_config("stablelm-3b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    eng = ServeEngine(
        cfg, params, num_pages=128, page_tokens=4, max_batch=8,
        dtype=jnp.float32,
    )
    n_req = 24
    for i in range(n_req):
        plen = int(rng.integers(2, 14))
        eng.submit(Request(
            i, rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
            max_new_tokens=int(rng.integers(2, 10)),
        ))
    t0 = time.perf_counter()
    eng.run_to_completion()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in eng.completed.values())
    frag = eng.kv.fragmentation()
    row("paged_serving", "nbbs-paged-engine", eng.max_batch, toks, dt,
        extra=f"queued_full={eng.stats['queued_full']};"
              f"largest_run_after={frag['largest_run']}")

    # allocator-level churn comparison: buddy pool vs fixed-slot pool
    kv = PagedKVManager(256, page_tokens=4)
    t0 = time.perf_counter()
    admitted = failed = 0
    live = []
    for i in range(2_000):
        if live and rng.random() < 0.5:
            kv.free_sequence(live.pop(int(rng.integers(len(live)))))
        else:
            need = int(rng.integers(4, 200))
            if kv.add_sequence(10_000 + i, need):
                admitted += 1
                live.append(10_000 + i)
            else:
                failed += 1
    dt = time.perf_counter() - t0
    row("paged_churn", "nbbs-buddy-pool", 1, 2_000, dt,
        extra=f"admitted={admitted};rejected={failed};"
              f"frag={kv.fragmentation()['largest_run']}")

    # fixed-slot baseline: worst-case contiguous reservation (no buddy):
    # slots of the maximum sequence size -> admission limited by slots
    slot_pages = 64  # worst case 200 tokens/4 -> 50 -> round 64
    n_slots = 256 // slot_pages
    free_slots = list(range(n_slots))
    live2 = []
    admitted2 = failed2 = 0
    t0 = time.perf_counter()
    for i in range(2_000):
        if live2 and rng.random() < 0.5:
            free_slots.append(live2.pop(int(rng.integers(len(live2)))))
        else:
            if free_slots:
                live2.append(free_slots.pop())
                admitted2 += 1
            else:
                failed2 += 1
    dt = time.perf_counter() - t0
    row("paged_churn", "fixed-slot-pool", 1, 2_000, dt,
        extra=f"admitted={admitted2};rejected={failed2}")


if __name__ == "__main__":
    run()
