"""Serving integration benchmark (beyond-paper): continuous batching on
NBBS-paged KV memory — tokens/s, admission behaviour and fragmentation
under request churn, versus a fixed-slot (no-buddy) pool baseline that
must reserve worst-case contiguous slots per sequence.

Requests come from the shared seeded generator
(`benchmarks.common.poisson_traffic`) so this bench and
`bench_serve_traffic` replay the same workload family; here the queue
is pre-loaded (arrival times ignored) because the host engine is the
only consumer.  `BENCH_FAST=1` shrinks the run for the CI smoke job.
"""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import poisson_traffic, row, traffic_prompt_tokens
from repro.configs import get_config
from repro.memory.kv_cache import PagedKVManager
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine

FAST = os.environ.get("BENCH_FAST") == "1"

N_REQ = 8 if FAST else 24
N_CHURN = 200 if FAST else 2_000
SEED = 0


def run() -> None:
    cfg = get_config("stablelm-3b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(SEED)

    eng = ServeEngine(
        cfg, params, num_pages=128, page_tokens=4, max_batch=8,
        dtype=jnp.float32,
    )
    trace = poisson_traffic(
        SEED, N_REQ, prompt_buckets=(2, 4, 8), out_range=(2, 8),
    )
    for t in trace:
        eng.submit(Request(
            t.req_id, traffic_prompt_tokens(t, cfg.vocab_size, rng),
            max_new_tokens=t.max_new,
        ))
    t0 = time.perf_counter()
    eng.run_to_completion()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in eng.completed.values())
    frag = eng.kv.fragmentation()
    row("paged_serving", "nbbs-paged-engine", eng.max_batch, toks, dt,
        extra=f"queued_full={eng.stats['queued_full']};"
              f"largest_run_after={frag['largest_run']}")

    # allocator-level churn comparison: buddy pool vs fixed-slot pool
    kv = PagedKVManager(256, page_tokens=4)
    t0 = time.perf_counter()
    admitted = failed = 0
    live = []
    for i in range(N_CHURN):
        if live and rng.random() < 0.5:
            kv.free_sequence(live.pop(int(rng.integers(len(live)))))
        else:
            need = int(rng.integers(4, 200))
            if kv.add_sequence(10_000 + i, need):
                admitted += 1
                live.append(10_000 + i)
            else:
                failed += 1
    dt = time.perf_counter() - t0
    row("paged_churn", "nbbs-buddy-pool", 1, N_CHURN, dt,
        extra=f"admitted={admitted};rejected={failed};"
              f"frag={kv.fragmentation()['largest_run']}")

    # fixed-slot baseline: worst-case contiguous reservation (no buddy):
    # slots of the maximum sequence size -> admission limited by slots
    slot_pages = 64  # worst case 200 tokens/4 -> 50 -> round 64
    n_slots = 256 // slot_pages
    free_slots = list(range(n_slots))
    live2 = []
    admitted2 = failed2 = 0
    t0 = time.perf_counter()
    for i in range(N_CHURN):
        if live2 and rng.random() < 0.5:
            free_slots.append(live2.pop(int(rng.integers(len(live2)))))
        else:
            if free_slots:
                live2.append(free_slots.pop())
                admitted2 += 1
            else:
                failed2 += 1
    dt = time.perf_counter() - t0
    row("paged_churn", "fixed-slot-pool", 1, N_CHURN, dt,
        extra=f"admitted={admitted2};rejected={failed2}")


if __name__ == "__main__":
    run()
