"""Thread Test benchmark (paper Fig. 9; Berger et al. Hoard [17]).

Each actor performs N/W allocations of a fixed size, then releases all
of them, repeating for CYCLES rounds.  Exercises batch-alloc-then-
batch-free — the regime where the paper observed the 4-level (bunch)
organization winning.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    WIDTHS,
    WavefrontAllocator,
    level_for,
    make_host_allocators,
    row,
)

TOTAL_MEM = 1 << 19
MIN_SIZE = 8
ALLOC_SIZE = 64
N_ALLOCS = 1_000  # paper: 10000/num_threads; scaled
CYCLES = 10


def run() -> None:
    units_total = TOTAL_MEM // MIN_SIZE
    batch = min(N_ALLOCS, (TOTAL_MEM // ALLOC_SIZE) // 2)

    for name, alloc in make_host_allocators(TOTAL_MEM, MIN_SIZE).items():
        t0 = time.perf_counter()
        for _ in range(CYCLES):
            addrs = [alloc.nb_alloc(ALLOC_SIZE) for _ in range(batch)]
            for a in addrs:
                if a is not None:
                    alloc.nb_free(a)
        dt = time.perf_counter() - t0
        row("thread_test", name, 1, CYCLES * 2 * batch, dt)

    level = level_for(units_total, ALLOC_SIZE // MIN_SIZE)
    for w in WIDTHS:
        wa = WavefrontAllocator(units_total, w)
        levels = np.full(w, level, np.int32)
        nodes = wa.alloc_batch(levels)
        wa.free_batch_(nodes)
        wa.block()
        t0 = time.perf_counter()
        for _ in range(CYCLES):
            held = []
            for _ in range(batch // w):
                held.append(wa.alloc_batch(levels))
            for nodes in held:
                wa.free_batch_(nodes)
        wa.block()
        dt = time.perf_counter() - t0
        row("thread_test", "nb-wavefront", w,
            CYCLES * 2 * (batch // w) * w, dt)


if __name__ == "__main__":
    run()
