"""RMW-reduction measurement (paper §III-D: the 4-level bunch cuts the
atomic-instruction count on the climb by ~4x; the TPU-native 32-bit
variant by ~3x).  Reports word-RMWs per operation for the unpacked
tree vs packed bunches, and the wavefront's merged-write count."""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from benchmarks.common import WavefrontAllocator, row
from repro.core.bunch import BunchBuddy
from repro.core.ref import NBBSRef

TOTAL_MEM = 1 << 16
MIN_SIZE = 1
OPS = 2_000


def run() -> None:
    rng = np.random.default_rng(2)
    sizes = [1, 1, 2, 4, 8, 16]

    variants = {
        "1lvl": NBBSRef(TOTAL_MEM, MIN_SIZE),
        "4lvl-64b": BunchBuddy(TOTAL_MEM, MIN_SIZE, bunch_levels=4,
                               word_bits=64),
        "3lvl-32b": BunchBuddy(TOTAL_MEM, MIN_SIZE, bunch_levels=3,
                               word_bits=32),
        "2lvl-32b": BunchBuddy(TOTAL_MEM, MIN_SIZE, bunch_levels=2,
                               word_bits=32),
    }
    results = {}
    for name, alloc in variants.items():
        live = []
        for i in range(OPS):
            if live and rng.random() < 0.5:
                alloc.nb_free(live.pop(int(rng.integers(len(live)))))
            else:
                a = alloc.nb_alloc(int(rng.choice(sizes)))
                if a is not None:
                    live.append(a)
        rmw = (
            alloc.stats.cas_attempts
            if hasattr(alloc.stats, "cas_attempts")
            else alloc.stats.word_rmws
        )
        results[name] = rmw / OPS
        row("bunch_rmw", name, 1, OPS, 1e-9, extra=f"rmw_per_op={rmw/OPS:.2f}")
    base = results["1lvl"]
    for name, r in results.items():
        if name != "1lvl":
            row("bunch_rmw_reduction", name, 1, OPS, 1e-9,
                extra=f"reduction={base / r:.2f}x")

    # wavefront merged writes: the vector-width limit of the same idea
    units = TOTAL_MEM // MIN_SIZE
    for w in (8, 32, 128):
        wa = WavefrontAllocator(units, w)
        from repro.core.concurrent import wavefront_alloc

        lv = jnp.full(w, 10, jnp.int32)
        tree, nodes, ok, stats = wavefront_alloc(
            wa.cfg, wa.tree, lv, jnp.ones(w, bool)
        )
        merged = int(stats["merged_writes"])
        logical = int(stats["logical_rmws"])
        row("wavefront_merged_writes", "nb-wavefront", w, w, 1e-9,
            extra=f"merged={merged};logical={logical};"
                  f"reduction={logical / max(merged, 1):.2f}x")


if __name__ == "__main__":
    run()
