"""RMW-reduction measurement (paper §III-D: the 4-level bunch cuts the
atomic-instruction count on the climb by ~4x; the TPU-native 32-bit
variant by ~3x).  Two sections:

  1. host allocators — word-RMWs per operation for the unpacked tree vs
     packed `BunchBuddy` variants, plus the wavefront's merged-write
     count (the vector-width limit of the same idea);
  2. device layouts — the SAME workloads replayed through
     `TreeConfig(layout=UNPACKED)` vs `TreeConfig(layout=BUNCH_PACKED)`
     (docs/design.md §3): allocation outcomes are asserted bit-identical
     first, then merged climb writes / logical RMWs / state footprint
     are recorded per workload (mixed-octave burst, constant occupancy)
     and appended to BENCH_BUNCH_LAYOUT.json.  The packed column must be
     strictly below the unpacked one — the §III-D claim carried through
     the merged substrate.

`BENCH_FAST=1` shrinks trees/ops for the CI smoke job (both layouts
still run).
"""

from __future__ import annotations

import os

import numpy as np

import jax.numpy as jnp

from benchmarks.common import (
    WavefrontAllocator,
    bench_envelope,
    bench_record,
    dump_bench_json,
    row,
)
from repro.core.bunch import BunchBuddy
from repro.core.concurrent import (
    BUNCH_PACKED,
    TreeConfig,
    UNPACKED,
    wavefront_alloc,
    wavefront_free,
    wavefront_step,
)
from repro.core.ref import NBBSRef

FAST = os.environ.get("BENCH_FAST") == "1"

TOTAL_MEM = 1 << (12 if FAST else 16)
MIN_SIZE = 1
OPS = 300 if FAST else 2_000

# device-layout sweep geometry
DEV_DEPTH = 8 if FAST else 12
DEV_WIDTH = 32 if FAST else 128
CHURN_ROUNDS = 4 if FAST else 10


def _host_section() -> None:
    rng = np.random.default_rng(2)
    sizes = [1, 1, 2, 4, 8, 16]

    variants = {
        "1lvl": NBBSRef(TOTAL_MEM, MIN_SIZE),
        "4lvl-64b": BunchBuddy(TOTAL_MEM, MIN_SIZE, bunch_levels=4,
                               word_bits=64),
        "3lvl-32b": BunchBuddy(TOTAL_MEM, MIN_SIZE, bunch_levels=3,
                               word_bits=32),
        "2lvl-32b": BunchBuddy(TOTAL_MEM, MIN_SIZE, bunch_levels=2,
                               word_bits=32),
    }
    results = {}
    for name, alloc in variants.items():
        live = []
        for i in range(OPS):
            if live and rng.random() < 0.5:
                alloc.nb_free(live.pop(int(rng.integers(len(live)))))
            else:
                a = alloc.nb_alloc(int(rng.choice(sizes)))
                if a is not None:
                    live.append(a)
        rmw = (
            alloc.stats.cas_attempts
            if hasattr(alloc.stats, "cas_attempts")
            else alloc.stats.word_rmws
        )
        results[name] = rmw / OPS
        row("bunch_rmw", name, 1, OPS, 1e-9, extra=f"rmw_per_op={rmw/OPS:.2f}")
    base = results["1lvl"]
    for name, r in results.items():
        if name != "1lvl":
            row("bunch_rmw_reduction", name, 1, OPS, 1e-9,
                extra=f"reduction={base / r:.2f}x")

    # wavefront merged writes: the vector-width limit of the same idea
    units = TOTAL_MEM // MIN_SIZE
    for w in (8, 32) if FAST else (8, 32, 128):
        wa = WavefrontAllocator(units, w)
        lv = jnp.full(w, 10, jnp.int32)
        tree, nodes, ok, stats = wavefront_alloc(
            wa.cfg, wa.tree, lv, jnp.ones(w, bool)
        )
        merged = int(stats["merged_writes"])
        logical = int(stats["logical_rmws"])
        row("wavefront_merged_writes", "nb-wavefront", w, w, 1e-9,
            extra=f"merged={merged};logical={logical};"
                  f"reduction={logical / max(merged, 1):.2f}x")


def _mixed_octave_burst(cfg: TreeConfig, rng) -> dict:
    """One saturating mixed-octave burst + its full release."""
    K = DEV_WIDTH
    levels = jnp.asarray(
        rng.integers(cfg.depth - 7, cfg.depth + 1, size=K), jnp.int32
    )
    tree, nodes, ok, stats = wavefront_alloc(
        cfg, cfg.empty_tree(), levels, jnp.ones(K, bool)
    )
    tree, freed, fstats = wavefront_free(cfg, tree, nodes, ok)
    assert (np.asarray(tree) == 0).all()
    return {
        "nodes": np.asarray(nodes),
        "ok": np.asarray(ok),
        "merged_writes": int(stats["merged_writes"])
        + int(fstats["merged_writes"]),
        "logical_rmws": int(stats["logical_rmws"])
        + int(fstats["logical_rmws"]),
        "rounds": int(stats["rounds"]),
    }


def _constant_occupancy(cfg: TreeConfig, rng) -> dict:
    """Paper Fig. 11 shape: a skewed long-lived pool, then churn at
    constant occupancy through `wavefront_step`."""
    K = DEV_WIDTH
    pool_levels = jnp.asarray(
        np.concatenate([
            rng.integers(cfg.depth - 3, cfg.depth + 1, size=3 * K // 4),
            rng.integers(cfg.depth - 7, cfg.depth - 3, size=K - 3 * K // 4),
        ]),
        jnp.int32,
    )
    tree, nodes, ok, stats = wavefront_alloc(
        cfg, cfg.empty_tree(), pool_levels, jnp.ones(K, bool)
    )
    merged = int(stats["merged_writes"])
    logical = int(stats["logical_rmws"])
    outcome = [np.asarray(nodes)]
    for _ in range(CHURN_ROUNDS):
        tree, nodes, ok, st = wavefront_step(
            cfg, tree, nodes, ok, pool_levels, jnp.ones(K, bool)
        )
        merged += int(st["merged_writes"]) + int(st["free_merged_writes"])
        logical += int(st["logical_rmws"]) + int(st["free_logical_rmws"])
        outcome.append(np.asarray(nodes))
    return {
        "nodes": np.concatenate(outcome),
        "ok": np.asarray(ok),
        "merged_writes": merged,
        "logical_rmws": logical,
        "rounds": int(stats["rounds"]),
    }


def _device_layout_sweep() -> None:
    cu = TreeConfig(depth=DEV_DEPTH, max_level=0, layout=UNPACKED)
    cp = TreeConfig(depth=DEV_DEPTH, max_level=0, layout=BUNCH_PACKED)
    records = []
    for workload, fn in (
        ("mixed_octave_burst", _mixed_octave_burst),
        ("constant_occupancy", _constant_occupancy),
    ):
        # identical rng stream per layout: identical workloads
        ru = fn(cu, np.random.default_rng(7))
        rp = fn(cp, np.random.default_rng(7))
        # outcomes must be bit-identical before costs are comparable
        assert (ru["nodes"] == rp["nodes"]).all(), workload
        assert (ru["ok"] == rp["ok"]).all(), workload
        assert rp["merged_writes"] < ru["merged_writes"], (
            "packed climb writes must be strictly below unpacked",
            workload, rp["merged_writes"], ru["merged_writes"],
        )
        rec = bench_record(
            dims={"workload": workload, "depth": DEV_DEPTH,
                  "width": DEV_WIDTH, "fast_mode": FAST,
                  "unpacked_state_words": cu.n_state_words,
                  "packed_state_words": cp.n_state_words},
            metrics={
                "state_ratio": cp.n_state_words / cu.n_state_words,
                "unpacked_merged_writes": ru["merged_writes"],
                "packed_merged_writes": rp["merged_writes"],
                "unpacked_logical_rmws": ru["logical_rmws"],
                "packed_logical_rmws": rp["logical_rmws"],
                "merged_reduction": ru["merged_writes"]
                / max(rp["merged_writes"], 1),
            },
        )
        m = rec["metrics"]
        assert m["state_ratio"] <= 0.25
        records.append(rec)
        row(
            "bunch_layout_sweep", workload, DEV_WIDTH, DEV_WIDTH, 1e-9,
            extra=(
                f"unpacked_merged={m['unpacked_merged_writes']};"
                f"packed_merged={m['packed_merged_writes']};"
                f"reduction={m['merged_reduction']:.2f}x;"
                f"state_ratio={m['state_ratio']:.3f}"
            ),
        )
    if not FAST:
        # never clobber the committed full-run trajectory with the
        # tiny smoke geometry
        dump_bench_json(
            "BENCH_BUNCH_LAYOUT.json",
            bench_envelope(
                "bench_bunch_rmw/layout_sweep",
                {"depth": DEV_DEPTH, "width": DEV_WIDTH},
                records,
            ),
        )


def run() -> None:
    _host_section()
    _device_layout_sweep()


if __name__ == "__main__":
    run()
