"""Shared benchmark harness utilities.

All benchmarks emit CSV rows: name,allocator,width,ops,seconds,
ops_per_sec,extra.  "width" is the wavefront width — the concurrency
axis that maps the paper's thread count onto this substrate
(docs/design.md §2): lock-based allocators serialize a width-W batch,
the non-blocking wavefront commits it in a handful of arbitration
rounds.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Iterable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import FreeListBuddy, SpinlockTreeBuddy
from repro.core.bunch import BunchBuddy
from repro.core.concurrent import TreeConfig, wavefront_alloc, wavefront_free
from repro.core.ref import NBBSRef

WIDTHS = (1, 2, 4, 8, 16, 32)

# Every BENCH_*.json artifact carries this envelope version;
# tools/check_bench_schema.py validates it (and every metric name
# against repro/obs/schema.py) in the CI bench-smoke job.
BENCH_SCHEMA_VERSION = 1


def bench_record(dims: dict, metrics: dict) -> dict:
    """One standardized benchmark record: `dims` are the workload axes
    that vary across records (shard count, layout, telemetry mode...),
    `metrics` are named observables — every key must be registered in
    the obs schema, so benchmarks cannot invent counters that drift
    from the kernels' and the engine's."""
    from repro.obs.schema import spec

    for name in metrics:
        spec(name)  # raises on unregistered metric names
    return {"dims": dims, "metrics": metrics}


def bench_envelope(
    benchmark: str, config: dict, records: List[dict], **extra
) -> dict:
    """The standardized BENCH_*.json envelope (schema_version,
    benchmark name, workload config, bench_record list)."""
    out = {
        "schema_version": BENCH_SCHEMA_VERSION,
        "benchmark": benchmark,
        "config": config,
        "records": records,
    }
    out.update(extra)
    return out


def dump_bench_json(filename: str, payload) -> str:
    """Persist a benchmark section's records as a JSON artifact at the
    repo root (BENCH_*.json — the scaling-trajectory record the docs
    and later PRs compare against).  Payloads must be `bench_envelope`
    objects — the CI schema check rejects bare record lists.  Returns
    the path written."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, filename)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}")
    return path


def row(name, allocator, width, ops, seconds, extra=""):
    out = (
        f"{name},{allocator},{width},{ops},{seconds:.4f},"
        f"{ops / max(seconds, 1e-9):.0f},{extra}"
    )
    print(out)
    return out


class WavefrontAllocator:
    """Batched non-blocking allocator (width-W wavefronts, jitted)."""

    name = "nb-wavefront"

    def __init__(self, total_units: int, width: int):
        self.cfg = TreeConfig(depth=(total_units - 1).bit_length(), max_level=0)
        self.tree = self.cfg.empty_tree()
        self.width = width
        self.total_units = total_units
        # running free-side instrumentation (paper Fig. 7, release side);
        # kept as device scalars so the timed loop never syncs
        self._free_merged = jnp.int32(0)
        self._free_logical = jnp.int32(0)

    def alloc_batch(self, levels: np.ndarray) -> np.ndarray:
        lv = jnp.asarray(levels, jnp.int32)
        self.tree, nodes, ok, _ = wavefront_alloc(
            self.cfg, self.tree, lv, jnp.ones(len(levels), bool)
        )
        return np.asarray(nodes)

    def free_batch_(self, nodes: np.ndarray) -> None:
        self.tree, _, stats = wavefront_free(
            self.cfg,
            self.tree,
            jnp.asarray(nodes, jnp.int32),
            jnp.asarray(nodes > 0),
        )
        self._free_merged = self._free_merged + stats["merged_writes"]
        self._free_logical = self._free_logical + stats["logical_rmws"]

    @property
    def free_stats(self) -> tuple:
        """(merged_writes, logical_rmws) accumulated over all frees."""
        return int(self._free_merged), int(self._free_logical)

    def block(self):
        jax.block_until_ready(self.tree)


def level_for(total_units: int, units: int) -> int:
    """Tree level serving an allocation of `units` units (paper rule A5)."""
    depth = (total_units - 1).bit_length()
    units = max(units, 1)
    need = 1 << (units - 1).bit_length()  # round up to power of two
    return depth - (need.bit_length() - 1)


def make_host_allocators(total_memory: int, min_size: int):
    """The paper's comparison set (host-side, sequential execution)."""
    return {
        "1lvl-nb-seq": NBBSRef(total_memory, min_size),          # our tree, sequential
        "1lvl-sl": SpinlockTreeBuddy(total_memory, min_size),    # + global lock
        "4lvl-nb-seq": BunchBuddy(total_memory, min_size, bunch_levels=4,
                                  word_bits=64),
        "list-buddy-sl": FreeListBuddy(total_memory, min_size),  # Linux-style
    }


# ---------------------------------------------------------------------------
# Shared serving-traffic generator (bench_paged_serving + bench_serve_traffic)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrafficRequest:
    """One synthetic serving request in step-time."""

    req_id: int
    arrival_step: int  # decode-step index at which the request arrives
    prompt_len: int
    max_new: int


def poisson_traffic(
    seed: int,
    n_requests: int,
    *,
    rate_per_step: float = 2.0,
    prompt_buckets: Sequence[int] = (2, 4, 8, 16, 32),
    prompt_weights: Optional[Sequence[float]] = None,
    out_range: tuple = (2, 32),
    out_mean: float = 8.0,
) -> List[TrafficRequest]:
    """Seeded request synthesis shared by the serving benchmarks.

    Arrivals are Poisson in *decode-step time* (exponential inter-
    arrival gaps of mean 1/rate), so the same trace drives engines of
    different wall-clock speed identically and latency is measured in
    steps.  Lengths are mixed the way serving traffic is:

      * prompts: a bucketed distribution skewed toward short
        interactive turns with a long-document tail (power-of-two
        buckets, so prefill compiles stay bounded for every engine);
      * outputs: geometric (many short answers, occasional rambles),
        clipped to `out_range`.
    """
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_per_step, size=n_requests)
    arrivals = np.floor(np.cumsum(gaps)).astype(np.int64)
    if prompt_weights is None:
        # short-turn heavy, monotone tail over the buckets
        w = np.asarray([2.0 ** -i for i in range(len(prompt_buckets))])
    else:
        w = np.asarray(prompt_weights, float)
    w = w / w.sum()
    prompts = rng.choice(np.asarray(prompt_buckets), size=n_requests, p=w)
    lo, hi = out_range
    outs = np.clip(rng.geometric(min(1.0, 1.0 / out_mean), n_requests), lo, hi)
    return [
        TrafficRequest(i, int(arrivals[i]), int(prompts[i]), int(outs[i]))
        for i in range(n_requests)
    ]


def traffic_prompt_tokens(
    tr: TrafficRequest, vocab_size: int, rng: np.random.Generator
) -> np.ndarray:
    """Deterministic-given-rng token fill for a synthetic request."""
    return rng.integers(0, vocab_size, size=tr.prompt_len).astype(np.int32)


def quantiles_steps(latencies: Sequence[int]) -> dict:
    """p50/p99 over integer step latencies (empty-safe)."""
    if not latencies:
        return {"p50": None, "p99": None}
    arr = np.asarray(sorted(latencies), float)
    return {
        "p50": float(np.percentile(arr, 50)),
        "p99": float(np.percentile(arr, 99)),
    }


def time_host_trace(alloc, trace: Iterable, min_size: int) -> float:
    """Replays (op, arg) trace: ('a', size) / ('f', key). Returns secs."""
    live = {}
    t0 = time.perf_counter()
    for op, arg in trace:
        if op == "a":
            a = alloc.nb_alloc(arg)
            if a is not None:
                live[len(live) + 1] = a
        else:
            if live:
                k = next(iter(live)) if arg is None else arg
                if k in live:
                    alloc.nb_free(live.pop(k))
    t1 = time.perf_counter()
    for a in live.values():
        alloc.nb_free(a)
    return t1 - t0
