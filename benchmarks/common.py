"""Shared benchmark harness utilities.

All benchmarks emit CSV rows: name,allocator,width,ops,seconds,
ops_per_sec,extra.  "width" is the wavefront width — the concurrency
axis that maps the paper's thread count onto this substrate
(docs/design.md §2): lock-based allocators serialize a width-W batch,
the non-blocking wavefront commits it in a handful of arbitration
rounds.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable, Iterable, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.baselines import FreeListBuddy, SpinlockTreeBuddy
from repro.core.bunch import BunchBuddy
from repro.core.concurrent import TreeConfig, wavefront_alloc, wavefront_free
from repro.core.ref import NBBSRef

WIDTHS = (1, 2, 4, 8, 16, 32)


def dump_bench_json(filename: str, payload) -> str:
    """Persist a benchmark section's records as a JSON artifact at the
    repo root (BENCH_*.json — the scaling-trajectory record the docs
    and later PRs compare against).  Returns the path written."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(root, filename)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}")
    return path


def row(name, allocator, width, ops, seconds, extra=""):
    out = (
        f"{name},{allocator},{width},{ops},{seconds:.4f},"
        f"{ops / max(seconds, 1e-9):.0f},{extra}"
    )
    print(out)
    return out


class WavefrontAllocator:
    """Batched non-blocking allocator (width-W wavefronts, jitted)."""

    name = "nb-wavefront"

    def __init__(self, total_units: int, width: int):
        self.cfg = TreeConfig(depth=(total_units - 1).bit_length(), max_level=0)
        self.tree = self.cfg.empty_tree()
        self.width = width
        self.total_units = total_units
        # running free-side instrumentation (paper Fig. 7, release side);
        # kept as device scalars so the timed loop never syncs
        self._free_merged = jnp.int32(0)
        self._free_logical = jnp.int32(0)

    def alloc_batch(self, levels: np.ndarray) -> np.ndarray:
        lv = jnp.asarray(levels, jnp.int32)
        self.tree, nodes, ok, _ = wavefront_alloc(
            self.cfg, self.tree, lv, jnp.ones(len(levels), bool)
        )
        return np.asarray(nodes)

    def free_batch_(self, nodes: np.ndarray) -> None:
        self.tree, _, stats = wavefront_free(
            self.cfg,
            self.tree,
            jnp.asarray(nodes, jnp.int32),
            jnp.asarray(nodes > 0),
        )
        self._free_merged = self._free_merged + stats["merged_writes"]
        self._free_logical = self._free_logical + stats["logical_rmws"]

    @property
    def free_stats(self) -> tuple:
        """(merged_writes, logical_rmws) accumulated over all frees."""
        return int(self._free_merged), int(self._free_logical)

    def block(self):
        jax.block_until_ready(self.tree)


def level_for(total_units: int, units: int) -> int:
    """Tree level serving an allocation of `units` units (paper rule A5)."""
    depth = (total_units - 1).bit_length()
    units = max(units, 1)
    need = 1 << (units - 1).bit_length()  # round up to power of two
    return depth - (need.bit_length() - 1)


def make_host_allocators(total_memory: int, min_size: int):
    """The paper's comparison set (host-side, sequential execution)."""
    return {
        "1lvl-nb-seq": NBBSRef(total_memory, min_size),          # our tree, sequential
        "1lvl-sl": SpinlockTreeBuddy(total_memory, min_size),    # + global lock
        "4lvl-nb-seq": BunchBuddy(total_memory, min_size, bunch_levels=4,
                                  word_bits=64),
        "list-buddy-sl": FreeListBuddy(total_memory, min_size),  # Linux-style
    }


def time_host_trace(alloc, trace: Iterable, min_size: int) -> float:
    """Replays (op, arg) trace: ('a', size) / ('f', key). Returns secs."""
    live = {}
    t0 = time.perf_counter()
    for op, arg in trace:
        if op == "a":
            a = alloc.nb_alloc(arg)
            if a is not None:
                live[len(live) + 1] = a
        else:
            if live:
                k = next(iter(live)) if arg is None else arg
                if k in live:
                    alloc.nb_free(live.pop(k))
    t1 = time.perf_counter()
    for a in live.values():
        alloc.nb_free(a)
    return t1 - t0
