"""Heavy-traffic serving benchmark: the jit-resident engine under
Poisson request pressure (the paper's full-concurrency scenario run
end-to-end through the compiled decode step).

One seeded trace (`benchmarks.common.poisson_traffic`: Poisson
arrivals in decode-step time, short-turn-heavy prompt buckets,
geometric output lengths) is replayed through:

  * the **jitted** engine (`serve.jit_engine.JitServeEngine`) — page
    allocation, paged attention, argmax and retirement burst-frees all
    inside one compiled `engine_step`, decoded in scan-fused chunks —
    for both tree layouts x S ∈ {1, 4} pool shards;
  * the **host-driven** engine (`serve.engine.ServeEngine`) — the
    PR-2-era loop that rebuilds tables in numpy and syncs logits every
    token — once per shard count, as the baseline the tentpole must
    beat on steady-state decode throughput.

Reported per run (into BENCH_SERVE_TRAFFIC.json unless BENCH_FAST=1):
wall/decode time, tokens/s, p50/p99 request sojourn (arrival ->
retirement, in steps and seconds), admission stats (queued_full /
rejected / overflow retirements) and allocator counters (merged writes
per alloc, probe overflows), plus a per-chunk occupancy trajectory
(active lanes, free pages, completions over time).

One extra jit run repeats the first configuration with the full
telemetry plane enabled (`ring_capacity > 0`): its steady-state decode
throughput vs the telemetry-off twin is the measured observability
overhead (must stay under 3%), and its drained
`JitServeEngine.snapshot()` is written to
BENCH_SERVE_TRAFFIC_SNAPSHOT.json — the artifact
`tools/obsdump.py --trace` renders as a Perfetto timeline.

Latency is measured in *steps* on the engine's own decode clock, so
both engines see identical arrival schedules regardless of wall speed;
seconds are derived from each engine's measured per-step wall time.

`BENCH_FAST=1` shrinks everything for the CI smoke job and skips the
JSON write (the committed artifact records full runs only) and the
perf assertion.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    bench_envelope,
    bench_record,
    dump_bench_json,
    poisson_traffic,
    quantiles_steps,
    row,
    traffic_prompt_tokens,
)
from repro.configs import get_config
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine
from repro.serve.jit_engine import JitServeEngine

FAST = os.environ.get("BENCH_FAST") == "1"

# Full-mode geometry targets *saturated* steady state: offered load
# (RATE x mean output length) ~ MAX_BATCH, so both engines decode full
# wavefronts and throughput measures engine overhead, not idle lanes.
N_REQ = 12 if FAST else 600
RATE = 1.0 if FAST else 8.0  # mean arrivals per decode step
NUM_PAGES = 64 if FAST else 4096
PAGE_TOKENS = 4
MAX_BATCH = 8 if FAST else 256  # concurrent device lanes
MAX_LANE_PAGES = 8 if FAST else 32
MAX_OUT = 8 if FAST else 64
PROMPTS = (2, 4, 8) if FAST else (2, 4, 8, 16, 32)
OUT_RANGE = (2, 8) if FAST else (8, 64)
OUT_MEAN = 4.0 if FAST else 32.0  # mean decode steps per request
CHUNK = 4 if FAST else 8  # scan-fused steps per dispatch
REPS = 1 if FAST else 2  # replays per row; each row reports its best
SHARDS = (1,) if FAST else (1, 4)
LAYOUTS = ("unpacked",) if FAST else ("unpacked", "bunch-packed")
SEED = 0
RING_CAP = 128 if FAST else 4096  # event ring size of the telemetry run
SNAPSHOT_FILE = "BENCH_SERVE_TRAFFIC_SNAPSHOT.json"


def _trace():
    return poisson_traffic(
        SEED, N_REQ, rate_per_step=RATE, prompt_buckets=PROMPTS,
        out_range=OUT_RANGE, out_mean=OUT_MEAN,
    )


def _prompts(trace, vocab):
    rng = np.random.default_rng(SEED + 1)
    return {t.req_id: traffic_prompt_tokens(t, vocab, rng) for t in trace}


def steady_toks_per_s(trajectory, n_requests) -> float | None:
    """Decode throughput over the saturated middle of the run: tokens
    completed between the trajectory points nearest 10% and 90% of
    request completions.  Excludes one-time compilation at the head and
    the draining tail, so it is the steady-state number the jit-vs-host
    comparison is about (each engine's own clock, same trace)."""
    if len(trajectory) < 3:
        return None
    lo_c, hi_c = 0.1 * n_requests, 0.9 * n_requests
    lo = next((p for p in trajectory if p["completed"] >= lo_c), None)
    hi = next((p for p in trajectory if p["completed"] >= hi_c), None)
    if lo is None or hi is None or hi["t"] <= lo["t"]:
        return None
    return (hi["tokens_done"] - lo["tokens_done"]) / (hi["t"] - lo["t"])


def run_jit(cfg, params, trace, prompts, n_shards, layout,
            fastpath=False, telemetry=False, magazines=0,
            snapshot_path=None) -> dict:
    def attempt():
        eng = JitServeEngine(
            cfg, params, num_pages=NUM_PAGES, page_tokens=PAGE_TOKENS,
            max_batch=MAX_BATCH, max_lane_pages=MAX_LANE_PAGES,
            max_out=MAX_OUT, dtype=jnp.float32, n_shards=n_shards,
            layout=layout, fastpath=fastpath, magazines=magazines,
            ring_capacity=RING_CAP if telemetry else 0,
        )
        pending = deque(trace)
        trajectory = []
        t0 = time.perf_counter()
        while True:
            eng._drain()
            now = eng.stats["steps"]
            while pending and pending[0].arrival_step <= now:
                t = pending.popleft()
                eng.submit(
                    Request(t.req_id, prompts[t.req_id], t.max_new)
                )
            eng._admit()
            if not pending and not eng.waiting and not eng.running:
                break
            # decode even when idle-waiting for arrivals: the device
            # step counter is the arrival clock, so it must keep
            # ticking
            eng.decode_steps(CHUNK, fused=True)
            trajectory.append({
                "step": eng.stats["steps"],
                "t": time.perf_counter() - t0,
                "completed": len(eng.completed),
                "tokens_done": sum(
                    len(r.out_tokens) for r in eng.completed.values()
                ),
                "active_lanes": int(np.asarray(eng.state.active).sum()),
                "free_pages": eng.device_free_pages(),
            })
        return eng, trajectory, time.perf_counter() - t0

    arrival = {t.req_id: t.arrival_step for t in trace}
    # every row reports its best of REPS replays (the second replay
    # reuses the compiled step, so it only costs decode wall time):
    # single-shot wall clocks on a 1-core box swing enough to drown
    # the ratios the full run asserts on
    eng, trajectory, wall = None, None, None
    for _ in range(REPS):
        e, tr, w = attempt()
        s = steady_toks_per_s(tr, len(trace))
        if eng is None or s > steady_toks_per_s(trajectory, len(trace)):
            eng, trajectory, wall = e, tr, w
    steps = max(eng.stats["steps"], 1)
    toks = sum(len(r.out_tokens) for r in eng.completed.values())
    lat = [
        eng.done_steps[t.req_id] - arrival[t.req_id]
        for t in trace
        if t.req_id in eng.done_steps
    ]
    q = quantiles_steps(lat)
    step_s = wall / steps
    tot = eng.stat_totals()
    metrics = {
        "wall_s": wall,
        "decode_steps": eng.stats["steps"],
        "tokens_out": toks,
        "toks_per_s": toks / max(wall, 1e-9),
        "steady_toks_per_s": steady_toks_per_s(trajectory, len(trace)),
        "p50_latency_steps": q["p50"],
        "p99_latency_steps": q["p99"],
        "p50_latency_s": None if q["p50"] is None else q["p50"] * step_s,
        "p99_latency_s": None if q["p99"] is None else q["p99"] * step_s,
        "admitted": eng.stats["admitted"],
        "queued_full": eng.stats["queued_full"],
        "rejected": eng.stats["rejected"],
        "overflow_retired": eng.stats["overflow_retired"],
        "alloc_pages": tot["alloc_pages"],
        "freed_pages": tot["freed_pages"],
        "probe_overflows": tot["probe_overflows"],
        "merged_writes_per_alloc": (
            tot["merged_writes"] / max(tot["alloc_pages"], 1)
        ),
        "fastpath_hits": tot["fastpath_hits"],
        "fastpath_spills": tot["fastpath_spills"],
        "free_pages": eng.device_free_pages(),
    }
    if magazines:
        metrics["magazine_hits"] = tot["magazine_hits"]
        metrics["magazine_spills"] = tot["magazine_spills"]
        metrics["magazine_refills"] = tot["magazine_refills"]
    if telemetry:
        metrics["ring_events"] = tot["ring_events"]
        metrics["ring_dropped"] = tot["ring_dropped"]
    rec = bench_record(
        dims={
            "engine": "jit", "layout": layout, "n_shards": n_shards,
            "fastpath": fastpath, "telemetry": telemetry,
            "magazines": magazines,
            "n_requests": len(trace), "max_batch": MAX_BATCH,
            "num_pages": NUM_PAGES, "chunk": CHUNK,
        },
        metrics={k: v for k, v in metrics.items() if v is not None},
    )
    rec["trajectory"] = trajectory
    if telemetry and snapshot_path:
        with open(snapshot_path, "w") as f:
            json.dump(eng.snapshot(), f, indent=2, sort_keys=True)
            f.write("\n")
    tag = (f"jit-{layout}-S{n_shards}" + ("-fp" if fastpath else "")
           + ("-mag" if magazines else "")
           + ("-tel" if telemetry else ""))
    row(
        "serve_traffic", tag, MAX_BATCH, toks, wall,
        extra=(
            f"steady={metrics['steady_toks_per_s']};"
            f"p50={q['p50']};p99={q['p99']};"
            f"queued_full={eng.stats['queued_full']};"
            f"overflow={eng.stats['overflow_retired']};"
            f"fp_hits={tot['fastpath_hits']};"
            f"fp_spills={tot['fastpath_spills']}"
            + (f";mag_hits={tot['magazine_hits']}" if magazines else "")
        ),
    )
    return rec


def run_host(cfg, params, trace, prompts, n_shards) -> dict:
    def attempt():
        eng = ServeEngine(
            cfg, params, num_pages=NUM_PAGES, page_tokens=PAGE_TOKENS,
            max_batch=MAX_BATCH, dtype=jnp.float32, n_shards=n_shards,
            # cap the host engine's block tables to the longest
            # admissible sequence (same bound the jit engine's
            # max_lane_pages imposes) so its attention gather isn't
            # penalized by pool capacity
            max_table_pages=MAX_LANE_PAGES,
        )
        pending = deque(trace)
        done_clock = {}
        clock = 0
        trajectory = []
        t0 = time.perf_counter()
        while True:
            while pending and pending[0].arrival_step <= clock:
                t = pending.popleft()
                eng.submit(
                    Request(t.req_id, prompts[t.req_id], t.max_new)
                )
            before = set(eng.completed)
            eng.step()
            clock += 1  # host clock ticks every pass, decode or idle
            for rid in eng.completed.keys() - before:
                done_clock[rid] = clock
            if clock % CHUNK == 0:
                trajectory.append({
                    "step": clock,
                    "t": time.perf_counter() - t0,
                    "completed": len(eng.completed),
                    "tokens_done": sum(
                        len(r.out_tokens)
                        for r in eng.completed.values()
                    ),
                    "active_lanes": len(eng.running),
                    "free_pages": eng.kv.free_pages(),
                })
            if not pending and not eng.waiting and not eng.running:
                break
        return eng, done_clock, clock, trajectory, (
            time.perf_counter() - t0
        )

    arrival = {t.req_id: t.arrival_step for t in trace}
    # best-of-REPS, same policy as the jit rows
    eng, done_clock, clock, trajectory, wall = (
        None, None, None, None, None
    )
    for _ in range(REPS):
        e, dc, c, tr, w = attempt()
        s = steady_toks_per_s(tr, len(trace))
        if eng is None or s > steady_toks_per_s(trajectory, len(trace)):
            eng, done_clock, clock, trajectory, wall = e, dc, c, tr, w
    toks = sum(len(r.out_tokens) for r in eng.completed.values())
    lat = [
        done_clock[t.req_id] - arrival[t.req_id]
        for t in trace
        if t.req_id in done_clock
    ]
    q = quantiles_steps(lat)
    step_s = wall / max(clock, 1)
    metrics = {
        "wall_s": wall,
        "decode_steps": clock,
        "tokens_out": toks,
        "toks_per_s": toks / max(wall, 1e-9),
        "steady_toks_per_s": steady_toks_per_s(trajectory, len(trace)),
        "p50_latency_steps": q["p50"],
        "p99_latency_steps": q["p99"],
        "p50_latency_s": None if q["p50"] is None else q["p50"] * step_s,
        "p99_latency_s": None if q["p99"] is None else q["p99"] * step_s,
        "admitted": eng.stats["admitted"],
        "queued_full": eng.stats["queued_full"],
        "rejected": eng.stats["rejected"],
        "overflow_retired": 0,
        "fastpath_hits": eng.kv.fastpath_hits,
        "fastpath_spills": eng.kv.fastpath_spills,
        "free_pages": eng.kv.free_pages(),
    }
    rec = bench_record(
        dims={
            "engine": "host", "layout": "unpacked",
            "n_shards": n_shards, "fastpath": False, "telemetry": False,
            "magazines": 0,
            "n_requests": len(trace), "max_batch": MAX_BATCH,
            "num_pages": NUM_PAGES, "chunk": 1,
        },
        metrics={k: v for k, v in metrics.items() if v is not None},
    )
    rec["trajectory"] = trajectory
    row(
        "serve_traffic", f"host-S{n_shards}", MAX_BATCH, toks, wall,
        extra=f"steady={metrics['steady_toks_per_s']};"
              f"p50={q['p50']};p99={q['p99']};"
              f"queued_full={eng.stats['queued_full']}",
    )
    return rec


def _run_single(spec: str, out_path: str) -> None:
    """Worker mode: one engine run in a fresh process (each full-scale
    run compiles sizeable executables; process isolation keeps every
    configuration's compile + pool memory independent)."""
    engine, layout, n_shards, fastpath, telemetry, magazines = (
        spec.split(":")
    )
    cfg = get_config("stablelm-3b").reduced()
    params = init_params(cfg, jax.random.PRNGKey(0))
    trace = _trace()
    prompts = _prompts(trace, cfg.vocab_size)
    if engine == "jit":
        rec = run_jit(
            cfg, params, trace, prompts, int(n_shards), layout,
            fastpath=fastpath == "1", telemetry=telemetry == "1",
            magazines=int(magazines),
            snapshot_path=out_path + ".snap",
        )
    else:
        rec = run_host(cfg, params, trace, prompts, int(n_shards))
    with open(out_path, "w") as f:
        json.dump(rec, f)


def run() -> None:
    specs = []
    for n_shards in SHARDS:
        for layout in LAYOUTS:
            specs.append(f"jit:{layout}:{n_shards}:0:0:0")
        # the slab front end rides the first layout (page churn is
        # layout-agnostic: the slab words sit outside the tree words)
        specs.append(f"jit:{LAYOUTS[0]}:{n_shards}:1:0:0")
        # the magazine layer likewise rides the first layout: retired
        # pages recycle lane-locally instead of climbing the tree
        specs.append(f"jit:{LAYOUTS[0]}:{n_shards}:0:0:4")
        specs.append(f"host:unpacked:{n_shards}:0:0:0")
    # the telemetry twin: the first configuration at the largest shard
    # count, re-run with the event ring + full metrics plane enabled
    specs.append(f"jit:{LAYOUTS[0]}:{SHARDS[-1]}:0:1:0")

    records = []
    snapshot = None
    with tempfile.TemporaryDirectory() as td:
        for i, spec in enumerate(specs):
            out = os.path.join(td, f"rec{i}.json")
            subprocess.run(
                [sys.executable, os.path.abspath(__file__),
                 "--single", spec, out],
                check=True, env=os.environ.copy(),
            )
            with open(out) as f:
                records.append(json.load(f))
            if os.path.exists(out + ".snap"):
                with open(out + ".snap") as f:
                    snapshot = json.load(f)

    # the tentpole claim: fused in-graph serving beats the host loop on
    # steady-state decode throughput, same trace, same shard count
    # (steady = 10%..90% completion window on each engine's own clock,
    # so one-time graph compilation and the drain tail are excluded)
    speedups = {}
    for n_shards in SHARDS:
        jit_t = max(
            r["metrics"].get("steady_toks_per_s") or 0.0 for r in records
            if r["dims"]["engine"] == "jit"
            and r["dims"]["n_shards"] == n_shards
            and not r["dims"]["telemetry"]
        )
        host_t = next(
            r["metrics"].get("steady_toks_per_s") or 1e-9 for r in records
            if r["dims"]["engine"] == "host"
            and r["dims"]["n_shards"] == n_shards
        )
        speedups[f"S{n_shards}"] = jit_t / max(host_t, 1e-9)
        print(f"# jit/host steady decode throughput S={n_shards}: "
              f"{speedups[f'S{n_shards}']:.2f}x")

    # the observability claim: the telemetry plane rides the compiled
    # step for (nearly) free — steady throughput off/on stays below 3%
    overhead = None
    tel_on = next((r for r in records if r["dims"]["telemetry"]), None)
    if tel_on is not None:
        d = tel_on["dims"]
        tel_off = next(
            r for r in records
            if r["dims"]["engine"] == "jit"
            and not r["dims"]["telemetry"]
            and r["dims"]["layout"] == d["layout"]
            and r["dims"]["n_shards"] == d["n_shards"]
            and r["dims"]["fastpath"] == d["fastpath"]
            and r["dims"].get("magazines", 0) == d.get("magazines", 0)
        )
        on_t = tel_on["metrics"].get("steady_toks_per_s") or 0.0
        off_t = tel_off["metrics"].get("steady_toks_per_s") or 0.0
        if on_t and off_t:
            overhead = off_t / on_t
            print(f"# telemetry overhead (off/on steady toks/s): "
                  f"{overhead:.4f}x  (off={off_t:.1f} on={on_t:.1f})")

    # the magazine claim: recycling retired pages lane-locally must not
    # cost steady-state decode throughput vs the matching plain jit row
    mag_ratios = {}
    for r in records:
        d = r["dims"]
        if d["engine"] != "jit" or not d.get("magazines"):
            continue
        base = next(
            b for b in records
            if b["dims"]["engine"] == "jit"
            and not b["dims"].get("magazines")
            and not b["dims"]["telemetry"]
            and b["dims"]["layout"] == d["layout"]
            and b["dims"]["n_shards"] == d["n_shards"]
            and b["dims"]["fastpath"] == d["fastpath"]
        )
        mag_t = r["metrics"].get("steady_toks_per_s") or 0.0
        base_t = base["metrics"].get("steady_toks_per_s") or 0.0
        if mag_t and base_t:
            mag_ratios[f"S{d['n_shards']}"] = mag_t / base_t
            print(f"# magazine/base steady decode throughput "
                  f"S={d['n_shards']}: {mag_t / base_t:.3f}x")

    if not FAST:
        assert all(s > 1.0 for s in speedups.values()), speedups
        assert overhead is not None and overhead < 1.03, (
            "telemetry-on steady throughput regressed >=3% vs off",
            overhead,
        )
        assert mag_ratios and all(
            s >= 1.0 for s in mag_ratios.values()
        ), mag_ratios
        dump_bench_json("BENCH_SERVE_TRAFFIC.json", bench_envelope(
            "bench_serve_traffic/heavy_traffic",
            {
                "n_requests": N_REQ,
                "rate_per_step": RATE,
                "num_pages": NUM_PAGES,
                "page_tokens": PAGE_TOKENS,
                "max_batch": MAX_BATCH,
                "max_lane_pages": MAX_LANE_PAGES,
                "max_out": MAX_OUT,
                "prompt_buckets": list(PROMPTS),
                "out_range": list(OUT_RANGE),
                "out_mean": OUT_MEAN,
                "chunk": CHUNK,
                "seed": SEED,
                "ring_capacity": RING_CAP,
                "arch": "stablelm-3b (reduced)",
            },
            records,
            jit_vs_host_speedup=speedups,
            telemetry_overhead=overhead,
            magazine_vs_base=mag_ratios,
        ))
        if snapshot is not None:
            dump_bench_json(SNAPSHOT_FILE, snapshot)


if __name__ == "__main__":
    if len(sys.argv) == 4 and sys.argv[1] == "--single":
        _run_single(sys.argv[2], sys.argv[3])
    else:
        run()
