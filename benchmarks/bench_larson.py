"""Larson benchmark (paper Fig. 10; Larson & Krishnan [23]).

Server-style behaviour: a working set of slots; each operation frees a
random slot and allocates a new random-sized chunk into it.  Throughput
over a fixed time window.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    WIDTHS,
    WavefrontAllocator,
    level_for,
    make_host_allocators,
    row,
)

TOTAL_MEM = 1 << 19
MIN_SIZE = 8
SIZES = [8, 16, 32, 64, 128, 256, 512, 1024]
SLOTS = 256
WINDOW_S = 1.0


def run() -> None:
    units_total = TOTAL_MEM // MIN_SIZE
    rng = np.random.default_rng(0)

    for name, alloc in make_host_allocators(TOTAL_MEM, MIN_SIZE).items():
        slots = [alloc.nb_alloc(int(rng.choice(SIZES))) for _ in range(SLOTS)]
        ops = 0
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < WINDOW_S:
            for _ in range(200):
                i = int(rng.integers(SLOTS))
                if slots[i] is not None:
                    alloc.nb_free(slots[i])
                slots[i] = alloc.nb_alloc(int(rng.choice(SIZES)))
                ops += 2
        dt = time.perf_counter() - t0
        row("larson", name, 1, ops, dt)

    for w in WIDTHS:
        wa = WavefrontAllocator(units_total, w)
        # working set as node batches
        held = []
        for _ in range(SLOTS // w):
            lv = np.asarray(
                [level_for(units_total, int(rng.choice(SIZES)) // MIN_SIZE)
                 for _ in range(w)], np.int32)
            held.append(wa.alloc_batch(lv))
        wa.block()
        ops = 0
        t0 = time.perf_counter()
        while time.perf_counter() - t0 < WINDOW_S:
            for _ in range(20):
                i = int(rng.integers(len(held)))
                wa.free_batch_(held[i])
                lv = np.asarray(
                    [level_for(units_total,
                               int(rng.choice(SIZES)) // MIN_SIZE)
                     for _ in range(w)], np.int32)
                held[i] = wa.alloc_batch(lv)
                ops += 2 * w
        wa.block()
        dt = time.perf_counter() - t0
        row("larson", "nb-wavefront", w, ops, dt)


if __name__ == "__main__":
    run()
