"""Wavefront scaling on the device substrate: arbitration rounds and
merged word-updates vs width, on empty and fragmented trees — the
structural (hardware-independent) scalability evidence that complements
the wall-clock Figs 8-11 analogues.  The width sweep runs under both
tree-state layouts (docs/design.md §3) so the packed layout's climb
economy is visible on the same workloads.

`BENCH_FAST=1` shrinks the geometry (tiny tree, 2 shards, fewer widths
and reps; both layouts still run) for the CI smoke job."""

from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    bench_envelope,
    bench_record,
    dump_bench_json,
    row,
)
from repro.core.concurrent import (
    BUNCH_PACKED,
    TreeConfig,
    UNPACKED,
    free_batch,
    wavefront_alloc,
    wavefront_free,
    wavefront_step,
)
from repro.core.pool import (
    PoolConfig,
    pool_wavefront_alloc,
    pool_wavefront_free,
)

FAST = os.environ.get("BENCH_FAST") == "1"

DEPTH = 8 if FAST else 14  # 16K units full, 256 fast
# Shard sweep geometry: equal total capacity for every S (a pool of S
# trees of depth D-log2(S) holds exactly 2^D units).
SHARD_TOTAL_DEPTH = 8 if FAST else 12
SHARD_COUNTS = (1, 2) if FAST else (1, 2, 4, 8)
WIDTHS = (1, 16) if FAST else (1, 4, 16, 64, 256)
REPS = 2 if FAST else 20
LAYOUTS = (("unpacked", UNPACKED), ("packed", BUNCH_PACKED))


def run() -> None:
    rng = np.random.default_rng(3)

    for lname, layout in LAYOUTS:
        cfg = TreeConfig(depth=DEPTH, max_level=0, layout=layout)
        alloc_name = f"nb-wavefront-{lname}"
        for width in WIDTHS:
            levels = jnp.asarray(
                rng.integers(DEPTH - 6, DEPTH + 1, size=width), jnp.int32
            )
            # compile
            tree, nodes, ok, stats = wavefront_alloc(
                cfg, cfg.empty_tree(), levels, jnp.ones(width, bool)
            )
            jax.block_until_ready(tree)
            t0 = time.perf_counter()
            for _ in range(REPS):
                tree, nodes, ok, stats = wavefront_alloc(
                    cfg, cfg.empty_tree(), levels, jnp.ones(width, bool)
                )
            jax.block_until_ready(tree)
            dt = time.perf_counter() - t0
            row(
                "wavefront_scaling", alloc_name, width, REPS * width, dt,
                extra=(
                    f"rounds={int(stats['rounds'])};"
                    f"merged={int(stats['merged_writes'])};"
                    f"logical={int(stats['logical_rmws'])}"
                ),
            )

        # free-side scaling: merged release pass vs per-free logical RMWs
        for width in WIDTHS:
            levels = jnp.asarray(
                rng.integers(DEPTH - 6, DEPTH + 1, size=width), jnp.int32
            )
            tree, nodes, ok, _ = wavefront_alloc(
                cfg, cfg.empty_tree(), levels, jnp.ones(width, bool)
            )
            # compile once, then time the merged release
            t1, freed, fstats = wavefront_free(cfg, tree, nodes, ok)
            jax.block_until_ready(t1)
            t0 = time.perf_counter()
            for _ in range(REPS):
                t1, freed, fstats = wavefront_free(cfg, tree, nodes, ok)
            jax.block_until_ready(t1)
            dt = time.perf_counter() - t0
            row(
                "wavefront_free_scaling", alloc_name, width, REPS * width,
                dt,
                extra=(
                    f"merged={int(fstats['merged_writes'])};"
                    f"logical={int(fstats['logical_rmws'])};"
                    f"freed={int(freed.sum())}"
                ),
            )

    cfg = TreeConfig(depth=DEPTH, max_level=0)

    # Constant Occupancy workload (paper Fig. 11), release side: a skewed
    # long-lived pool, then dealloc/realloc bursts at constant occupancy
    # through wavefront_step — report free-side merged writes vs the
    # paper's per-free RMW count (Fig. 7 metric, release side).
    for width in (16,) if FAST else (64, 256):
        pool_levels = jnp.asarray(
            np.concatenate([
                rng.integers(DEPTH - 3, DEPTH + 1, size=3 * width // 4),
                rng.integers(DEPTH - 7, DEPTH - 3, size=width - 3 * width // 4),
            ]),
            jnp.int32,
        )
        tree, pool_nodes, pool_ok, _ = wavefront_alloc(
            cfg, cfg.empty_tree(), pool_levels, jnp.ones(width, bool)
        )
        merged_total = logical_total = 0
        ROUNDS = 3 if FAST else 10
        t0 = time.perf_counter()
        for _ in range(ROUNDS):
            # constant occupancy: free the pool burst, re-allocate the
            # same levels in the same mixed step
            tree, pool_nodes, pool_ok, stats = wavefront_step(
                cfg, tree, pool_nodes, pool_ok, pool_levels,
                jnp.ones(width, bool),
            )
            merged_total += int(stats["free_merged_writes"])
            logical_total += int(stats["free_logical_rmws"])
        jax.block_until_ready(tree)
        dt = time.perf_counter() - t0
        row(
            "wavefront_constant_occupancy_free", "nb-wavefront", width,
            2 * ROUNDS * width, dt,
            extra=(
                f"free_merged={merged_total};free_logical={logical_total};"
                f"ratio={merged_total / max(logical_total, 1):.3f}"
            ),
        )
        assert merged_total < logical_total, (
            "merged release pass should beat per-free RMWs", merged_total,
            logical_total,
        )

    # ---- sharded-pool sweep: rounds-to-completion vs shard count ----
    # A saturating mixed-octave burst (demand ~70-90% of capacity, every
    # lane completes) at equal total capacity: S trees of depth
    # D - log2(S).  One tree serializes the burst's nested conflict
    # chains through 10+ arbitration rounds; splitting lanes across
    # shards shortens each shard's chains, so the pool completes in
    # fewer (vmapped, per-round-parallel) rounds.  Per-shard merged vs
    # logical RMW stats extend the Fig. 7 metric to the pool.
    shard_records = []
    # mixed octaves at ~66-72% of total capacity in either geometry
    K = 16 if FAST else 64
    srng = np.random.default_rng(3)
    sizes = 2 ** srng.integers(0, 6 if FAST else 9, size=K)
    for S in SHARD_COUNTS:
        sd = SHARD_TOTAL_DEPTH - (S.bit_length() - 1)
        pcfg = PoolConfig(TreeConfig(depth=sd), S)
        levels = jnp.asarray(sd - np.log2(sizes).astype(int), jnp.int32)
        active = jnp.ones(K, bool)
        # compile
        trees, nodes, shard, ok, stats = pool_wavefront_alloc(
            pcfg, pcfg.empty_trees(), levels, active
        )
        jax.block_until_ready(trees)
        t0 = time.perf_counter()
        for _ in range(REPS):
            trees, nodes, shard, ok, stats = pool_wavefront_alloc(
                pcfg, pcfg.empty_trees(), levels, active
            )
        jax.block_until_ready(trees)
        dt = time.perf_counter() - t0
        # per-shard release stats: one merged free_round per shard
        # (what pool_free_round vmaps), recorded shard-by-shard
        from repro.core.concurrent import free_round as _free_round

        free_ms, free_ls = [], []
        for s in range(S):
            mask = ok & (shard == s)
            _, m_s, l_s, _ = _free_round(pcfg.tree, trees[s], nodes, mask)
            free_ms.append(int(m_s))
            free_ls.append(int(l_s))
        trees, freed, fstats = pool_wavefront_free(
            pcfg, trees, nodes, shard, ok
        )
        rec = bench_record(
            dims={"n_shards": S, "shard_depth": sd, "width": K,
                  "capacity_units": 1 << SHARD_TOTAL_DEPTH},
            metrics={
                "demand_units": int(sizes.sum()),
                "rounds": int(stats["rounds"]),
                "ok": int(ok.sum()),
                "overflows": int(stats["overflows"]),
                "merged_writes": int(stats["merged_writes"]),
                "logical_rmws": int(stats["logical_rmws"]),
                "free_merged_writes": int(fstats["merged_writes"]),
                "free_logical_rmws": int(fstats["logical_rmws"]),
                "free_merged_per_shard": free_ms,
                "free_logical_per_shard": free_ls,
                "seconds_per_burst": dt / REPS,
            },
        )
        shard_records.append(rec)
        m = rec["metrics"]
        row(
            "wavefront_shard_sweep", f"pool-s{S}", K, REPS * K, dt,
            extra=(
                f"rounds={m['rounds']};ok={m['ok']};"
                f"overflows={m['overflows']};"
                f"merged={m['merged_writes']};"
                f"logical={m['logical_rmws']};"
                f"free_merged={m['free_merged_writes']};"
                f"free_logical={m['free_logical_rmws']}"
            ),
        )
    by_s = {r["dims"]["n_shards"]: r["metrics"] for r in shard_records}
    assert all(r["metrics"]["ok"] == K for r in shard_records), (
        "the burst must complete on every pool size", shard_records
    )
    if not FAST:
        assert by_s[4]["rounds"] < by_s[1]["rounds"], (
            "S=4 must complete the saturating burst in fewer rounds than S=1",
            by_s[4]["rounds"], by_s[1]["rounds"],
        )
        dump_bench_json(
            "BENCH_WAVEFRONT_SHARDS.json",
            bench_envelope(
                "bench_wavefront/shard_sweep",
                {"total_depth": SHARD_TOTAL_DEPTH, "width": K,
                 "reps": REPS},
                shard_records,
            ),
        )

    # fragmented-tree behaviour: occupancy ~50% at mixed levels
    tree = cfg.empty_tree()
    FRAG = 64 if FAST else 512
    lv = jnp.asarray(rng.integers(6, DEPTH + 1, size=FRAG), jnp.int32)
    tree, nodes, ok, _ = wavefront_alloc(cfg, tree, lv, jnp.ones(FRAG, bool))
    tree, _ = free_batch(cfg, tree, nodes[::2], jnp.ones(FRAG // 2, bool))
    for width in (16,) if FAST else (16, 64):
        levels = jnp.asarray(
            rng.integers(DEPTH - 4, DEPTH + 1, size=width), jnp.int32
        )
        t1, n1, ok1, stats = wavefront_alloc(
            cfg, tree, levels, jnp.ones(width, bool)
        )
        jax.block_until_ready(t1)
        row(
            "wavefront_fragmented", "nb-wavefront", width, width, 1e-9,
            extra=(
                f"rounds={int(stats['rounds'])};ok={int(ok1.sum())};"
                f"merged={int(stats['merged_writes'])}"
            ),
        )


if __name__ == "__main__":
    run()
