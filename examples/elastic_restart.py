"""Elastic rescale demo: train on a (4,2) mesh, checkpoint, restore onto a
(2,4) mesh and continue — the code path a pod uses after losing (or
gaining) slices.  Runs in a subprocess with 8 forced host devices.

    PYTHONPATH=src python examples/elastic_restart.py
"""

import os
import subprocess
import sys

SCRIPT = r"""
import tempfile, jax, jax.numpy as jnp
from jax.sharding import NamedSharding
from repro.launch.mesh import make_test_mesh, use_mesh
from repro.configs import get_config
from repro.models.sharding import MeshAxes, param_specs
from repro.train.trainer import TrainConfig, init_train_state, make_train_step
from repro.data.pipeline import SyntheticLM
from repro.ckpt.checkpoint import CheckpointManager

cfg = get_config("stablelm-3b").reduced()
tcfg = TrainConfig(remat=True, dtype=jnp.float32)
axes = MeshAxes(dp=("data",), tp="model")
data = SyntheticLM(cfg.vocab_size, 16, 8)

def run_steps(mesh, state, n, start):
    ns = lambda s: NamedSharding(mesh, s)
    state = jax.device_put(state, jax.tree.map(ns, param_specs(axes, state)))
    step = jax.jit(make_train_step(cfg, tcfg, axes), donate_argnums=0)
    with use_mesh(mesh):
        for i in range(start, start + n):
            state, m = step(state, data.batch_at(i))
            print(f"  mesh={tuple(mesh.shape.values())} step {i} "
                  f"loss {float(m['loss']):.4f}")
    return state

state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
print("phase 1: (data=4, model=2) — 256 chips' worth of topology, scaled")
mesh42 = make_test_mesh((4, 2), ("data", "model"))
state = run_steps(mesh42, state, 4, 0)

with tempfile.TemporaryDirectory() as d:
    CheckpointManager(d, async_io=False).save(4, state)
    print("checkpoint saved; simulating topology change (lost a slice)...")
    mesh24 = make_test_mesh((2, 4), ("data", "model"))
    like = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    ns = lambda s: NamedSharding(mesh24, s)
    restored = CheckpointManager(d, async_io=False).restore(
        4, like=like, shardings=jax.tree.map(ns, param_specs(axes, like))
    )
    print("phase 2: restored onto (data=2, model=4), training continues")
    run_steps(mesh24, restored, 4, 4)
print("elastic rescale OK")
"""

env = dict(os.environ)
env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
env["PYTHONPATH"] = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src")
sys.exit(subprocess.run([sys.executable, "-c", SCRIPT], env=env).returncode)
