"""End-to-end serving driver: continuous batching on NBBS-paged KV memory.

    PYTHONPATH=src python examples/serve_paged.py

A burst of variable-length requests hits one shared page pool; the buddy
system handles admission control, page placement (contiguous buddy runs),
and coalescing on completion — while the model decodes all running
sequences together through the paged-attention path.

Two engines run the same burst: the host-loop `ServeEngine` (readable
baseline — numpy tables, one host sync per token) and the jit-resident
`JitServeEngine` (page alloc, paged attention, sampling and retirement
frees fused into one compiled `engine_step`; docs/design.md §8).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine

cfg = get_config("stablelm-3b").reduced()
params = init_params(cfg, jax.random.PRNGKey(0))
engine = ServeEngine(
    cfg, params, num_pages=128, page_tokens=4, max_batch=6, dtype=jnp.float32
)

rng = np.random.default_rng(0)
print(f"pool: {engine.kv.num_pages} pages x {engine.page_tokens} tokens")
for i in range(12):
    plen = int(rng.integers(3, 14))
    engine.submit(Request(
        req_id=i,
        prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
        max_new_tokens=int(rng.integers(3, 9)),
    ))

t0 = time.perf_counter()
step = 0
while engine.waiting or engine.running:
    engine.step()
    step += 1
    if step % 3 == 1:
        f = engine.kv.fragmentation()
        print(f"step {step:3d}: running={len(engine.running)} "
              f"waiting={len(engine.waiting)} done={len(engine.completed)} "
              f"used={f['used_pages']:3d}p largest_run={f['largest_run']:3d}p")
dt = time.perf_counter() - t0

toks = sum(len(r.out_tokens) for r in engine.completed.values())
print(f"\ncompleted {len(engine.completed)} requests, {toks} tokens "
      f"in {dt:.1f}s ({toks/dt:.1f} tok/s on CPU)")
f = engine.kv.fragmentation()
print(f"pool after completion: used={f['used_pages']} "
      f"largest_run={f['largest_run']} (fully coalesced: "
      f"{f['largest_run'] == engine.kv.num_pages})")
for i in sorted(engine.completed)[:3]:
    print(f"  req {i}: generated {engine.completed[i].out_tokens}")

# --- the same burst through the jit-resident engine --------------------
from repro.serve.jit_engine import JitServeEngine  # noqa: E402

jit_engine = JitServeEngine(
    cfg, params, num_pages=128, page_tokens=4, max_batch=6,
    max_lane_pages=8, max_out=16, dtype=jnp.float32,
)
rng = np.random.default_rng(0)  # same seed -> same requests
for i in range(12):
    plen = int(rng.integers(3, 14))
    jit_engine.submit(Request(
        req_id=i,
        prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
        max_new_tokens=int(rng.integers(3, 9)),
    ))

t0 = time.perf_counter()
jit_engine.run_to_completion(chunk=4)  # 4 steps per compiled dispatch
dt = time.perf_counter() - t0
toks = sum(len(r.out_tokens) for r in jit_engine.completed.values())
tot = jit_engine.stat_totals()
print(f"\njit engine: {len(jit_engine.completed)} requests, {toks} tokens "
      f"in {dt:.1f}s ({toks/dt:.1f} tok/s, compile included)")
print(f"  in-graph allocator: {tot['alloc_pages']} pages allocated, "
      f"{tot['freed_pages']} freed, {tot['merged_writes']} merged tree "
      f"writes; pool free={jit_engine.device_free_pages()}/128")
