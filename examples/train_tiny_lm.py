"""Train a tiny LM end-to-end on CPU: full stack (synthetic data pipeline,
AdamW, remat, microbatching, int8 error-feedback gradient compression,
async checkpoints, failure injection + restart).

    PYTHONPATH=src python examples/train_tiny_lm.py

On a real pod the same driver (repro/launch/train.py) runs any assigned
arch at full size with the FSDPxTP shardings proven by the dry-run.
"""

import tempfile

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.optim.adamw import AdamWConfig
from repro.runtime.supervisor import FailureInjector, Supervisor
from repro.train.trainer import TrainConfig, init_train_state, make_train_step

STEPS = 150
cfg = get_config("stablelm-3b").reduced()
tcfg = TrainConfig(
    microbatches=2,
    remat=True,
    dtype=jnp.float32,
    compress_grads=True,  # int8 error-feedback wire simulation
    optimizer=AdamWConfig(peak_lr=3e-3, warmup_steps=10, total_steps=STEPS),
)
data = SyntheticLM(cfg.vocab_size, seq_len=32, global_batch=8, seed=0)
step_jit = jax.jit(make_train_step(cfg, tcfg), donate_argnums=0)
key = jax.random.PRNGKey(0)

with tempfile.TemporaryDirectory() as ckpt_dir:
    sup = Supervisor(
        make_state=lambda: init_train_state(cfg, tcfg, key),
        step_fn=lambda st, i: step_jit(st, data.batch_at(i)),
        ckpt_manager=CheckpointManager(ckpt_dir),
        ckpt_every=25,
        failure_injector=FailureInjector(fail_at_steps=(60,)),  # node loss!
    )
    sup.run(STEPS)
    losses = [h["loss"] for h in sup.history]
    print(f"\nsteps run: {len(sup.history)} (incl. replay after "
          f"{sup.restarts} injected failure)")
    print(f"loss: first10={sum(losses[:10])/10:.3f} "
          f"last10={sum(losses[-10:])/10:.3f}")
    assert sum(losses[-10:]) < sum(losses[:10]), "should have learned"
    print("loss decreased through a failure+restart  [OK]")
