"""Quickstart: the non-blocking buddy system in 60 seconds.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's API (alloc/free with splitting+coalescing), the packed
bunch variant (§III-D), the TPU wavefront adaptation, and the Pallas
kernel — all five implementations agreeing on the same trace.
"""

import jax.numpy as jnp
import numpy as np

from repro.core import BunchBuddy, NBBSRef, TreeConfig, wavefront_alloc
from repro.kernels.nbbs_alloc import wavefront_alloc_pallas

print("== 1. paper-faithful allocator (core/ref.py) ==")
a = NBBSRef(total_memory=1024, min_size=8)
x = a.nb_alloc(512)
y = a.nb_alloc(256)
z = a.nb_alloc(200)  # rounded up to 256
print(f"alloc 512@{x}  256@{y}  200->256@{z}  free={a.free_bytes()}B")
a.nb_free(y)
w = a.nb_alloc(64)
print(f"freed the middle 256; 64B lands inside it @ {w}")
a.nb_free(x), a.nb_free(z), a.nb_free(w)
a.check_invariants()
print(f"all freed -> coalesced: alloc(1024) = {a.nb_alloc(1024)} (full block)")
print(f"RMW instrumentation: {a.stats.cas_attempts} CAS attempts\n")

print("== 2. packed bunches (paper §III-D; 3-level/32-bit = TPU-native) ==")
b = BunchBuddy(1024, 8, bunch_levels=4, word_bits=64)
addrs = [b.nb_alloc(s) for s in (512, 256, 200)]
for ad in addrs:
    b.nb_free(ad)
print(f"same trace, word-RMWs: {b.stats.word_rmws} "
      f"(vs {a.stats.cas_attempts} unpacked)\n")

print("== 3. wavefront: 32 concurrent allocations, one arbitration round ==")
cfg = TreeConfig(depth=10, max_level=0)
levels = jnp.asarray(np.random.default_rng(0).integers(5, 11, 32), jnp.int32)
tree, nodes, ok, stats = wavefront_alloc(
    cfg, cfg.empty_tree(), levels, jnp.ones(32, bool)
)
print(f"committed {int(ok.sum())}/32 in {int(stats['rounds'])} round(s); "
      f"merged word-updates {int(stats['merged_writes'])} vs "
      f"{int(stats['logical_rmws'])} logical RMWs\n")

print("== 4. the same wavefront as a Pallas TPU kernel (interpret mode) ==")
t2, n2, ok2, st2 = wavefront_alloc_pallas(cfg, cfg.empty_tree(), levels)
assert (np.asarray(t2) == np.asarray(tree)).all()
assert (np.asarray(n2) == np.asarray(nodes)).all()
print("kernel output bit-identical to the jnp oracle  [OK]")

print("\n== 5. sharded pool: 4 replicated trees, overflow routing ==")
from repro.core import PoolConfig, pool_wavefront_alloc, pool_wavefront_free

pcfg = PoolConfig(TreeConfig(depth=8, max_level=0), n_shards=4)
trees, pnodes, shard, pok, pstats = pool_wavefront_alloc(
    pcfg, pcfg.empty_trees(), levels - 2, jnp.ones(32, bool)
)
per_shard = np.bincount(np.asarray(shard)[np.asarray(pok)], minlength=4)
print(f"committed {int(pok.sum())}/32 across shards {per_shard.tolist()} "
      f"in {int(pstats['rounds'])} round(s); "
      f"{int(pstats['overflows'])} overflowed their home shard")
trees, freed, _ = pool_wavefront_free(pcfg, trees, pnodes, shard, pok)
assert (np.asarray(trees) == 0).all()
print("burst release: one merged pass per shard, all trees empty  [OK]")

print("\n== 6. packed-bunch device layout (§III-D on the wavefront) ==")
from repro.core import BUNCH_PACKED, wavefront_free

pcfg6 = TreeConfig(depth=10, max_level=0, layout=BUNCH_PACKED)
ptree, pn, pko, pst = wavefront_alloc(
    pcfg6, pcfg6.empty_tree(), levels, jnp.ones(32, bool)
)
assert (np.asarray(pn) == np.asarray(nodes)).all()  # same answers
print(f"identical nodes to the unpacked tree; state "
      f"{pcfg6.n_state_words} uint32 words vs {cfg.n_state_words} int32 "
      f"(~{cfg.n_state_words / pcfg6.n_state_words:.1f}x smaller); "
      f"merged climb writes {int(pst['merged_writes'])} vs "
      f"{int(stats['merged_writes'])}")
ptree, _, _ = wavefront_free(pcfg6, ptree, pn, pko)
assert (np.asarray(ptree) == 0).all()
print("packed release drains to an all-zero packed tree  [OK]")
