"""Unit tests for the NBBS core: ref oracle, packed bunches, baselines,
wavefront, and the single-op jitted API."""

import random

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import FreeListBuddy, SpinlockTreeBuddy
from repro.core.bits import BUSY, OCC, is_free
from repro.core.bunch import BunchBuddy
from repro.core.concurrent import (
    BUNCH_PACKED,
    TreeConfig,
    UNPACKED,
    free_batch,
    free_batch_sequential,
    free_round,
    levels_from_sizes,
    wavefront_alloc,
    wavefront_free,
    wavefront_step,
)
from repro.core.nbbs_jax import init_state, nb_alloc, nb_free, nb_free_batch
from repro.core.ref import NBBSRef

# Both persistent tree-state layouts (docs/design.md §3): every
# layout-agnostic wavefront test runs on each, and the dedicated
# differential class below holds them outcome-identical.
LAYOUTS = pytest.mark.parametrize(
    "layout", [UNPACKED, BUNCH_PACKED], ids=["unpacked", "packed"]
)


class TestRef:
    def test_full_drain_min_size(self):
        a = NBBSRef(1024, 8)
        addrs = [a.nb_alloc(8) for _ in range(128)]
        assert sorted(addrs) == list(range(0, 1024, 8))
        assert a.nb_alloc(8) is None
        for x in addrs:
            a.nb_free(x)
        a.check_invariants()
        assert a.free_bytes() == 1024

    def test_split_and_coalesce(self):
        a = NBBSRef(1024, 8)
        x = a.nb_alloc(512)
        y = a.nb_alloc(256)
        z = a.nb_alloc(256)
        assert {x, y, z} == {0, 512, 768}
        assert a.nb_alloc(8) is None  # full
        a.nb_free(y)
        w = a.nb_alloc(128)
        assert w is not None and 512 <= w < 768
        a.nb_free(x), a.nb_free(z), a.nb_free(w)
        a.check_invariants()
        assert a.nb_alloc(1024) == 0  # fully coalesced again

    def test_non_power_of_two_rounding(self):
        a = NBBSRef(1024, 8)
        assert a.level_for_size(3) == a.level_for_size(8)
        assert a.level_for_size(9) == a.level_for_size(16)
        assert a.level_for_size(1024) == 0

    def test_max_size_cap(self):
        a = NBBSRef(1024, 8, max_size=256)
        assert a.nb_alloc(512) is None
        xs = [a.nb_alloc(256) for _ in range(4)]
        assert all(x is not None for x in xs)

    def test_oversize_fails(self):
        a = NBBSRef(1024, 8)
        assert a.nb_alloc(2048) is None

    def test_scattered_hint(self):
        a = NBBSRef(1024, 8)
        x = a.nb_alloc(8, scattered=True)
        y = a.nb_alloc(8, scattered=True)
        assert x != y

    def test_rmw_instrumentation(self):
        a = NBBSRef(1024, 8)
        a.nb_alloc(8)
        # 1 node CAS + depth climb CASes
        assert a.stats.cas_attempts == 1 + a.depth


class TestBunch:
    @pytest.mark.parametrize("B,w", [(4, 64), (3, 32), (2, 32)])
    def test_trace_equivalence(self, B, w):
        random.seed(B * 100 + w)
        ref = NBBSRef(4096, 8)
        bb = BunchBuddy(4096, 8, bunch_levels=B, word_bits=w)
        live = []
        for _ in range(1500):
            if live and random.random() < 0.45:
                addr, _ = live.pop(random.randrange(len(live)))
                ref.nb_free(addr)
                bb.nb_free(addr)
            else:
                sz = random.choice([8, 8, 16, 32, 64, 128, 1024])
                a1, a2 = ref.nb_alloc(sz), bb.nb_alloc(sz)
                assert a1 == a2
                if a1 is not None:
                    live.append((a1, sz))
        assert ref.free_bytes() == bb.free_bytes()

    def test_rmw_reduction(self):
        """Paper §III-D: one RMW per bunch instead of one per level."""
        ref = NBBSRef(1 << 16, 1)
        bb = BunchBuddy(1 << 16, 1, bunch_levels=4, word_bits=64)
        for _ in range(64):
            ref.nb_alloc(1)
            bb.nb_alloc(1)
        # depth=16: ref pays ~17 RMW per alloc; 4-level bunches ~ depth/4
        assert ref.stats.cas_attempts > 2.5 * bb.stats.word_rmws

    def test_word_capacity_guard(self):
        with pytest.raises(ValueError):
            BunchBuddy(1024, 8, bunch_levels=4, word_bits=32)


class TestBaselines:
    def test_freelist_matches_semantics(self):
        random.seed(7)
        fl = FreeListBuddy(4096, 8)
        live = {}
        for step in range(2000):
            if live and random.random() < 0.45:
                addr = random.choice(list(live))
                fl.nb_free(addr)
                del live[addr]
            else:
                sz = random.choice([8, 16, 64, 512])
                a = fl.nb_alloc(sz)
                if a is not None:
                    blk = 8
                    while blk < sz:
                        blk *= 2
                    for other, oblk in live.items():
                        assert a + blk <= other or other + oblk <= a
                    live[a] = blk
        for addr in list(live):
            fl.nb_free(addr)
        assert fl.free_bytes() == 4096
        assert fl.nb_alloc(4096) == 0

    def test_spinlock_counts_lock_acquisitions(self):
        sl = SpinlockTreeBuddy(1024, 8)
        a = sl.nb_alloc(8)
        sl.nb_free(a)
        assert sl.lock_acquisitions == 2


class TestWavefront:
    @LAYOUTS
    def test_single_round_parallel_alloc(self, layout):
        cfg = TreeConfig(depth=7, max_level=0, layout=layout)
        tree, nodes, ok, stats = wavefront_alloc(
            cfg, cfg.empty_tree(), jnp.full(16, 7, jnp.int32),
            jnp.ones(16, bool),
        )
        assert bool(ok.all())
        assert int(stats["rounds"]) == 1
        assert len(set(np.asarray(nodes).tolist())) == 16
        # merged climb writes far fewer words than per-request RMWs
        assert int(stats["merged_writes"]) < int(stats["logical_rmws"])

    def test_matches_sequential_oracle(self):
        cfg = TreeConfig(depth=7, max_level=0)
        tree, nodes, ok, _ = wavefront_alloc(
            cfg, cfg.empty_tree(), jnp.full(16, 7, jnp.int32),
            jnp.ones(16, bool),
        )
        ref = NBBSRef(128, 1)
        for _ in range(16):
            assert ref.nb_alloc(1) is not None
        assert (np.asarray(tree) == np.array(ref.tree)).all()

    def test_ancestor_conflict_arbitration(self):
        cfg = TreeConfig(depth=7, max_level=0)
        lv = jnp.array([7, 0, 7, 1], jnp.int32)
        _, nodes, ok, stats = wavefront_alloc(
            cfg, cfg.empty_tree(), lv, jnp.ones(4, bool)
        )
        # the root request (level 0) conflicts with everything and must
        # lose to the lower-id unit request, then find no free root
        assert [bool(x) for x in ok] == [True, False, True, True]

    @LAYOUTS
    def test_free_batch_roundtrip(self, layout):
        cfg = TreeConfig(depth=6, max_level=0, layout=layout)
        tree, nodes, ok, _ = wavefront_alloc(
            cfg, cfg.empty_tree(), jnp.full(8, 3, jnp.int32),
            jnp.ones(8, bool),
        )
        tree, _ = free_batch(cfg, tree, nodes, jnp.ones(8, bool))
        assert (np.asarray(tree) == 0).all()

    def test_vectorized_free_matches_sequential_scan(self):
        """The merged release pass must be indistinguishable from the
        faithful per-node FREENODE/UNMARK scan on any quiescent batch."""
        rng = np.random.default_rng(11)
        for depth, max_level in [(5, 0), (7, 0), (6, 2)]:
            cfg = TreeConfig(depth=depth, max_level=max_level)
            tree = cfg.empty_tree()
            live = []
            for _ in range(6):
                K = 8
                lv = jnp.asarray(
                    rng.integers(max_level, depth + 1, size=K), jnp.int32
                )
                tree, nodes, ok, _ = wavefront_alloc(
                    cfg, tree, lv, jnp.ones(K, bool)
                )
                live += [
                    int(n) for n, o in zip(np.asarray(nodes), np.asarray(ok)) if o
                ]
                k = int(rng.integers(0, len(live) + 1))
                if not k:
                    continue
                idx = rng.choice(len(live), size=k, replace=False)
                sel = [live[i] for i in idx]
                live = [n for i, n in enumerate(live) if i not in set(idx.tolist())]
                fn = jnp.asarray(sel, jnp.int32)
                fa = jnp.ones(k, bool)
                t_seq, w_seq = free_batch_sequential(cfg, tree, fn, fa)
                t_vec, merged, logical, freed = free_round(cfg, tree, fn, fa)
                assert (np.asarray(t_seq) == np.asarray(t_vec)).all()
                assert bool(np.asarray(freed).all())
                assert int(merged) <= int(w_seq)
                assert int(logical) <= int(w_seq)
                tree = t_vec

    def test_large_noncontended_free_burst_is_one_pass(self):
        """K=64 frees release in one merged O(depth) pass with fewer word
        writes than the paper's per-free climb count (acceptance: the
        sequential K-step scan is gone from the hot path)."""
        cfg = TreeConfig(depth=10, max_level=0)
        K = 64
        tree, nodes, ok, _ = wavefront_alloc(
            cfg, cfg.empty_tree(), jnp.full(K, 10, jnp.int32), jnp.ones(K, bool)
        )
        assert bool(ok.all())
        tree, freed, stats = wavefront_free(cfg, tree, nodes, jnp.ones(K, bool))
        assert bool(freed.all())
        assert (np.asarray(tree) == 0).all()
        assert int(stats["merged_writes"]) < int(stats["logical_rmws"])

    @LAYOUTS
    def test_double_free_is_dropped(self, layout):
        cfg = TreeConfig(depth=5, max_level=0, layout=layout)
        tree, nodes, ok, _ = wavefront_alloc(
            cfg, cfg.empty_tree(), jnp.asarray([3, 4], jnp.int32),
            jnp.ones(2, bool),
        )
        t1, freed1, _ = wavefront_free(cfg, tree, nodes, jnp.ones(2, bool))
        assert bool(freed1.all())
        # releasing the same handles again must change nothing
        t2, freed2, _ = wavefront_free(cfg, t1, nodes, jnp.ones(2, bool))
        assert not bool(freed2.any())
        assert (np.asarray(t1) == np.asarray(t2)).all()
        # and a batch mixing a stale handle with a live one frees only the
        # live one
        t3, n3, ok3, _ = wavefront_alloc(
            cfg, t1, jnp.asarray([2], jnp.int32), jnp.ones(1, bool)
        )
        mixed = jnp.asarray([int(nodes[0]), int(n3[0])], jnp.int32)
        t4, freed4, _ = wavefront_free(cfg, t3, mixed, jnp.ones(2, bool))
        assert [bool(x) for x in freed4] == [False, True]
        assert (np.asarray(t4) == np.asarray(t1)).all()
        # the same handle twice in ONE burst frees exactly once (min
        # lane id wins, the duplicate is dropped from mask and stats)
        t5, n5, ok5, _ = wavefront_alloc(
            cfg, t4, jnp.asarray([3], jnp.int32), jnp.ones(1, bool)
        )
        dup = jnp.asarray([int(n5[0]), int(n5[0])], jnp.int32)
        t6, freed6, st6 = wavefront_free(cfg, t5, dup, jnp.ones(2, bool))
        assert [bool(x) for x in freed6] == [True, False]
        assert (np.asarray(t6) == np.asarray(t4)).all()

    def test_wavefront_step_differential_vs_ref(self):
        """Interleaved alloc/free bursts through wavefront_step vs the
        paper-faithful NBBSRef replaying the same linearization (same
        frees; committed nodes mirrored through TRYALLOC): identical
        trees — hence identical reachable occupancy per level — and every
        failed request genuinely unsatisfiable on the post-step state."""
        for seed, depth in [(0, 5), (1, 6), (2, 5)]:
            rng = np.random.default_rng(seed)
            K = F = 6
            cfg = TreeConfig(depth=depth, max_level=0)
            total = 1 << depth
            tree = cfg.empty_tree()
            ref = NBBSRef(total, 1)
            live = []
            for _ in range(30):
                k = int(rng.integers(0, min(len(live), F) + 1)) if live else 0
                idx = (
                    sorted(rng.choice(len(live), size=k, replace=False).tolist())
                    if k else []
                )
                fnodes = [live[i] for i in idx]
                live = [n for i, n in enumerate(live) if i not in set(idx)]
                fn = np.zeros(F, np.int32)
                fa = np.zeros(F, bool)
                fn[: len(fnodes)] = fnodes
                fa[: len(fnodes)] = True
                a = int(rng.integers(1, K + 1))
                lv = np.zeros(K, np.int32)
                aa = np.zeros(K, bool)
                lv[:a] = rng.integers(0, depth + 1, size=a)
                aa[:a] = True
                tree, nodes, ok, _ = wavefront_step(
                    cfg, tree, jnp.asarray(fn), jnp.asarray(fa),
                    jnp.asarray(lv), jnp.asarray(aa),
                )
                nodes, ok = np.asarray(nodes), np.asarray(ok)
                for n in fnodes:
                    ref.nb_free(ref.starting_address(n))
                for n, o in zip(nodes[:a], ok[:a]):
                    if o:
                        assert ref._try_alloc(int(n)) == 0
                        addr = ref.starting_address(int(n))
                        ref.index[addr // ref.min_size] = int(n)
                        live.append(int(n))
                assert (np.asarray(tree) == np.array(ref.tree)).all()
                # failed requests must be genuinely unsatisfiable
                import copy
                for L, o in zip(lv[:a], ok[:a]):
                    if not o:
                        probe = copy.deepcopy(ref)
                        assert probe.nb_alloc(total >> int(L)) is None
            ref.check_invariants()

    def test_nb_free_batch_in_graph(self):
        """Batched in-graph release: one call retires a burst of unit
        offsets and matches the sequential reference."""
        cfg = TreeConfig(depth=6, max_level=0)
        st = init_state(cfg)
        ref = NBBSRef(64, 1)
        offs = []
        for lv in [6, 6, 4, 3, 6, 5]:
            st, off, ok = nb_alloc(cfg, st, jnp.int32(lv))
            assert bool(ok)
            a = ref.nb_alloc(64 >> lv)
            assert a == int(off)
            offs.append(int(off))
        burst = offs[::2]
        st, freed = nb_free_batch(
            cfg, st, jnp.asarray(burst, jnp.int32), jnp.ones(len(burst), bool)
        )
        assert bool(freed.all())
        ref.nb_free_many(burst)
        assert (np.asarray(st.tree) == np.array(ref.tree)).all()
        # re-freeing through stale offsets is a no-op
        st2, freed2 = nb_free_batch(
            cfg, st, jnp.asarray(burst, jnp.int32), jnp.ones(len(burst), bool)
        )
        assert not bool(freed2.any())
        assert (np.asarray(st2.tree) == np.asarray(st.tree)).all()

    def test_levels_from_sizes(self):
        cfg = TreeConfig(depth=7, max_level=0)
        lev = levels_from_sizes(cfg, 128, jnp.array([1, 2, 3, 128, 64, 0]))
        assert np.asarray(lev).tolist() == [7, 6, 5, 0, 1, 7]

    @LAYOUTS
    def test_exhaustion_reports_failure(self, layout):
        cfg = TreeConfig(depth=3, max_level=0, layout=layout)
        levels = jnp.full(10, 3, jnp.int32)  # 10 requests, 8 units
        _, nodes, ok, _ = wavefront_alloc(
            cfg, cfg.empty_tree(), levels, jnp.ones(10, bool)
        )
        assert int(ok.sum()) == 8


class TestSingleOpJax:
    def test_equivalence_with_ref(self):
        cfg = TreeConfig(depth=6, max_level=0)
        st = init_state(cfg)
        ref = NBBSRef(64, 1)
        random.seed(1)
        live = []
        for _ in range(200):
            if live and random.random() < 0.5:
                off, _ = live.pop(random.randrange(len(live)))
                st = nb_free(cfg, st, jnp.int32(off))
                ref.nb_free(off)
            else:
                lv = random.choice([6, 6, 5, 4, 3])
                st, off, ok = nb_alloc(cfg, st, jnp.int32(lv))
                a = ref.nb_alloc(64 >> lv)
                if a is None:
                    assert not bool(ok)
                else:
                    assert bool(ok) and int(off) == a
                    live.append((int(off), lv))
            assert (np.asarray(st.tree) == np.array(ref.tree)).all()


class TestTreeLayouts:
    """`BunchPacked` vs the `Unpacked` oracle (docs/design.md §3):
    outcome-identical on valid traces, ~7x smaller persistent state,
    strictly fewer merged climb writes."""

    def test_packed_state_word_budget(self):
        """Bottom-aligned B=3 layering keeps the packed word count at
        ~1/7 of unpacked — and always within the 1/4 budget."""
        for depth in range(3, 15):
            cu = TreeConfig(depth=depth)
            cp = TreeConfig(depth=depth, layout=BUNCH_PACKED)
            assert cp.n_state_words * 4 <= cu.n_state_words
            # and the packed tree still addresses every node
            assert cp.n_words == cu.n_words
        # the asymptotic ratio: 4 leaves/word + higher layers ~ 1/7
        cu, cp = TreeConfig(depth=14), TreeConfig(depth=14, layout=BUNCH_PACKED)
        assert cp.n_state_words / cu.n_state_words < 0.15

    def test_packed_equals_unpacked_on_mixed_traces(self):
        """Replayed mixed alloc/free wavefronts: identical nodes, ok
        masks, and freed masks at every step, and both drain to zero."""
        for seed, depth in [(0, 6), (1, 8), (2, 9)]:
            rng = np.random.default_rng(seed)
            cu = TreeConfig(depth=depth, max_level=0)
            cp = TreeConfig(depth=depth, max_level=0, layout=BUNCH_PACKED)
            tu, tp = cu.empty_tree(), cp.empty_tree()
            live = []
            for _ in range(12):
                K = 8
                lv = jnp.asarray(
                    rng.integers(1, depth + 1, size=K), jnp.int32
                )
                act = jnp.asarray(rng.random(K) < 0.8)
                tu, nu, oku, _ = wavefront_alloc(cu, tu, lv, act)
                tp, np_, okp, _ = wavefront_alloc(cp, tp, lv, act)
                assert (np.asarray(nu) == np.asarray(np_)).all()
                assert (np.asarray(oku) == np.asarray(okp)).all()
                live += [
                    int(n)
                    for n, o in zip(np.asarray(nu), np.asarray(oku))
                    if o
                ]
                k = int(rng.integers(0, len(live) + 1))
                if not k:
                    continue
                idx = rng.choice(len(live), size=k, replace=False)
                sel = [live[i] for i in idx]
                live = [
                    n for i, n in enumerate(live)
                    if i not in set(idx.tolist())
                ]
                fn = jnp.asarray(sel, jnp.int32)
                fa = jnp.ones(k, bool)
                tu, fu, _ = wavefront_free(cu, tu, fn, fa)
                tp, fp, _ = wavefront_free(cp, tp, fn, fa)
                assert (np.asarray(fu) == np.asarray(fp)).all()
            if live:
                fn = jnp.asarray(live, jnp.int32)
                fa = jnp.ones(len(live), bool)
                tu, _, _ = wavefront_free(cu, tu, fn, fa)
                tp, _, _ = wavefront_free(cp, tp, fn, fa)
            assert (np.asarray(tu) == 0).all()
            assert (np.asarray(tp) == 0).all()

    def test_packed_single_op_matches_ref_addresses(self):
        """The in-graph single-op API over the packed layout replays the
        sequential specification's addresses (nbbs_jax with
        layout=BUNCH_PACKED vs NBBSRef)."""
        cfg = TreeConfig(depth=6, max_level=0, layout=BUNCH_PACKED)
        st = init_state(cfg)
        ref = NBBSRef(64, 1)
        random.seed(3)
        live = []
        for _ in range(150):
            if live and random.random() < 0.5:
                off = live.pop(random.randrange(len(live)))
                st = nb_free(cfg, st, jnp.int32(off))
                ref.nb_free(off)
            else:
                lv = random.choice([6, 6, 5, 4, 2])
                st, off, ok = nb_alloc(cfg, st, jnp.int32(lv))
                a = ref.nb_alloc(64 >> lv)
                if a is None:
                    assert not bool(ok)
                else:
                    assert bool(ok) and int(off) == a
                    live.append(int(off))
        for off in live:
            ref.nb_free(off)
        st, freed = nb_free_batch(
            cfg, st, jnp.asarray(live or [0], jnp.int32),
            jnp.asarray([bool(live)] * max(len(live), 1)),
        )
        assert (np.asarray(st.tree) == 0).all()
        assert ref.free_bytes() == 64

    def test_packed_merged_climb_writes_below_unpacked(self):
        """The §III-D payoff: the same burst costs strictly fewer packed
        word updates than unpacked word updates, alloc and free side."""
        rng = np.random.default_rng(5)
        depth, K = 10, 64
        cu = TreeConfig(depth=depth, max_level=0)
        cp = TreeConfig(depth=depth, max_level=0, layout=BUNCH_PACKED)
        lv = jnp.asarray(rng.integers(4, depth + 1, size=K), jnp.int32)
        tu, nu, oku, su = wavefront_alloc(
            cu, cu.empty_tree(), lv, jnp.ones(K, bool)
        )
        tp, np_, okp, sp = wavefront_alloc(
            cp, cp.empty_tree(), lv, jnp.ones(K, bool)
        )
        assert int(sp["merged_writes"]) < int(su["merged_writes"])
        # identical logical baseline semantics: packed logical counts
        # per-bunch RMWs, so it is smaller too (the paper's ~B x claim)
        assert int(sp["logical_rmws"]) < int(su["logical_rmws"])
        tu, fu, fsu = wavefront_free(cu, tu, nu, oku)
        tp, fp, fsp = wavefront_free(cp, tp, np_, okp)
        assert int(fsp["merged_writes"]) < int(fsu["merged_writes"])


class TestJunkHandles:
    @LAYOUTS
    def test_out_of_range_handle_is_dropped(self, layout):
        """A node id >= n_words is a junk handle and must be dropped,
        never aliased to the clamped last leaf."""
        cfg = TreeConfig(depth=3, max_level=0, layout=layout)
        K = 8
        tree, nodes, ok, _ = wavefront_alloc(
            cfg, cfg.empty_tree(), jnp.full(K, 3, jnp.int32),
            jnp.ones(K, bool),
        )
        assert bool(ok.all())
        junk = jnp.asarray([cfg.n_words + 984, cfg.n_words - 1 + 16,
                            -5], jnp.int32)
        t2, freed, _ = wavefront_free(cfg, tree, junk, jnp.ones(3, bool))
        assert not bool(freed.any())
        assert (np.asarray(t2) == np.asarray(tree)).all()

    def test_sequential_scan_rejects_packed_layout(self):
        """The faithful per-word scan replays unpacked bit ops and must
        refuse packed state instead of corrupting it."""
        cfg = TreeConfig(depth=6, max_level=0, layout=BUNCH_PACKED)
        with pytest.raises(ValueError):
            free_batch_sequential(
                cfg, cfg.empty_tree(), jnp.asarray([64], jnp.int32),
                jnp.ones(1, bool),
            )
