"""Unit tests for the NBBS core: ref oracle, packed bunches, baselines,
wavefront, and the single-op jitted API."""

import random

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import FreeListBuddy, SpinlockTreeBuddy
from repro.core.bits import BUSY, OCC, is_free
from repro.core.bunch import BunchBuddy
from repro.core.concurrent import (
    TreeConfig,
    free_batch,
    levels_from_sizes,
    wavefront_alloc,
)
from repro.core.nbbs_jax import init_state, nb_alloc, nb_free
from repro.core.ref import NBBSRef


class TestRef:
    def test_full_drain_min_size(self):
        a = NBBSRef(1024, 8)
        addrs = [a.nb_alloc(8) for _ in range(128)]
        assert sorted(addrs) == list(range(0, 1024, 8))
        assert a.nb_alloc(8) is None
        for x in addrs:
            a.nb_free(x)
        a.check_invariants()
        assert a.free_bytes() == 1024

    def test_split_and_coalesce(self):
        a = NBBSRef(1024, 8)
        x = a.nb_alloc(512)
        y = a.nb_alloc(256)
        z = a.nb_alloc(256)
        assert {x, y, z} == {0, 512, 768}
        assert a.nb_alloc(8) is None  # full
        a.nb_free(y)
        w = a.nb_alloc(128)
        assert w is not None and 512 <= w < 768
        a.nb_free(x), a.nb_free(z), a.nb_free(w)
        a.check_invariants()
        assert a.nb_alloc(1024) == 0  # fully coalesced again

    def test_non_power_of_two_rounding(self):
        a = NBBSRef(1024, 8)
        assert a.level_for_size(3) == a.level_for_size(8)
        assert a.level_for_size(9) == a.level_for_size(16)
        assert a.level_for_size(1024) == 0

    def test_max_size_cap(self):
        a = NBBSRef(1024, 8, max_size=256)
        assert a.nb_alloc(512) is None
        xs = [a.nb_alloc(256) for _ in range(4)]
        assert all(x is not None for x in xs)

    def test_oversize_fails(self):
        a = NBBSRef(1024, 8)
        assert a.nb_alloc(2048) is None

    def test_scattered_hint(self):
        a = NBBSRef(1024, 8)
        x = a.nb_alloc(8, scattered=True)
        y = a.nb_alloc(8, scattered=True)
        assert x != y

    def test_rmw_instrumentation(self):
        a = NBBSRef(1024, 8)
        a.nb_alloc(8)
        # 1 node CAS + depth climb CASes
        assert a.stats.cas_attempts == 1 + a.depth


class TestBunch:
    @pytest.mark.parametrize("B,w", [(4, 64), (3, 32), (2, 32)])
    def test_trace_equivalence(self, B, w):
        random.seed(B * 100 + w)
        ref = NBBSRef(4096, 8)
        bb = BunchBuddy(4096, 8, bunch_levels=B, word_bits=w)
        live = []
        for _ in range(1500):
            if live and random.random() < 0.45:
                addr, _ = live.pop(random.randrange(len(live)))
                ref.nb_free(addr)
                bb.nb_free(addr)
            else:
                sz = random.choice([8, 8, 16, 32, 64, 128, 1024])
                a1, a2 = ref.nb_alloc(sz), bb.nb_alloc(sz)
                assert a1 == a2
                if a1 is not None:
                    live.append((a1, sz))
        assert ref.free_bytes() == bb.free_bytes()

    def test_rmw_reduction(self):
        """Paper §III-D: one RMW per bunch instead of one per level."""
        ref = NBBSRef(1 << 16, 1)
        bb = BunchBuddy(1 << 16, 1, bunch_levels=4, word_bits=64)
        for _ in range(64):
            ref.nb_alloc(1)
            bb.nb_alloc(1)
        # depth=16: ref pays ~17 RMW per alloc; 4-level bunches ~ depth/4
        assert ref.stats.cas_attempts > 2.5 * bb.stats.word_rmws

    def test_word_capacity_guard(self):
        with pytest.raises(ValueError):
            BunchBuddy(1024, 8, bunch_levels=4, word_bits=32)


class TestBaselines:
    def test_freelist_matches_semantics(self):
        random.seed(7)
        fl = FreeListBuddy(4096, 8)
        live = {}
        for step in range(2000):
            if live and random.random() < 0.45:
                addr = random.choice(list(live))
                fl.nb_free(addr)
                del live[addr]
            else:
                sz = random.choice([8, 16, 64, 512])
                a = fl.nb_alloc(sz)
                if a is not None:
                    blk = 8
                    while blk < sz:
                        blk *= 2
                    for other, oblk in live.items():
                        assert a + blk <= other or other + oblk <= a
                    live[a] = blk
        for addr in list(live):
            fl.nb_free(addr)
        assert fl.free_bytes() == 4096
        assert fl.nb_alloc(4096) == 0

    def test_spinlock_counts_lock_acquisitions(self):
        sl = SpinlockTreeBuddy(1024, 8)
        a = sl.nb_alloc(8)
        sl.nb_free(a)
        assert sl.lock_acquisitions == 2


class TestWavefront:
    def test_single_round_parallel_alloc(self):
        cfg = TreeConfig(depth=7, max_level=0)
        tree, nodes, ok, stats = wavefront_alloc(
            cfg, cfg.empty_tree(), jnp.full(16, 7, jnp.int32),
            jnp.ones(16, bool),
        )
        assert bool(ok.all())
        assert int(stats["rounds"]) == 1
        assert len(set(np.asarray(nodes).tolist())) == 16
        # merged climb writes far fewer words than per-request RMWs
        assert int(stats["merged_writes"]) < int(stats["logical_rmws"])

    def test_matches_sequential_oracle(self):
        cfg = TreeConfig(depth=7, max_level=0)
        tree, nodes, ok, _ = wavefront_alloc(
            cfg, cfg.empty_tree(), jnp.full(16, 7, jnp.int32),
            jnp.ones(16, bool),
        )
        ref = NBBSRef(128, 1)
        for _ in range(16):
            assert ref.nb_alloc(1) is not None
        assert (np.asarray(tree) == np.array(ref.tree)).all()

    def test_ancestor_conflict_arbitration(self):
        cfg = TreeConfig(depth=7, max_level=0)
        lv = jnp.array([7, 0, 7, 1], jnp.int32)
        _, nodes, ok, stats = wavefront_alloc(
            cfg, cfg.empty_tree(), lv, jnp.ones(4, bool)
        )
        # the root request (level 0) conflicts with everything and must
        # lose to the lower-id unit request, then find no free root
        assert [bool(x) for x in ok] == [True, False, True, True]

    def test_free_batch_roundtrip(self):
        cfg = TreeConfig(depth=6, max_level=0)
        tree, nodes, ok, _ = wavefront_alloc(
            cfg, cfg.empty_tree(), jnp.full(8, 3, jnp.int32),
            jnp.ones(8, bool),
        )
        tree, _ = free_batch(cfg, tree, nodes, jnp.ones(8, bool))
        assert (np.asarray(tree) == 0).all()

    def test_levels_from_sizes(self):
        cfg = TreeConfig(depth=7, max_level=0)
        lev = levels_from_sizes(cfg, 128, jnp.array([1, 2, 3, 128, 64, 0]))
        assert np.asarray(lev).tolist() == [7, 6, 5, 0, 1, 7]

    def test_exhaustion_reports_failure(self):
        cfg = TreeConfig(depth=3, max_level=0)
        levels = jnp.full(10, 3, jnp.int32)  # 10 requests, 8 units
        _, nodes, ok, _ = wavefront_alloc(
            cfg, cfg.empty_tree(), levels, jnp.ones(10, bool)
        )
        assert int(ok.sum()) == 8


class TestSingleOpJax:
    def test_equivalence_with_ref(self):
        cfg = TreeConfig(depth=6, max_level=0)
        st = init_state(cfg)
        ref = NBBSRef(64, 1)
        random.seed(1)
        live = []
        for _ in range(200):
            if live and random.random() < 0.5:
                off, _ = live.pop(random.randrange(len(live)))
                st = nb_free(cfg, st, jnp.int32(off))
                ref.nb_free(off)
            else:
                lv = random.choice([6, 6, 5, 4, 3])
                st, off, ok = nb_alloc(cfg, st, jnp.int32(lv))
                a = ref.nb_alloc(64 >> lv)
                if a is None:
                    assert not bool(ok)
                else:
                    assert bool(ok) and int(off) == a
                    live.append((int(off), lv))
            assert (np.asarray(st.tree) == np.array(ref.tree)).all()
