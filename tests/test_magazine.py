"""Magazine battery: per-lane page caches over the sharded pool
(core/magazine.py + the fused paths in core/pool.py).

Differential contract: a magazines-on pool must be capacity- and
failure-equivalent to a magazines-off pool on everything a caller can
observe — per-lane success/failure on capacity-sufficient traces,
winner *count* under exhaustion (the exhaustion spill-back may reshuffle
which lanes win, a documented benign divergence, docs/design.md §10),
total pages outstanding, and drain-to-empty — while serving recycled
pages through a pop that costs zero shared-state RMWs.

Runs as its own CI matrix cell (`-m magazine`).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import magazine as magmod
from repro.core.concurrent import BUNCH_PACKED, TreeConfig, UNPACKED
from repro.core.fastpath import FastPathConfig
from repro.core.magazine import MagazineConfig, init_magazines, mag_total
from repro.core.pool import (
    PoolConfig,
    pool_free_units,
    pool_init_magazines,
    pool_mag_free_per_shard,
    pool_magazine_drain,
    pool_magazine_refill,
    pool_wavefront_alloc,
    pool_wavefront_alloc_mag,
    pool_wavefront_free_mag,
)

pytestmark = pytest.mark.magazine

LAYOUTS = [("unpacked", UNPACKED), ("bunch-packed", BUNCH_PACKED)]
SHARDS = [1, 4]
FASTPATHS = [False, True]
GRID = [
    pytest.param(name, layout, S, fp, id=f"{name}-S{S}-fp{int(fp)}")
    for name, layout in LAYOUTS
    for S in SHARDS
    for fp in FASTPATHS
]


def _pair(depth, S, layout, fastpath, mag_cap=4, refill=0):
    """(magazines-on pool, magazines-off pool), identical geometry."""
    tree = TreeConfig(depth=depth, layout=layout)
    fp = FastPathConfig(level=None, slab_level=1) if fastpath else None
    on = PoolConfig(
        tree, S, fastpath=fp,
        magazines=MagazineConfig(mag_cap=mag_cap, refill_batch=refill),
    )
    off = PoolConfig(tree, S, fastpath=fp)
    return on, off


def _leaf_alloc_mag(pcfg, trees, mags, active, lane_ids, mag_lane):
    K = len(active)
    levels = jnp.full((K,), pcfg.tree.depth, jnp.int32)
    return pool_wavefront_alloc_mag(
        pcfg, trees, mags, levels,
        jnp.asarray(active, bool), 64,
        jnp.asarray(lane_ids, jnp.int32),
        jnp.asarray(mag_lane, jnp.int32),
    )


def _leaf_alloc(pcfg, trees, active, lane_ids):
    K = len(active)
    levels = jnp.full((K,), pcfg.tree.depth, jnp.int32)
    return pool_wavefront_alloc(
        pcfg, trees, levels, jnp.asarray(active, bool), 64,
        jnp.asarray(lane_ids, jnp.int32),
    )


class TestMagazineConfig:
    def test_validation(self):
        with pytest.raises(ValueError):
            MagazineConfig(mag_cap=0).validate()
        with pytest.raises(ValueError):
            MagazineConfig(mag_cap=4, refill_batch=-1).validate()
        with pytest.raises(ValueError):
            PoolConfig(
                TreeConfig(depth=3), 1,
                magazines=MagazineConfig(mag_cap=-2),
            )
        # well-formed config threads through PoolConfig
        pcfg = PoolConfig(
            TreeConfig(depth=3), 2, magazines=MagazineConfig(mag_cap=4)
        )
        assert pcfg.magazines.mag_cap == 4

    def test_init_shapes(self):
        mcfg = MagazineConfig(mag_cap=3)
        mags = init_magazines(mcfg, 5)
        assert mags.pages.shape == (5, 3)
        assert mags.depth.shape == (5,)
        assert int(mag_total(mags)) == 0
        assert bool((mags.pages == -1).all())


class TestClaimStashUnits:
    """Pure MagazineState semantics, no pool attached."""

    def test_lifo_order_and_rank(self):
        mcfg = MagazineConfig(mag_cap=4)
        mags = init_magazines(mcfg, 2)
        # two lanes stash two pages each, in lane order
        pages = jnp.asarray([10, 11, 20, 21], jnp.int32)
        want = jnp.ones(4, bool)
        lane = jnp.asarray([0, 0, 1, 1], jnp.int32)
        mags, stashed = magmod.mag_stash(mcfg, mags, pages, want, lane)
        assert bool(stashed.all())
        assert mags.depth.tolist() == [2, 2]
        assert mags.pages[0, :2].tolist() == [10, 11]
        # pop order is LIFO top-down in lane order: lane 0 twice pops
        # 11 then 10; lane 1 pops 21
        mags, got_pages, got, hits = magmod.mag_claim(
            mcfg, mags, jnp.ones(3, bool),
            jnp.asarray([0, 0, 1], jnp.int32),
        )
        assert int(hits) == 3
        assert got_pages.tolist() == [11, 10, 21]
        assert mags.depth.tolist() == [0, 1]
        assert int(mags.pages[1, 0]) == 20

    def test_stash_drop_through_when_full(self):
        mcfg = MagazineConfig(mag_cap=2)
        mags = init_magazines(mcfg, 1)
        pages = jnp.asarray([1, 2, 3], jnp.int32)
        mags, stashed = magmod.mag_stash(
            mcfg, mags, pages, jnp.ones(3, bool), jnp.zeros(3, jnp.int32)
        )
        assert stashed.tolist() == [True, True, False]
        assert int(mags.depth[0]) == 2

    def test_claim_underflow_misses(self):
        mcfg = MagazineConfig(mag_cap=4)
        mags = init_magazines(mcfg, 1)
        mags, _ = magmod.mag_stash(
            mcfg, mags, jnp.asarray([7], jnp.int32),
            jnp.ones(1, bool), jnp.zeros(1, jnp.int32),
        )
        mags, pages, got, hits = magmod.mag_claim(
            mcfg, mags, jnp.ones(3, bool), jnp.zeros(3, jnp.int32)
        )
        assert got.tolist() == [True, False, False]
        assert int(hits) == 1

    def test_group_rank(self):
        keys = jnp.asarray([2, 0, 2, 1, 2], jnp.int32)
        cand = jnp.asarray([1, 1, 1, 0, 1], bool)
        rank = magmod.group_rank(keys, cand, 3)
        # within each group, candidates rank in lane order; non-cands 0
        assert rank.tolist() == [0, 0, 1, 0, 2]

    def test_precomputed_rank_matches_group_rank(self):
        # the jit-engine fast paths: a caller whose structure makes the
        # rank trivial may pass it and skip the stable sort — results
        # must be bit-identical to the group_rank path
        mcfg = MagazineConfig(mag_cap=4)
        B, MP = 8, 4
        mags = init_magazines(mcfg, B)
        lane = jnp.repeat(jnp.arange(B, dtype=jnp.int32), MP)
        pages = jnp.arange(B * MP, dtype=jnp.int32)
        cand = (jnp.arange(B * MP) % MP) < 2  # prefix-wise rows
        rank = jnp.tile(jnp.arange(MP, dtype=jnp.int32), B)
        m1, s1 = magmod.mag_stash(mcfg, mags, pages, cand, lane)
        m2, s2 = magmod.mag_stash(
            mcfg, mags, pages, cand, lane, rank=rank
        )
        assert (s1 == s2).all()
        assert (m1.pages == m2.pages).all()
        assert (m1.depth == m2.depth).all()
        # distinct mag_lane per claimant => rank identically zero
        want = jnp.ones(B, bool)
        ml = jnp.arange(B, dtype=jnp.int32)
        a1 = magmod.mag_claim(mcfg, m1, want, ml)
        a2 = magmod.mag_claim(
            mcfg, m1, want, ml, rank=jnp.zeros(B, jnp.int32)
        )
        for x, y in zip(
            jax.tree_util.tree_leaves(a1), jax.tree_util.tree_leaves(a2)
        ):
            assert (np.asarray(x) == np.asarray(y)).all()

    def test_assume_owned_free_matches_generic(self):
        # assume_owned skips the ownership/dedup guards; on a burst
        # that actually satisfies the contract (distinct owned leaves)
        # the release must be bit-identical, fast paths and all
        mcfg = MagazineConfig(mag_cap=4)
        pcfg = PoolConfig(
            tree=TreeConfig(depth=4), n_shards=2, magazines=mcfg
        )
        trees = pcfg.empty_trees()
        L, K = 4, 8
        mags = pool_init_magazines(pcfg, L)
        levels = jnp.full(K, 4, jnp.int32)
        active = jnp.ones(K, bool)
        mag_lane = jnp.arange(K, dtype=jnp.int32) % L
        trees, mags, nodes, shard, ok, _ = pool_wavefront_alloc_mag(
            pcfg, trees, mags, levels, active, 64, None, mag_lane
        )
        assert bool(ok.all())
        rank = magmod.group_rank(mag_lane, active, L)
        o1 = pool_wavefront_free_mag(
            pcfg, trees, mags, nodes, shard, active, mag_lane
        )
        o2 = pool_wavefront_free_mag(
            pcfg, trees, mags, nodes, shard, active, mag_lane,
            rank, True,
        )
        for x, y in zip(
            jax.tree_util.tree_leaves(o1), jax.tree_util.tree_leaves(o2)
        ):
            assert (np.asarray(x) == np.asarray(y)).all()


class TestMagazineDifferential:
    """Magazines-on vs magazines-off pools on shared traces."""

    @pytest.mark.parametrize("name,layout,S,fp", GRID)
    def test_churn_recycles_with_zero_rmws(self, name, layout, S, fp):
        """alloc -> stash-free -> realloc: the second wave is served
        entirely by magazine pops (zero logical RMWs), conservation
        holds throughout, and draining restores the off baseline."""
        depth = 5
        on, off = _pair(depth, S, layout, fp)
        total = int(pool_free_units(off, off.empty_trees()).sum())
        K = 8
        lanes = list(range(K))
        mag_lane = [i // 2 for i in range(K)]  # 2 pages per magazine

        mags = pool_init_magazines(on, K // 2)
        trees = on.empty_trees()
        trees, mags, nodes, shard, ok, st = _leaf_alloc_mag(
            on, trees, mags, [True] * K, lanes, mag_lane
        )
        assert bool(ok.all())
        assert int(st["magazine_hits"]) == 0  # nothing stashed yet

        trees, mags, freed, fst = pool_wavefront_free_mag(
            on, trees, mags, nodes, shard, ok,
            jnp.asarray(mag_lane, jnp.int32),
        )
        assert bool(freed.all())
        assert int(mag_total(mags)) == K  # all parked, none spilled
        assert int(fst["magazine_spills"]) == 0
        # conservation: stashed pages count as free capacity
        assert (
            int(pool_free_units(on, trees).sum()) + int(mag_total(mags))
            == total
        )
        assert (
            pool_mag_free_per_shard(on, mags).sum() == mag_total(mags)
        )

        trees, mags, nodes2, shard2, ok2, st2 = _leaf_alloc_mag(
            on, trees, mags, [True] * K, lanes, mag_lane
        )
        assert bool(ok2.all())
        assert int(st2["magazine_hits"]) == K
        assert int(st2["logical_rmws"]) == 0  # zero shared-state RMWs
        assert int(st2["overflows"]) == 0  # pops are not probe misses
        assert int(mag_total(mags)) == 0

        # drain-to-empty equals the magazines-off baseline exactly
        trees, mags, freed, _ = pool_wavefront_free_mag(
            on, trees, mags, nodes2, shard2, ok2,
            jnp.asarray(mag_lane, jnp.int32),
        )
        trees, mags, _ = pool_magazine_drain(on, trees, mags)
        assert int(mag_total(mags)) == 0
        assert int(pool_free_units(on, trees).sum()) == total
        off_units = pool_free_units(off, off.empty_trees())
        assert pool_free_units(on, trees).tolist() == off_units.tolist()

    @pytest.mark.parametrize("name,layout,S,fp", GRID)
    def test_capacity_equivalence_on_and_off(self, name, layout, S, fp):
        """Same churn trace on both pools: identical per-wave success
        masks while capacity suffices, identical winner counts under
        exhaustion, identical outstanding-page totals every wave."""
        depth = 4
        on, off = _pair(depth, S, layout, fp)
        total = int(pool_free_units(off, off.empty_trees()).sum())
        rng = np.random.default_rng(42 + S)
        K = 8
        t_on, m_on = on.empty_trees(), pool_init_magazines(on, K)
        t_off = off.empty_trees()
        held_on, held_off = [], []  # (nodes, shard, ok) per wave
        for wave in range(6):
            lanes = rng.integers(0, 64, K).tolist()
            mag_lane = list(range(K))
            t_on, m_on, n1, s1, ok1, _ = _leaf_alloc_mag(
                on, t_on, m_on, [True] * K, lanes, mag_lane
            )
            t_off, n2, s2, ok2, _ = _leaf_alloc(
                off, t_off, [True] * K, lanes
            )
            # failure equivalence: identical number served (the winner
            # *set* may differ once the spill-back reshuffles lanes)
            assert int(ok1.sum()) == int(ok2.sum()), wave
            held_on.append((n1, s1, ok1))
            held_off.append((n2, s2, ok2))
            # equal outstanding capacity, counting stashed pages free
            free_on = (
                int(pool_free_units(on, t_on).sum())
                + int(mag_total(m_on))
            )
            assert free_on == int(pool_free_units(off, t_off).sum())
            if wave % 2 == 1:  # free the two oldest waves
                for _ in range(2):
                    n1, s1, ok1 = held_on.pop(0)
                    t_on, m_on, _, _ = pool_wavefront_free_mag(
                        on, t_on, m_on, n1, s1, ok1,
                        jnp.arange(K, dtype=jnp.int32),
                    )
                    n2, s2, ok2 = held_off.pop(0)
                    from repro.core.pool import pool_wavefront_free

                    t_off, _, _ = pool_wavefront_free(
                        off, t_off, n2, s2, ok2
                    )
        # drain everything: both sides fully coalesced
        for n1, s1, ok1 in held_on:
            t_on, m_on, _, _ = pool_wavefront_free_mag(
                on, t_on, m_on, n1, s1, ok1,
                jnp.arange(K, dtype=jnp.int32),
            )
        t_on, m_on, _ = pool_magazine_drain(on, t_on, m_on)
        assert int(pool_free_units(on, t_on).sum()) == total

    @pytest.mark.parametrize("name,layout", LAYOUTS)
    def test_exhaustion_spills_magazines_back(self, name, layout):
        """A pool whose free capacity is entirely parked in magazines
        must still serve a magazine-less lane: one merged spill-back
        replenishes the tree and the failed lanes retry."""
        on, _ = _pair(3, 1, layout, False, mag_cap=8)
        K = 8
        trees, mags = on.empty_trees(), pool_init_magazines(on, 1)
        trees, mags, nodes, shard, ok, _ = _leaf_alloc_mag(
            on, trees, mags, [True] * K, list(range(K)), [0] * K
        )
        assert bool(ok.all())
        trees, mags, _, _ = pool_wavefront_free_mag(
            on, trees, mags, nodes, shard, ok,
            jnp.zeros(K, jnp.int32),
        )
        assert int(mag_total(mags)) == K
        assert int(pool_free_units(on, trees).sum()) == 0
        # lane with no magazine: only the spill-back can serve it
        trees, mags, _, _, ok2, st = _leaf_alloc_mag(
            on, trees, mags, [True] * 4, list(range(4)), [-1] * 4
        )
        assert bool(ok2.all())
        assert int(st["magazine_hits"]) == 0
        assert int(st["magazine_spills"]) == K
        assert int(mag_total(mags)) == 0

    @pytest.mark.parametrize("name,layout", LAYOUTS)
    def test_unowned_handles_never_stash(self, name, layout):
        """Freeing a handle the pool does not mark allocated must not
        park it in a magazine (a stashed junk page would later be
        'recycled' into a double allocation)."""
        on, _ = _pair(4, 2, layout, False)
        trees, mags = on.empty_trees(), pool_init_magazines(on, 2)
        total = int(pool_free_units(on, trees).sum())
        lo = 1 << on.tree.depth
        # never-allocated leaf + out-of-range node + junk shard
        nodes = jnp.asarray([lo + 3, 2, lo + 1], jnp.int32)
        shard = jnp.asarray([0, 0, 9], jnp.int32)
        trees, mags, freed, _ = pool_wavefront_free_mag(
            on, trees, mags, nodes, shard, jnp.ones(3, bool),
            jnp.zeros(3, jnp.int32),
        )
        assert int(mag_total(mags)) == 0
        assert int(pool_free_units(on, trees).sum()) == total

    @pytest.mark.parametrize("name,layout", LAYOUTS)
    def test_duplicate_burst_stashes_once(self, name, layout):
        """Duplicate instances of one page in a single burst: exactly
        one may stash, and the duplicates must not also free the page
        through the tree (stash + tree-free = capacity forgery)."""
        on, _ = _pair(4, 1, layout, False)
        total = int(pool_free_units(on, on.empty_trees()).sum())
        trees, mags = on.empty_trees(), pool_init_magazines(on, 4)
        trees, mags, nodes, shard, ok, _ = _leaf_alloc_mag(
            on, trees, mags, [True] * 2, [0, 1], [0, 1]
        )
        burst_nodes = jnp.asarray(
            [int(nodes[0])] * 3 + [int(nodes[1])], jnp.int32
        )
        burst_shard = jnp.asarray([int(shard[0])] * 3 + [int(shard[1])],
                                  jnp.int32)
        trees, mags, _, _ = pool_wavefront_free_mag(
            on, trees, mags, burst_nodes, burst_shard,
            jnp.ones(4, bool), jnp.asarray([0, 1, 2, 3], jnp.int32),
        )
        assert int(mag_total(mags)) == 2  # one instance each, no dups
        assert (
            int(pool_free_units(on, trees).sum()) + int(mag_total(mags))
            == total
        )

    def test_refill_batches_into_magazines(self):
        on, _ = _pair(4, 1, UNPACKED, False, mag_cap=4, refill=2)
        total = int(pool_free_units(on, on.empty_trees()).sum())
        trees, mags = on.empty_trees(), pool_init_magazines(on, 3)
        trees, mags, st = pool_magazine_refill(
            on, trees, mags, jnp.ones(3, bool)
        )
        assert int(st["magazine_refills"]) == 6  # 3 lanes x batch 2
        assert int(mag_total(mags)) == 6
        assert (
            int(pool_free_units(on, trees).sum()) + int(mag_total(mags))
            == total
        )
        # refill respects remaining room: a second burst on lane 0 only
        trees, mags, st2 = pool_magazine_refill(
            on, trees, mags, jnp.asarray([True, False, False])
        )
        assert int(st2["magazine_refills"]) == 2
        assert int(mags.depth[0]) == 4  # clipped at mag_cap
        with pytest.raises(ValueError):
            on2, _ = _pair(4, 1, UNPACKED, False, refill=0)
            pool_magazine_refill(
                on2, on2.empty_trees(), pool_init_magazines(on2, 1),
                jnp.ones(1, bool),
            )


class TestMagazineKernelParity:
    """The ops driver must produce identical results whether the pool
    step runs through the Pallas kernel (interpret mode) or the pure
    reference — magazines fused around the per-shard launches."""

    @pytest.mark.parametrize(
        "name,layout,fp",
        [
            ("unpacked", UNPACKED, False),
            ("unpacked", UNPACKED, True),
            ("bunch-packed", BUNCH_PACKED, False),
        ],
    )
    def test_step_parity(self, name, layout, fp):
        from repro.kernels.ops import nbbs_pool_wavefront_step
        from repro.obs.schema import POOL_STEP_SLOTS

        on, _ = _pair(4, 2, layout, fp)
        K = 6
        lanes = jnp.arange(K, dtype=jnp.int32)
        mag_lane = jnp.asarray([0, 0, 1, 1, 2, 2], jnp.int32)
        levels = jnp.full((K,), on.tree.depth, jnp.int32)

        def drive(impl):
            trees = on.empty_trees()
            mags = pool_init_magazines(on, 3)
            # warm the magazines: alloc one wave, free it into the
            # stash pre-pass of a mixed release+alloc step
            trees, mags, n0, s0, ok0, _ = _leaf_alloc_mag(
                on, trees, mags, [True] * K, list(range(K)),
                mag_lane.tolist(),
            )
            return nbbs_pool_wavefront_step(
                on, trees, n0, s0, ok0, levels,
                lane_ids=lanes, impl=impl,
                mags=mags, free_mag_lane=mag_lane,
                alloc_mag_lane=mag_lane,
            )

        t_r, m_r, n_r, s_r, ok_r, st_r = drive("reference")
        t_k, m_k, n_k, s_k, ok_k, st_k = drive("interpret")
        assert n_r.tolist() == n_k.tolist()
        assert s_r.tolist() == s_k.tolist()
        assert ok_r.tolist() == ok_k.tolist()
        assert int(mag_total(m_r)) == int(mag_total(m_k))
        assert (
            pool_free_units(on, t_r).tolist()
            == pool_free_units(on, t_k).tolist()
        )
        for slot in (
            "magazine_hits", "magazine_spills", "magazine_refills",
            "fastpath_hits", "freed",
        ):
            assert int(st_r[slot]) == int(st_k[slot]), slot
        assert set(POOL_STEP_SLOTS) <= set(st_k)


class TestManagerMagazines:
    """Host mirror: PagedKVManager with per-(lane,shard) magazines."""

    def test_recycle_hit_and_conservation(self):
        from repro.memory.kv_cache import PagedKVManager

        kv = PagedKVManager(
            64, 16, n_shards=2, fastpath=True, magazines=4, mag_lanes=4
        )
        assert kv.add_sequence(7, 16)
        kv.free_sequence(7)
        assert kv.mag_stashed() == 1
        assert kv.free_pages() == 64  # stashed page counts as free
        assert kv.add_sequence(7, 16)
        assert kv.magazine_hits == 1
        assert kv.mag_stashed() == 0
        frag = kv.fragmentation()
        for key in ("magazine_hits", "magazine_spills",
                    "magazine_refills", "magazine_stashed"):
            assert key in frag

    def test_append_rollback_mirrors_pr1_leak_test(self):
        """The PR 1 regression, magazines on: a failed grow releases
        runs appended by earlier iterations of the same call and the
        observable state is exactly as before."""
        from repro.memory.kv_cache import PagedKVManager

        kv = PagedKVManager(
            16, 1, max_run_pages=2, magazines=4, mag_lanes=2
        )
        assert kv.add_sequence(1, 2)
        assert kv.add_sequence(2, 8)
        assert kv.add_sequence(3, 4)
        assert kv.free_pages() == 2
        assert not kv.append_tokens(1, 6)
        s = kv.seqs[1]
        assert s.n_tokens == 2 and s.n_pages == 2
        assert kv.free_pages() == 2
        kv.free_sequence(2)
        kv.free_sequence(3)
        assert kv.append_tokens(1, 6)

    def test_rollback_returns_magazine_page_to_same_lane(self):
        """Satellite regression: a partial growth that consumed a
        magazine-claimed page must put it back on the *same lane's*
        magazine — not leak it into the shared tree — leaving both the
        magazine and the tree exactly as before the failed call."""
        from repro.memory.kv_cache import PagedKVManager

        kv = PagedKVManager(
            4, 1, max_run_pages=1, magazines=4, mag_lanes=1
        )
        assert kv.add_sequence(0, 1)
        assert kv.add_sequence(1, 1)
        assert kv.add_sequence(2, 1)
        kv.free_sequence(2)             # parks one page in lane 0's mag
        assert kv.mag_stashed() == 1
        stashed_page = kv._mags[0][0][-1]
        free_before = kv.free_pages()
        # grow needs 3 pages: magazine pop + tree page, then failure
        assert not kv.append_tokens(0, 3)
        assert kv.seqs[0].n_tokens == 1 and kv.seqs[0].n_pages == 1
        assert kv.free_pages() == free_before
        assert stashed_page in kv._mags[0][0]  # back on its own lane
        # the rolled-back tree page stashes too (uniform free policy):
        # both rollback pages sit in lane 0's magazine, none leaked
        assert kv.mag_stashed() == 2
        # nothing leaked: everything is still admissible
        kv.free_sequence(0)
        kv.free_sequence(1)
        assert kv.free_pages() == 4
        assert kv.add_sequence(9, 4)  # full capacity reclaimable

    def test_admission_spills_magazines_when_full(self):
        """All capacity parked across two lanes' magazines: a full-pool
        admission on one lane pops its own magazine, runs out, and can
        only fit after the add_sequence spill-retry releases the other
        lane's stash back to the tree."""
        from repro.memory.kv_cache import PagedKVManager

        kv = PagedKVManager(4, 1, max_run_pages=1, magazines=4,
                            mag_lanes=2)
        for i in range(4):
            assert kv.add_sequence(i, 1)
        kv.free_sequences([0, 1, 2, 3])
        assert kv.mag_stashed() == 4  # all capacity parked
        assert kv.add_sequence(8, 4)  # lane 0: 2 pops, then spill-retry
        assert kv.magazine_hits == 2
        assert kv.magazine_spills >= 2
        assert kv.mag_stashed() == 0
        assert kv.free_pages() == 0

    def test_device_pool_config_threads_magazines(self):
        from repro.memory.kv_cache import PagedKVManager

        kv = PagedKVManager(64, 16, n_shards=2, magazines=4,
                            magazine_refill=2)
        pcfg = kv.device_pool_config()
        assert pcfg.magazines is not None
        assert pcfg.magazines.mag_cap == 4
        assert pcfg.magazines.refill_batch == 2
        assert PagedKVManager(64, 16).device_pool_config().magazines is None


class TestOracleMagazines:
    """PageOracle mirrors the device claim/stash/spill exactly."""

    def test_claim_stash_lifo_and_duplicates(self):
        from repro.memory.kv_cache import PageOracle

        o = PageOracle(16, 16, magazines=4, mag_lanes=2)
        got = o.alloc_wavefront(
            [(k, k) for k in range(4)], mag_lanes=[0, 0, 1, 1]
        )
        pages = [got[k] for k in range(4)]
        o.free_burst(pages, stash_lanes=[0, 0, 1, 1])
        assert o.mag_stashed() == 4
        assert o.free_pages() == 16
        # duplicate instances: stash once, never double-free
        o2 = PageOracle(16, 16, magazines=4, mag_lanes=2)
        g = o2.alloc_wavefront([(0, 0)], mag_lanes=[0])
        p = g[0]
        o2.free_burst([p, p, p], stash_lanes=[0, 1, -1])
        assert o2.mag_stashed() == 1
        assert o2.free_pages() == 16
        o2.check_invariants()

    def test_exhaustion_spill_back(self):
        from repro.memory.kv_cache import PageOracle

        o = PageOracle(8, 16, magazines=8, mag_lanes=1)
        got = o.alloc_wavefront(
            [(k, k) for k in range(8)], mag_lanes=[0] * 8
        )
        o.free_burst(list(got.values()), stash_lanes=[0] * 8)
        assert o.mag_stashed() == 8
        got2 = o.alloc_wavefront([(k, 50 + k) for k in range(4)])
        assert all(v is not None for v in got2.values())
        assert o.magazine_spills == 8
        assert o.mag_stashed() == 0


class TestMagazineEngine:
    """Trace-replay regressions: the jit-resident engine with magazines
    on must stay step-exact vs the host oracle, and must emit the same
    tokens as itself with magazines off (recycling is a pure mechanism
    change on capacity-sufficient traces)."""

    @classmethod
    def setup_class(cls):
        from repro.configs import get_config
        from repro.models import init_params

        cls.cfg = get_config("stablelm-3b").reduced()
        cls.params = init_params(cls.cfg, jax.random.PRNGKey(0))

    def _engine(self, **kw):
        from repro.serve.jit_engine import JitServeEngine

        base = dict(
            num_pages=16, page_tokens=4, max_batch=4, max_lane_pages=8,
            max_out=16, dtype=jnp.float32,
        )
        base.update(kw)
        return JitServeEngine(self.cfg, self.params, **base)

    @staticmethod
    def _trace(seed, vocab, n=8):
        rng = np.random.default_rng(seed)
        return [
            (
                i,
                rng.integers(
                    0, vocab, size=int(rng.integers(1, 14))
                ).astype(np.int32),
                int(rng.integers(1, 8)),
            )
            for i in range(n)
        ]

    @pytest.mark.parametrize(
        "n_shards,layout", [(1, "unpacked"), (2, "bunch-packed")]
    )
    def test_matches_host_oracle_with_magazines(self, n_shards, layout):
        from repro.serve.engine import Request
        from repro.serve.oracle import HostOracleEngine

        eng = self._engine(
            n_shards=n_shards, layout=layout, magazines=4
        )
        orc = HostOracleEngine(
            num_pages=16, page_tokens=4, max_batch=4, max_lane_pages=8,
            max_out=16, n_shards=n_shards, magazines=4,
        )
        for i, p, mn in self._trace(3 * n_shards, self.cfg.vocab_size):
            eng.submit(Request(i, p, mn))
            orc.submit(Request(i, p.copy(), mn))
        for _ in range(100):
            eng._drain(), eng._admit()
            orc._drain(), orc._admit()
            assert sorted(eng.running) == sorted(orc.running)
            if not eng.running and not eng.waiting:
                break
            for sid in eng.running:
                assert (
                    eng.device_block_table(sid) == orc.block_table(sid)
                ).all(), sid
            assert eng.device_free_pages() == orc.free_pages()
            eng.decode_steps(1)
            orc.decode_steps(1)
        assert eng.retired_order == orc.retired_order
        assert eng.done_steps == orc.done_steps
        assert eng.device_free_pages() == orc.free_pages() == 16
        tot, otot = eng.stat_totals(), orc.stat_totals()
        for key in (
            "magazine_hits", "magazine_spills", "magazine_refills",
            "fastpath_hits", "fastpath_spills",
            "admitted", "overflow_retired",
        ):
            assert tot[key] == otot[key], key
        orc.pool.check_invariants()

    def test_magazines_on_off_token_exact(self):
        """Recycling must not change what the engine computes: with
        magazines on or off the engine emits the same tokens and the
        same retirement schedule on a capacity-sufficient trace (block
        tables legitimately differ — recycled pages come back LIFO)."""
        from repro.serve.engine import Request

        e_on = self._engine(n_shards=2, magazines=4)
        e_off = self._engine(n_shards=2)
        for i, p, mn in self._trace(5, self.cfg.vocab_size):
            e_on.submit(Request(i, p, mn))
            e_off.submit(Request(i, p.copy(), mn))
        for _ in range(100):
            e_on._drain(), e_on._admit()
            e_off._drain(), e_off._admit()
            assert sorted(e_on.running) == sorted(e_off.running)
            if not e_on.running and not e_on.waiting:
                break
            assert e_on.device_free_pages() == e_off.device_free_pages()
            e_on.decode_steps(1)
            e_off.decode_steps(1)
        assert e_on.retired_order == e_off.retired_order
        assert e_on.done_steps == e_off.done_steps
        for sid in e_on.completed:
            assert (
                e_on.completed[sid].out_tokens
                == e_off.completed[sid].out_tokens
            )
        assert e_on.stat_totals()["magazine_hits"] > 0
        assert e_off.stat_totals()["magazine_hits"] == 0

    def test_overflow_trace_with_magazines(self):
        """A trace that overflows the pool retires the same sequences
        the same way with magazines on: the exhaustion spill-back keeps
        failure semantics magazines-off-equivalent."""
        from repro.serve.engine import Request
        from repro.serve.oracle import HostOracleEngine

        kw = dict(num_pages=4, page_tokens=2, max_batch=2,
                  max_lane_pages=4, max_out=8)
        eng = self._engine(magazines=2, **kw)
        orc = HostOracleEngine(magazines=2, **kw)
        rng = np.random.default_rng(7)
        for i in range(6):
            p = rng.integers(
                0, self.cfg.vocab_size, int(rng.integers(1, 5))
            ).astype(np.int32)
            mn = int(rng.integers(2, 8))
            eng.submit(Request(i, p, mn))
            orc.submit(Request(i, p.copy(), mn))
        eng.run_to_completion(max_steps=200)
        orc.run_to_completion(max_steps=200)
        assert eng.retired_order == orc.retired_order
        assert eng.done_steps == orc.done_steps
        assert (
            eng.stat_totals()["overflow_retired"]
            == orc.stats["overflow_retired"]
        )
        assert eng.device_free_pages() == orc.free_pages() == 4
        orc.pool.check_invariants()

    def test_magazine_step_adds_no_host_sync(self):
        """The magazine claim/stash lives inside the compiled step:
        the decode loop stays transfer-free and re-trace-free."""
        from repro.serve import jit_engine as je
        from repro.serve.engine import Request

        eng = self._engine(magazines=4, fastpath=True, ring_capacity=16)
        rng = np.random.default_rng(0)
        for i in range(3):
            eng.submit(Request(
                i,
                rng.integers(0, self.cfg.vocab_size, 6).astype(np.int32),
                8,
            ))
        eng._drain(), eng._admit()
        eng.decode_steps(1)  # trace both step shapes outside the guard
        eng.decode_steps(2)
        traced = je.TRACE_COUNTS[eng.ecfg]
        with jax.transfer_guard("disallow"):
            for _ in range(4):
                eng.decode_steps(1)
                eng.decode_steps(2)
        assert je.TRACE_COUNTS[eng.ecfg] == traced
        eng._drain()
        assert eng.stat_totals()["magazine_hits"] >= 0
