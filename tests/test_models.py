"""Per-arch smoke tests (reduced configs) + mixer equivalences +
serving-path consistency.  One forward/train step on CPU per assigned
architecture, asserting output shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config
from repro.models import decode_step, init_params, prefill, train_loss
from repro.models.rwkv import apply_rwkv6, init_rwkv6, init_rwkv6_state
from repro.models.ssm import (
    apply_mamba2,
    apply_mamba2_decode,
    init_mamba2,
    init_mamba2_state,
)

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def make_batch(cfg, key, seq=S):
    batch = {"labels": jax.random.randint(key, (B, seq), 0, cfg.vocab_size)}
    if cfg.frontend != "none":
        batch["embeds"] = jax.random.normal(
            key, (B, seq, cfg.d_model), jnp.float32
        )
    else:
        batch["tokens"] = jax.random.randint(key, (B, seq), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_train_step(name):
    cfg = get_config(name).reduced()
    params = init_params(cfg, KEY)
    batch = make_batch(cfg, KEY)
    loss, grads = jax.value_and_grad(
        lambda p: train_loss(cfg, p, batch, dtype=jnp.float32, remat=True)
    )(params)
    assert jnp.isfinite(loss), name
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
    )
    assert jnp.isfinite(gnorm) and gnorm > 0, name


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_arch_smoke_serve_step(name):
    cfg = get_config(name).reduced()
    params = init_params(cfg, KEY)
    batch = make_batch(cfg, KEY)
    lg, cache = prefill(cfg, params, batch, max_len=S + 4, dtype=jnp.float32)
    assert lg.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(lg).all(), name
    tok = jnp.argmax(lg, -1).astype(jnp.int32)
    lg2, cache2 = decode_step(cfg, params, cache, tok, dtype=jnp.float32)
    assert lg2.shape == (B, cfg.vocab_size)
    assert jnp.isfinite(lg2).all(), name
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize(
    "name",
    ["stablelm-3b", "gemma2-27b", "zamba2-1.2b", "rwkv6-7b",
     "phi3.5-moe-42b-a6.6b", "llama4-scout-17b-a16e"],
)
def test_serve_consistency(name):
    """prefill(S+1) last logits == prefill(S) + decode(token S)."""
    cfg = get_config(name).reduced()
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab_size)
    lg_full, _ = prefill(
        cfg, params, {"tokens": toks}, max_len=S + 4, dtype=jnp.float32
    )
    _, cache = prefill(
        cfg, params, {"tokens": toks[:, :S]}, max_len=S + 4, dtype=jnp.float32
    )
    lg_dec, _ = decode_step(cfg, params, cache, toks[:, S], dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(lg_full), np.asarray(lg_dec), atol=1e-4
    )


def test_gemma2_window_pattern():
    from repro.models.transformer import window_array

    cfg = get_config("gemma2-27b")
    w = np.asarray(window_array(cfg))
    assert len(w) == 46
    assert (w[::2] == 4096).all() and (w[1::2] == 0).all()


def test_mamba2_chunk_invariance_and_decode():
    d, d_inner, d_state, hd = 32, 64, 16, 16
    p = init_mamba2(KEY, d, d_inner, d_state, hd)
    x = jax.random.normal(jax.random.fold_in(KEY, 1), (B, 24, d), jnp.float32)
    kw = dict(d_inner=d_inner, d_state=d_state, head_dim=hd)
    y8 = apply_mamba2(p, x, chunk=8, **kw)
    y24 = apply_mamba2(p, x, chunk=24, **kw)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y24), atol=1e-4)
    st = init_mamba2_state(B, d_inner, d_state, hd, dtype=jnp.float32)
    ys = []
    for t in range(24):
        yt, st = apply_mamba2_decode(p, x[:, t : t + 1], st, **kw)
        ys.append(yt)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, 1)), np.asarray(y8), atol=1e-4
    )


def test_mamba2_prefill_state_continuation():
    d, d_inner, d_state, hd = 32, 64, 16, 16
    p = init_mamba2(KEY, d, d_inner, d_state, hd)
    kw = dict(d_inner=d_inner, d_state=d_state, head_dim=hd)
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (B, 20, d), jnp.float32)
    y_full = apply_mamba2(p, x, chunk=8, **kw)
    _, st = apply_mamba2(p, x[:, :12], chunk=8, return_state=True, **kw)
    ys = []
    for t in range(12, 20):
        yt, st = apply_mamba2_decode(p, x[:, t : t + 1], st, **kw)
        ys.append(yt)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, 1)),
        np.asarray(y_full[:, 12:]),
        atol=1e-4,
    )


def test_rwkv6_streaming_equivalence():
    d, hd = 32, 16
    p = init_rwkv6(KEY, d, 4 * d, hd)
    x = jax.random.normal(jax.random.fold_in(KEY, 3), (B, 24, d), jnp.float32)
    y1, _ = apply_rwkv6(p, x, head_dim=hd)
    ha, sta = apply_rwkv6(p, x[:, :12], head_dim=hd)
    hb, _ = apply_rwkv6(p, x[:, 12:], head_dim=hd, state=sta)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([ha, hb], 1)), np.asarray(y1), atol=1e-4
    )
    st = init_rwkv6_state(B, d, hd)
    ys = []
    for t in range(24):
        yt, st = apply_rwkv6(p, x[:, t : t + 1], head_dim=hd, state=st)
        ys.append(yt)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(ys, 1)), np.asarray(y1), atol=1e-4
    )


def test_moe_aux_loss_and_balance():
    from repro.models.moe import apply_moe, init_moe

    p = init_moe(KEY, 32, 64, 4)
    x = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 64, 32), jnp.float32)
    y, aux = apply_moe(p, x, top_k=2, dtype=jnp.float32)
    assert y.shape == x.shape
    assert jnp.isfinite(y).all() and jnp.isfinite(aux)
    assert float(aux) > 0


def test_param_counts_sane():
    # analytic counts should be within 2x of actual reduced-model counts
    for name in ARCH_NAMES:
        cfg = get_config(name).reduced()
        params = init_params(cfg, KEY)
        actual = sum(x.size for x in jax.tree.leaves(params))
        est = cfg.param_count()
        assert 0.4 < est / actual < 2.5, (name, est, actual)


def test_moe_dispatch_modes_equivalent():
    """GShard einsum dispatch == scatter dispatch (same capacity
    semantics) — the §Perf collective fix must not change the math."""
    from repro.models.moe import apply_moe, init_moe

    p = init_moe(KEY, 16, 32, 4)
    x = jax.random.normal(jax.random.fold_in(KEY, 5), (2, 32, 16), jnp.float32)
    for k in (1, 2):
        y1, a1 = apply_moe(p, x, top_k=k, capacity_factor=1.25,
                           dtype=jnp.float32)
        y2, a2 = apply_moe(p, x, top_k=k, capacity_factor=1.25,
                           dtype=jnp.float32, dispatch="einsum",
                           group_size=64)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
        np.testing.assert_allclose(float(a1), float(a2), atol=1e-5)
    # block-local scatter == group-local einsum at matching geometry
    y3, _ = apply_moe(p, x, top_k=2, capacity_factor=2.0, dtype=jnp.float32,
                      n_blocks=4)
    y4, _ = apply_moe(p, x, top_k=2, capacity_factor=2.0, dtype=jnp.float32,
                      dispatch="einsum", group_size=16)
    np.testing.assert_allclose(np.asarray(y3), np.asarray(y4), atol=1e-5)


def test_moe_einsum_arch_end_to_end():
    """A MoE arch trains and serves with dispatch_mode='einsum'."""
    import dataclasses

    cfg = dataclasses.replace(
        get_config("phi3.5-moe-42b-a6.6b").reduced(),
        dispatch_mode="einsum", dispatch_group=16,
    )
    params = init_params(cfg, KEY)
    batch = make_batch(cfg, KEY)
    loss = train_loss(cfg, params, batch, dtype=jnp.float32, remat=False)
    assert jnp.isfinite(loss)
    lg, cache = prefill(cfg, params, batch, max_len=S + 2, dtype=jnp.float32)
    lg2, _ = decode_step(cfg, params, cache,
                         jnp.argmax(lg, -1).astype(jnp.int32),
                         dtype=jnp.float32)
    assert jnp.isfinite(lg2).all()
