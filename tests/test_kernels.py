"""Pallas kernel validation: interpret-mode execution vs pure-jnp
oracles across shape/dtype sweeps (per-kernel allclose)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.concurrent import (
    BUNCH_PACKED,
    TreeConfig,
    UNPACKED,
    wavefront_alloc,
    wavefront_step,
)

_LAYOUTS = {"unpacked": UNPACKED, "packed": BUNCH_PACKED}
from repro.core.pool import PoolConfig
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.nbbs_alloc import wavefront_alloc_pallas, wavefront_step_pallas
from repro.kernels.ops import (
    flash_attention,
    nbbs_pool_wavefront_step,
    nbbs_wavefront_alloc,
    nbbs_wavefront_step,
    paged_attention,
)
from repro.kernels.paged_attention import paged_attention as paged_pallas
from repro.kernels.ref import mha_reference, paged_attention_reference

KEY = jax.random.PRNGKey(0)


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


class TestFlashAttention:
    @pytest.mark.parametrize("S,D,Hq,Hkv", [
        (128, 32, 4, 4),    # MHA
        (256, 64, 8, 2),    # GQA
        (192, 16, 2, 1),    # MQA, non-128 seq
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shapes_dtypes(self, S, D, Hq, Hkv, dtype):
        B = 2
        q = rand(jax.random.fold_in(KEY, 1), (B, Hq, S, D), dtype)
        k = rand(jax.random.fold_in(KEY, 2), (B, Hkv, S, D), dtype)
        v = rand(jax.random.fold_in(KEY, 3), (B, Hkv, S, D), dtype)
        out = flash_attention_fwd(q, k, v, block_q=64, block_k=64)
        ref = mha_reference(q, k, v)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=tol, rtol=tol,
        )

    @pytest.mark.parametrize("variant", [
        dict(causal=False),
        dict(causal=True, window=64),
        dict(causal=True, softcap=30.0),
        dict(causal=True, window=96, softcap=50.0),
    ])
    def test_variants(self, variant):
        B, Hq, Hkv, S, D = 1, 4, 2, 256, 32
        q = rand(jax.random.fold_in(KEY, 4), (B, Hq, S, D), jnp.float32)
        k = rand(jax.random.fold_in(KEY, 5), (B, Hkv, S, D), jnp.float32)
        v = rand(jax.random.fold_in(KEY, 6), (B, Hkv, S, D), jnp.float32)
        out = flash_attention_fwd(q, k, v, block_q=64, block_k=64, **variant)
        ref = mha_reference(q, k, v, **variant)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5
        )

    @pytest.mark.parametrize("bq,bk", [(32, 32), (64, 128), (128, 64)])
    def test_block_size_sweep(self, bq, bk):
        B, Hq, Hkv, S, D = 1, 2, 2, 256, 32
        q = rand(jax.random.fold_in(KEY, 7), (B, Hq, S, D), jnp.float32)
        k = rand(jax.random.fold_in(KEY, 8), (B, Hkv, S, D), jnp.float32)
        v = rand(jax.random.fold_in(KEY, 9), (B, Hkv, S, D), jnp.float32)
        out = flash_attention_fwd(q, k, v, block_q=bq, block_k=bk)
        ref = mha_reference(q, k, v)
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(ref), atol=2e-5, rtol=2e-5
        )

    def test_gradients_match_reference(self):
        B, Hq, Hkv, S, D = 1, 4, 2, 128, 32
        q = rand(jax.random.fold_in(KEY, 10), (B, Hq, S, D), jnp.float32)
        k = rand(jax.random.fold_in(KEY, 11), (B, Hkv, S, D), jnp.float32)
        v = rand(jax.random.fold_in(KEY, 12), (B, Hkv, S, D), jnp.float32)
        g1 = jax.grad(
            lambda q, k, v: flash_attention(q, k, v, impl="interpret").sum(),
            argnums=(0, 1, 2),
        )(q, k, v)
        g2 = jax.grad(
            lambda q, k, v: flash_attention(q, k, v, impl="reference").sum(),
            argnums=(0, 1, 2),
        )(q, k, v)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


class TestPagedAttention:
    @pytest.mark.parametrize("page,maxp,Hq,Hkv,D", [
        (16, 8, 4, 2, 64),
        (8, 16, 8, 8, 32),
        (32, 4, 2, 1, 128),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_sweep(self, page, maxp, Hq, Hkv, D, dtype):
        B, P = 3, 64
        kp = rand(jax.random.fold_in(KEY, 20), (P, page, Hkv, D), dtype)
        vp = rand(jax.random.fold_in(KEY, 21), (P, page, Hkv, D), dtype)
        q = rand(jax.random.fold_in(KEY, 22), (B, Hq, D), dtype)
        rng = np.random.default_rng(0)
        bt = np.full((B, maxp), -1, np.int32)
        cl = np.zeros((B,), np.int32)
        for b in range(B):
            n = int(rng.integers(1, maxp + 1))
            bt[b, :n] = rng.choice(P, size=n, replace=False)
            cl[b] = int(rng.integers(1, n * page + 1))
        out = paged_pallas(q, kp, vp, jnp.asarray(bt), jnp.asarray(cl))
        ref = paged_attention_reference(q, kp, vp, jnp.asarray(bt), jnp.asarray(cl))
        tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=tol, rtol=tol,
        )

    def test_softcap(self):
        B, P, page, maxp, Hq, Hkv, D = 2, 16, 8, 4, 4, 2, 32
        kp = rand(jax.random.fold_in(KEY, 23), (P, page, Hkv, D), jnp.float32)
        vp = rand(jax.random.fold_in(KEY, 24), (P, page, Hkv, D), jnp.float32)
        q = rand(jax.random.fold_in(KEY, 25), (B, Hq, D), jnp.float32)
        bt = jnp.asarray([[0, 1, 2, 3], [4, 5, -1, -1]], jnp.int32)
        cl = jnp.asarray([30, 12], jnp.int32)
        out = paged_pallas(q, kp, vp, bt, cl, softcap=20.0)
        ref = paged_attention_reference(q, kp, vp, bt, cl, softcap=20.0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


class TestNBBSKernel:
    @pytest.mark.parametrize("depth,K,seed,layout", [
        (6, 16, 0, "unpacked"), (9, 64, 1, "unpacked"),
        (8, 33, 2, "packed"), (10, 128, 3, "unpacked"),
        (6, 16, 4, "packed"),
    ])
    def test_matches_jnp_wavefront(self, depth, K, seed, layout):
        cfg = TreeConfig(depth=depth, max_level=0, layout=_LAYOUTS[layout])
        rng = np.random.default_rng(seed)
        levels = jnp.asarray(
            rng.integers(2, depth + 1, size=K), jnp.int32
        )
        t0 = cfg.empty_tree()
        t1, n1, ok1, _ = wavefront_alloc(cfg, t0, levels, jnp.ones(K, bool))
        t2, n2, ok2, stats = wavefront_alloc_pallas(cfg, t0, levels)
        assert (np.asarray(t1) == np.asarray(t2)).all()
        assert (np.asarray(n1) == np.asarray(n2)).all()

    def test_on_fragmented_tree(self):
        cfg = TreeConfig(depth=8, max_level=0)
        tree = cfg.empty_tree()
        # fragment: allocate some, free alternating
        tree, nodes, ok, _ = wavefront_alloc(
            cfg, tree, jnp.full(32, 8, jnp.int32), jnp.ones(32, bool)
        )
        from repro.core.concurrent import free_batch
        tree, _ = free_batch(cfg, tree, nodes[::2], jnp.ones(16, bool))
        levels = jnp.asarray([4, 5, 8, 8, 6], jnp.int32)
        t1, n1, ok1, _ = wavefront_alloc(cfg, tree, levels, jnp.ones(5, bool))
        t2, n2, ok2, _ = wavefront_alloc_pallas(cfg, tree, levels)
        assert (np.asarray(t1) == np.asarray(t2)).all()
        assert (np.asarray(n1) == np.asarray(n2)).all()

    def test_ops_dispatch(self):
        cfg = TreeConfig(depth=6, max_level=0)
        levels = jnp.asarray([3, 4, 5], jnp.int32)
        t1, n1, ok1, s1 = nbbs_wavefront_alloc(
            cfg, cfg.empty_tree(), levels, impl="interpret"
        )
        t2, n2, ok2, s2 = nbbs_wavefront_alloc(
            cfg, cfg.empty_tree(), levels, impl="reference"
        )
        assert (np.asarray(t1) == np.asarray(t2)).all()
        assert int(s1["rounds"]) == int(s2["rounds"])

    @pytest.mark.parametrize("depth,K,F,seed,layout", [
        (6, 16, 8, 0, "unpacked"), (8, 33, 16, 1, "unpacked"),
        (9, 64, 64, 2, "unpacked"), (7, 24, 12, 3, "packed"),
    ])
    def test_mixed_step_matches_jnp(self, depth, K, F, seed, layout):
        """Kernel mixed alloc+free rounds (tree state VMEM-resident for
        the whole step) vs the jnp wavefront_step oracle — both tree
        layouts (the packed case keeps uint32 bunch words in VMEM)."""
        cfg = TreeConfig(depth=depth, max_level=0, layout=_LAYOUTS[layout])
        rng = np.random.default_rng(seed)
        # fragment first so frees exercise real coalescing
        tree, nodes, ok, _ = wavefront_alloc(
            cfg, cfg.empty_tree(),
            jnp.asarray(rng.integers(2, depth + 1, size=2 * F), jnp.int32),
            jnp.ones(2 * F, bool),
        )
        fn = jnp.asarray(np.asarray(nodes)[:F], jnp.int32)
        fa = jnp.asarray(np.asarray(ok)[:F])
        levels = jnp.asarray(rng.integers(1, depth + 1, size=K), jnp.int32)
        t1, n1, ok1, s1 = wavefront_step(
            cfg, tree, fn, fa, levels, jnp.ones(K, bool)
        )
        t2, n2, ok2, s2 = wavefront_step_pallas(cfg, tree, fn, fa, levels)
        assert (np.asarray(t1) == np.asarray(t2)).all()
        assert (np.asarray(n1) == np.asarray(n2)).all()
        assert int(s2[3]) == int(s1["free_merged_writes"])
        assert int(s2[4]) == int(s1["free_logical_rmws"])
        assert int(s2[5]) == int(s1["freed"])

    def test_mixed_step_ops_dispatch(self):
        cfg = TreeConfig(depth=6, max_level=0)
        tree, nodes, ok, _ = wavefront_alloc(
            cfg, cfg.empty_tree(), jnp.full(8, 6, jnp.int32), jnp.ones(8, bool)
        )
        fn, fa = nodes[:4], jnp.ones(4, bool)
        levels = jnp.asarray([2, 5, 6], jnp.int32)
        t1, n1, ok1, s1 = nbbs_wavefront_step(
            cfg, tree, fn, fa, levels, impl="interpret"
        )
        t2, n2, ok2, s2 = nbbs_wavefront_step(
            cfg, tree, fn, fa, levels, impl="reference"
        )
        assert (np.asarray(t1) == np.asarray(t2)).all()
        assert (np.asarray(n1) == np.asarray(n2)).all()
        assert int(s1["free_merged_writes"]) == int(s2["free_merged_writes"])


class TestPooledNBBSKernel:
    """Grid-over-shards pooled kernel vs the in-graph pool router."""

    def test_s1_bit_identical_to_single_tree_kernel(self):
        cfg = TreeConfig(depth=6, max_level=0)
        pcfg = PoolConfig(cfg, 1)
        rng = np.random.default_rng(4)
        tree, nodes, ok, _ = wavefront_alloc(
            cfg, cfg.empty_tree(),
            jnp.asarray(rng.integers(2, 7, size=16), jnp.int32),
            jnp.ones(16, bool),
        )
        fn, fa = nodes[:8], ok[:8]
        levels = jnp.asarray(rng.integers(1, 7, size=12), jnp.int32)
        t1, n1, ok1, _ = wavefront_step_pallas(cfg, tree, fn, fa, levels)
        t2, n2, sh2, ok2, _ = nbbs_pool_wavefront_step(
            pcfg, tree[None, :], fn, jnp.zeros(8, jnp.int32), fa, levels,
            impl="interpret",
        )
        assert (np.asarray(t1) == np.asarray(t2[0])).all()
        assert (np.asarray(n1) == np.asarray(n2)).all()
        assert not np.asarray(sh2).any()

    @pytest.mark.parametrize("S,depth,K,seed,layout", [
        (2, 6, 16, 0, "unpacked"), (4, 5, 20, 1, "unpacked"),
        (2, 6, 16, 2, "packed"),
    ])
    def test_no_overflow_matches_reference_pool(self, S, depth, K, seed, layout):
        """Without overflow the attempt-granular kernel linearization is
        the same linearization as the lockstep in-graph router, so the
        results must be bit-identical (both tree layouts)."""
        pcfg = PoolConfig(TreeConfig(depth=depth, layout=_LAYOUTS[layout]), S)
        rng = np.random.default_rng(seed)
        # ample capacity: mid-to-leaf levels, no shard can exhaust
        levels = jnp.asarray(
            rng.integers(depth - 2, depth + 1, size=K), jnp.int32
        )
        fz = jnp.zeros(4, jnp.int32)
        fza = jnp.zeros(4, bool)
        r = nbbs_pool_wavefront_step(
            pcfg, pcfg.empty_trees(), fz, fz, fza, levels, impl="reference"
        )
        p = nbbs_pool_wavefront_step(
            pcfg, pcfg.empty_trees(), fz, fz, fza, levels, impl="interpret"
        )
        for a, b in zip(r[:4], p[:4]):
            assert (np.asarray(a) == np.asarray(b)).all()
        assert int(r[4]["overflows"]) == 0
        assert int(p[4]["overflows"]) == 0

    def test_pooled_mixed_step_with_frees(self):
        """Frees land on their recorded shard inside the kernel launch
        and the freed capacity is reusable by the same launch's allocs."""
        S, depth = 2, 5
        pcfg = PoolConfig(TreeConfig(depth=depth), S)
        # fill both shards completely at the leaf level
        K0 = S << depth
        lv0 = jnp.full(K0, depth, jnp.int32)
        from repro.core.pool import pool_wavefront_alloc

        trees, nodes, shard, ok, _ = pool_wavefront_alloc(
            pcfg, pcfg.empty_trees(), lv0, jnp.ones(K0, bool)
        )
        assert bool(ok.all())
        # free half of each shard, then allocate one level-(depth-1)
        # chunk per shard through the pooled kernel
        keep = np.arange(K0) % 2 == 0
        fn = jnp.asarray(np.asarray(nodes)[keep], jnp.int32)
        fs = jnp.asarray(np.asarray(shard)[keep], jnp.int32)
        fa = jnp.ones(fn.shape[0], bool)
        levels = jnp.full(2, depth, jnp.int32)
        trees2, n2, sh2, ok2, stats = nbbs_pool_wavefront_step(
            pcfg, trees, fn, fs, fa, levels, impl="interpret"
        )
        assert bool(ok2.all())
        assert int(stats["freed"]) == fn.shape[0]
