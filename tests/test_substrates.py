"""Substrate tests: data pipeline, optimizer, compression, checkpointing
(incl. elastic restore), supervisor restart/straggler logic."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import Prefetcher, SyntheticLM
from repro.optim import adamw
from repro.optim.compression import (
    compress,
    decompress,
    ef_roundtrip,
    init_error_buf,
)
from repro.runtime.supervisor import (
    FailureInjector,
    SimulatedFailure,
    StragglerDetector,
    Supervisor,
)
from repro.train.trainer import TrainConfig, init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


class TestData:
    def test_deterministic_and_seekable(self):
        d = SyntheticLM(100, 16, 8, seed=3)
        b1 = d.batch_at(5)
        b2 = d.batch_at(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(
            d.batch_at(6)["tokens"], b1["tokens"]
        )

    def test_labels_are_shifted_tokens(self):
        d = SyntheticLM(100, 16, 4)
        b = d.batch_at(0)
        assert b["tokens"].shape == b["labels"].shape == (4, 16)

    def test_host_sharding_partitions_global_batch(self):
        full = SyntheticLM(100, 8, 8, seed=1).batch_at(2)
        p0 = SyntheticLM(100, 8, 8, seed=1, process_index=0, process_count=2)
        p1 = SyntheticLM(100, 8, 8, seed=1, process_index=1, process_count=2)
        np.testing.assert_array_equal(
            np.concatenate(
                [p0.batch_at(2)["tokens"], p1.batch_at(2)["tokens"]]
            ),
            full["tokens"],
        )

    def test_prefetcher(self):
        d = SyntheticLM(100, 8, 4)
        it = Prefetcher(iter(d), depth=2)
        a = next(it)
        b = next(it)
        assert not np.array_equal(a["tokens"], b["tokens"])


class TestAdamW:
    def test_descends_quadratic(self):
        cfg = adamw.AdamWConfig(peak_lr=0.1, warmup_steps=1, total_steps=100)
        params = {"w": jnp.asarray([2.0, -3.0])}
        state = adamw.init(params)
        for _ in range(100):
            grads = {"w": 2 * params["w"]}
            params, state, m = adamw.update(cfg, grads, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.2

    def test_clipping(self):
        cfg = adamw.AdamWConfig(clip_norm=1.0, warmup_steps=1)
        params = {"w": jnp.zeros(4)}
        state = adamw.init(params)
        _, _, m = adamw.update(
            cfg, {"w": jnp.full(4, 100.0)}, state, params
        )
        assert float(m["grad_norm"]) == pytest.approx(200.0)

    def test_schedule_shape(self):
        cfg = adamw.AdamWConfig(
            peak_lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1
        )
        assert float(adamw.schedule(cfg, jnp.asarray(0))) == 0.0
        assert float(adamw.schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0)
        assert float(adamw.schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1)


class TestCompression:
    def test_roundtrip_error_bounded(self):
        g = jax.random.normal(KEY, (1000,))
        q, s = compress(g)
        rec = decompress(q, s, g.shape)
        assert float(jnp.abs(rec - g).max()) <= float(s.max()) + 1e-6

    def test_error_feedback_accumulates(self):
        g = {"w": jax.random.normal(KEY, (300,)) * 1e-3}
        ebuf = init_error_buf(g)
        rec, ebuf = ef_roundtrip(g, ebuf)
        # the residual is carried, not lost
        np.testing.assert_allclose(
            np.asarray(rec["w"] + ebuf["w"]), np.asarray(g["w"]), atol=1e-6
        )

    def test_wire_volume(self):
        q, s = compress(jnp.ones((4096,)))
        assert q.dtype == jnp.int8
        assert q.size == 4096 and s.size == 16  # 1B/elem + 1/256 scales


class TestCheckpoint:
    def test_save_restore_roundtrip(self):
        tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4))}}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_io=False)
            mgr.save(7, tree)
            assert mgr.latest_step() == 7
            out = mgr.restore(7, like=tree)
            np.testing.assert_array_equal(np.asarray(out["a"]),
                                          np.asarray(tree["a"]))

    def test_retention_gc(self):
        tree = {"a": jnp.zeros(2)}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2, async_io=False)
            for s in (1, 2, 3, 4):
                mgr.save(s, tree)
            assert mgr.all_steps() == [3, 4]

    def test_corruption_detected(self):
        tree = {"a": jnp.arange(5.0)}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_io=False)
            mgr.save(1, tree)
            path = os.path.join(d, "step_00000001", "leaf_00000.npy")
            with open(path, "r+b") as f:
                f.seek(-1, 2)
                f.write(b"\x00")
            with pytest.raises(IOError):
                mgr.restore(1, like=tree)

    def test_async_save(self):
        tree = {"a": jnp.arange(100.0)}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_io=True)
            mgr.save(1, tree)
            mgr.wait()
            assert mgr.latest_step() == 1

    def test_elastic_restore_placement(self):
        """Checkpoints are global arrays: restoring with different
        shardings (a different mesh) is the same code path."""
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_io=False)
            mgr.save(1, tree)
            sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
            out = mgr.restore(1, like=tree, shardings={"w": sh})
            np.testing.assert_array_equal(np.asarray(out["w"]),
                                          np.asarray(tree["w"]))


class TestSupervisor:
    def _mk(self, d, fail_at=(), steps=20, ckpt_every=5):
        cfg = get_config("stablelm-3b").reduced()
        tcfg = TrainConfig(microbatches=1, remat=False, dtype=jnp.float32)
        data = SyntheticLM(cfg.vocab_size, 8, 4)
        step_jit = jax.jit(make_train_step(cfg, tcfg))

        def make_state():
            return init_train_state(cfg, tcfg, KEY)

        def step_fn(state, idx):
            return step_jit(state, data.batch_at(idx))

        ckpt = CheckpointManager(d, async_io=False)
        return Supervisor(
            make_state, step_fn, ckpt, ckpt_every=ckpt_every,
            failure_injector=FailureInjector(tuple(fail_at)),
        )

    def test_restart_resumes_from_checkpoint(self):
        with tempfile.TemporaryDirectory() as d:
            sup = self._mk(d, fail_at=(7,), steps=12)
            sup.run(12)
            assert sup.restarts == 1
            steps_seen = [h["step"] for h in sup.history]
            # steps 5 and 6 are replayed after the failure at 7
            assert steps_seen.count(5) == 2 and steps_seen.count(6) == 2
            assert steps_seen[-1] == 11

    def test_too_many_failures_raises(self):
        with tempfile.TemporaryDirectory() as d:
            sup = self._mk(d, fail_at=(0,))
            sup.max_restarts = 0
            # failing at step 0 repeatedly (fires once) then resumes
            with pytest.raises(SimulatedFailure):
                sup.inject.fired.clear()
                sup.max_restarts = -1
                sup.run(2)

    def test_straggler_detection(self):
        det = StragglerDetector(warmup=3, threshold_sigma=2.0)
        for i in range(10):
            det.observe(i, 0.10 + 0.001 * (i % 2))
        assert det.observe(10, 1.0) is True
        assert det.events[-1]["step"] == 10
        # baseline stays clean: a normal step afterwards is not flagged
        assert det.observe(11, 0.10) is False


class TestTrainerLoop:
    def test_loss_decreases_tiny_lm(self):
        cfg = get_config("stablelm-3b").reduced()
        tcfg = TrainConfig(
            microbatches=2, remat=True, dtype=jnp.float32,
            compress_grads=True,
            optimizer=adamw.AdamWConfig(
                peak_lr=3e-3, warmup_steps=5, total_steps=60
            ),
        )
        data = SyntheticLM(cfg.vocab_size, 32, 8, seed=0)
        step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=0)
        state = init_train_state(cfg, tcfg, KEY)
        losses = []
        for i in range(60):
            state, m = step(state, data.batch_at(i))
            losses.append(float(m["loss"]))
        assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.2
