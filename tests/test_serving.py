"""Serving stack tests: NBBS page manager, continuous-batching engine,
paged-vs-dense decode equivalence, admission control, fragmentation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.memory.kv_cache import PagedKVManager
from repro.models import init_params, prefill, decode_step
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


class TestPagedKVManager:
    def test_admission_and_release(self):
        kv = PagedKVManager(64, page_tokens=16)
        assert kv.add_sequence(1, 100)  # -> 7 pages -> run of 8
        assert kv.seqs[1].n_pages == 8
        assert kv.free_pages() == 56
        kv.free_sequence(1)
        assert kv.free_pages() == 64

    def test_block_table_contiguous_runs(self):
        kv = PagedKVManager(64, page_tokens=16)
        kv.add_sequence(1, 64)  # 4 pages, one buddy run
        bt = kv.block_table(1, 8)
        run = bt[bt >= 0]
        assert len(run) == 4
        assert (np.diff(run) == 1).all()  # buddy contiguity

    def test_growth_by_doubling(self):
        kv = PagedKVManager(64, page_tokens=4)
        kv.add_sequence(1, 4)  # 1 page
        for _ in range(12):
            assert kv.append_tokens(1, 1)
        s = kv.seqs[1]
        assert s.n_pages >= kv.pages_for_tokens(s.n_tokens)
        # O(log T) runs
        assert len(s.runs) <= 4

    def test_admission_control_when_full(self):
        kv = PagedKVManager(16, page_tokens=16)
        assert kv.add_sequence(1, 16 * 12)
        assert not kv.add_sequence(2, 16 * 8)  # would exceed pool
        assert 2 not in kv.seqs  # rollback left no partial allocation
        kv.free_sequence(1)
        assert kv.add_sequence(2, 16 * 8)

    def test_append_tokens_failure_rolls_back_partial_growth(self):
        """Regression: a failed grow must release runs appended by earlier
        iterations of the same call — a partially grown sequence would
        leak pages the token count never accounts for."""
        kv = PagedKVManager(16, page_tokens=1, max_run_pages=2)
        assert kv.add_sequence(1, 2)          # one run of 2 pages
        assert kv.add_sequence(2, 8)          # 4 runs of 2
        assert kv.add_sequence(3, 4)          # 2 runs of 2
        assert kv.free_pages() == 2
        # growing to 8 pages needs 3 more runs of 2; only one fits
        assert not kv.append_tokens(1, 6)
        s = kv.seqs[1]
        assert s.n_tokens == 2                # token rollback
        assert s.n_pages == 2                 # run rollback
        assert kv.free_pages() == 2           # nothing leaked
        # the sequence is still fully usable after the failed grow
        kv.free_sequence(2)
        kv.free_sequence(3)
        assert kv.append_tokens(1, 6)
        assert kv.seqs[1].n_pages >= kv.pages_for_tokens(8)

    def test_free_sequences_batch_release(self):
        kv = PagedKVManager(64, page_tokens=16)
        for i in range(4):
            assert kv.add_sequence(i, 16 * 4)
        kv.free_sequences([0, 2])
        assert kv.free_pages() == 56
        assert set(kv.seqs) == {1, 3}
        kv.free_sequences([1, 3])
        assert kv.free_pages() == 64
        kv.buddy.check_invariants()

    def test_free_sequences_unknown_id_leaves_state_intact(self):
        kv = PagedKVManager(64, page_tokens=16)
        for i in range(2):
            assert kv.add_sequence(i, 16 * 4)
        with pytest.raises(KeyError):
            kv.free_sequences([0, 99])
        # nothing was popped or freed: the batch validates before mutating
        assert set(kv.seqs) == {0, 1}
        assert kv.free_pages() == 56
        kv.free_sequences([0, 0, 1])  # duplicates collapse
        assert kv.free_pages() == 64

    def test_fragmentation_stats(self):
        kv = PagedKVManager(64, page_tokens=16)
        ids = []
        for i in range(8):
            kv.add_sequence(i, 16 * 4)  # 4 pages each
            ids.append(i)
        for i in ids[::2]:
            kv.free_sequence(i)
        f = kv.fragmentation()
        assert f["used_pages"] == 16
        assert f["largest_run"] >= 4
        # buddy coalescing: freeing neighbours re-creates large runs
        for i in ids[1::2]:
            kv.free_sequence(i)
        assert kv.fragmentation()["largest_run"] == 64


class TestShardedPagedKVManager:
    def test_sequence_lands_on_home_shard_within_range(self):
        kv = PagedKVManager(64, page_tokens=16, n_shards=4)
        assert kv.add_sequence(7, 100)
        s = kv.seqs[7]
        assert s.shard == kv.home_shard(7)
        lo = s.shard * kv.pages_per_shard
        assert all(
            lo <= p < lo + kv.pages_per_shard for r in s.runs for p in r
        )

    def test_overflow_admission_probes_next_shard(self):
        kv = PagedKVManager(64, page_tokens=1, n_shards=4)
        # fill seq 1's home shard completely, then admit another
        # sequence with the same home: it must land on a different shard
        home = kv.home_shard(1)
        assert kv.add_sequence(1, 16)  # entire home shard
        assert kv.seqs[1].shard == home
        clone = next(
            i for i in range(2, 200)
            if kv.home_shard(i) == home
        )
        assert kv.add_sequence(clone, 16)
        assert kv.seqs[clone].shard == (home + 1) % 4
        # pool full only when every shard is full
        others = []
        i = 1000
        while kv.free_pages():
            if kv.add_sequence(i, 16):
                others.append(i)
            i += 1
        assert not kv.add_sequence(i + 1, 1)

    def test_burst_release_per_shard_and_invariants(self):
        kv = PagedKVManager(64, page_tokens=16, n_shards=2)
        ids = []
        for i in range(8):
            assert kv.add_sequence(i, 16 * 4)
            ids.append(i)
        shards = {kv.seqs[i].shard for i in ids}
        assert shards == {0, 1}  # hash spreads across both shards
        kv.free_sequences(ids)
        assert kv.free_pages() == 64
        for b in kv.buddies:
            b.check_invariants()

    def test_growth_stays_on_recorded_shard(self):
        kv = PagedKVManager(64, page_tokens=4, n_shards=4)
        assert kv.add_sequence(1, 4)
        shard = kv.seqs[1].shard
        for _ in range(20):
            assert kv.append_tokens(1, 1)
        s = kv.seqs[1]
        assert s.shard == shard
        lo = shard * kv.pages_per_shard
        assert all(
            lo <= p < lo + kv.pages_per_shard for r in s.runs for p in r
        )

    def test_fragmentation_reports_per_shard(self):
        kv = PagedKVManager(64, page_tokens=16, n_shards=4)
        assert kv.add_sequence(1, 16 * 4)
        f = kv.fragmentation()
        assert len(f["per_shard_free"]) == 4
        assert sum(f["per_shard_free"]) == f["free_pages"]
        assert f["largest_run"] == 16  # three shards still empty

    def test_invalid_shard_counts_rejected(self):
        with pytest.raises(ValueError):
            PagedKVManager(64, page_tokens=16, n_shards=3)
        with pytest.raises(ValueError):
            PagedKVManager(64, page_tokens=16, n_shards=0)

    def test_oversized_sequence_raises_not_false(self):
        """A request bigger than one shard can never be admitted — that
        must surface as an error, not as a retriable 'pool full'."""
        kv = PagedKVManager(64, page_tokens=1, n_shards=4)  # 16/shard
        with pytest.raises(ValueError):
            kv.add_sequence(1, 17)
        assert 1 not in kv.seqs
        assert kv.free_pages() == 64

    def test_engine_rejects_impossible_request_without_blocking(self):
        """An unadmittable request must not head-of-line block the
        engine: it is rejected and the queue behind it still serves."""
        cfg = get_config("stablelm-3b").reduced()
        params = init_params(cfg, KEY)
        eng = ServeEngine(
            cfg, params, num_pages=16, page_tokens=4, max_batch=4,
            dtype=jnp.float32, n_shards=2,
        )
        rng = np.random.default_rng(12)
        # needs ceil(40/4)=10 pages -> run of 16 > 8 per shard
        eng.submit(Request(0, rng.integers(0, 200, 30).astype(np.int32), 10))
        eng.submit(Request(1, rng.integers(0, 200, 4).astype(np.int32), 3))
        eng.run_to_completion(max_steps=100)
        assert eng.stats["rejected"] == 1
        assert not eng.completed[0].out_tokens  # rejected, never decoded
        assert len(eng.completed[1].out_tokens) == 3
        assert eng.kv.free_pages() == 16


class TestServeEngine:
    def _engine(self, **kw):
        cfg = get_config("stablelm-3b").reduced()
        params = init_params(cfg, KEY)
        return cfg, params, ServeEngine(
            cfg, params, num_pages=64, page_tokens=4, max_batch=4,
            dtype=jnp.float32, **kw
        )

    def test_run_to_completion_and_full_release(self):
        _, _, eng = self._engine()
        rng = np.random.default_rng(0)
        for i in range(6):
            eng.submit(Request(
                i,
                rng.integers(0, 200, size=int(rng.integers(3, 9))).astype(np.int32),
                max_new_tokens=5,
            ))
        eng.run_to_completion()
        assert len(eng.completed) == 6
        assert all(len(r.out_tokens) == 5 for r in eng.completed.values())
        assert eng.kv.free_pages() == 64  # everything coalesced back

    def test_paged_equals_dense_decode(self):
        cfg, params, eng = self._engine()
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
        lg, cache = prefill(
            cfg, params, {"tokens": jnp.asarray(prompt[None])},
            max_len=16, dtype=jnp.float32,
        )
        t0 = int(np.argmax(np.asarray(lg)[0]))
        lg_dense, _ = decode_step(
            cfg, params, cache, jnp.asarray([t0], jnp.int32),
            dtype=jnp.float32,
        )
        t1_dense = int(np.argmax(np.asarray(lg_dense)[0]))
        eng.submit(Request(0, prompt, max_new_tokens=2))
        eng.step()
        req = (list(eng.completed.values()) or list(eng.running.values()))[0]
        assert req.out_tokens[:2] == [t0, t1_dense]

    def test_continuous_batching_mixed_positions(self):
        _, _, eng = self._engine()
        rng = np.random.default_rng(2)
        eng.submit(Request(0, rng.integers(0, 200, 8).astype(np.int32), 6))
        eng.step()  # req 0 starts decoding
        eng.submit(Request(1, rng.integers(0, 200, 3).astype(np.int32), 4))
        eng.run_to_completion()
        assert len(eng.completed) == 2

    def test_sharded_engine_run_to_completion(self):
        """The engine on a 2-shard page pool serves and fully releases
        the same workload (sequences land on per-shard buddy trees)."""
        cfg = get_config("stablelm-3b").reduced()
        params = init_params(cfg, KEY)
        eng = ServeEngine(
            cfg, params, num_pages=64, page_tokens=4, max_batch=4,
            dtype=jnp.float32, n_shards=2,
        )
        rng = np.random.default_rng(9)
        for i in range(5):
            eng.submit(Request(
                i,
                rng.integers(0, 200, size=int(rng.integers(3, 9))).astype(np.int32),
                max_new_tokens=4,
            ))
        eng.run_to_completion()
        assert len(eng.completed) == 5
        assert eng.kv.free_pages() == 64
        for b in eng.kv.buddies:
            b.check_invariants()

    def test_queueing_under_memory_pressure(self):
        cfg = get_config("stablelm-3b").reduced()
        params = init_params(cfg, KEY)
        eng = ServeEngine(
            cfg, params, num_pages=16, page_tokens=4, max_batch=8,
            dtype=jnp.float32,
        )
        rng = np.random.default_rng(3)
        for i in range(6):
            eng.submit(Request(i, rng.integers(0, 200, 12).astype(np.int32), 8))
        eng.step()
        assert eng.stats["queued_full"] > 0  # admission control engaged
        eng.run_to_completion(max_steps=500)
        assert len(eng.completed) == 6  # but everyone eventually served


class TestMoEServing:
    def test_moe_engine(self):
        cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
        params = init_params(cfg, KEY)
        eng = ServeEngine(
            cfg, params, num_pages=32, page_tokens=4, max_batch=2,
            dtype=jnp.float32,
        )
        rng = np.random.default_rng(4)
        eng.submit(Request(0, rng.integers(0, 200, 5).astype(np.int32), 3))
        eng.run_to_completion()
        assert len(eng.completed) == 1


class TestLayoutKnob:
    """The tree-layout knob on the serving stack (docs/design.md §3):
    handles and the public API are unchanged — only the exported device
    pool config's state format differs."""

    def test_kv_manager_exports_device_pool_config(self):
        from repro.core.layout import BunchPacked, Unpacked

        kv = PagedKVManager(256, 16, n_shards=4)
        pcfg = kv.device_pool_config()
        assert isinstance(pcfg.tree.layout, Unpacked)
        assert pcfg.n_shards == 4
        assert pcfg.total_units == 256  # one unit per page

        kvp = PagedKVManager(256, 16, n_shards=4, layout="bunch-packed")
        pp = kvp.device_pool_config()
        assert isinstance(pp.tree.layout, BunchPacked)
        assert pp.tree.depth == pcfg.tree.depth
        assert pp.n_state_words * 4 <= pcfg.n_state_words
        # identical host behaviour: the knob never leaks into handles
        assert kvp.add_sequence(1, 64)
        assert kv.add_sequence(1, 64)
        assert kv.seqs[1].runs == kvp.seqs[1].runs

    def test_kv_manager_rejects_unknown_layout(self):
        with pytest.raises(ValueError):
            PagedKVManager(64, 16, layout="zip-packed")

    def test_device_admission_on_exported_config_matches_host(self):
        """Burst admission through the exported packed config returns
        the same (shard, page) handles as the unpacked one."""
        from repro.core.pool import pool_wavefront_alloc

        kv_u = PagedKVManager(128, 16, n_shards=2)
        kv_p = PagedKVManager(128, 16, n_shards=2, layout="bunch-packed")
        pu, pp = kv_u.device_pool_config(), kv_p.device_pool_config()
        K = 8
        lv = jnp.full(K, pu.tree.depth - 1, jnp.int32)  # 2-page runs
        ids = jnp.arange(K, dtype=jnp.int32)
        tu, nu, su, oku, _ = pool_wavefront_alloc(
            pu, pu.empty_trees(), lv, jnp.ones(K, bool), 64, ids
        )
        tp, np_, sp, okp, _ = pool_wavefront_alloc(
            pp, pp.empty_trees(), lv, jnp.ones(K, bool), 64, ids
        )
        assert (np.asarray(nu) == np.asarray(np_)).all()
        assert (np.asarray(su) == np.asarray(sp)).all()
        assert bool(oku.all()) and bool(okp.all())


# ---------------------------------------------------------------------------
# Jit-resident engine (serve/jit_engine.py, docs/design.md §8)
# ---------------------------------------------------------------------------


def _jit_engine(cfg, params, **kw):
    from repro.serve.jit_engine import JitServeEngine

    base = dict(
        num_pages=16, page_tokens=4, max_batch=4, max_lane_pages=8,
        max_out=16, dtype=jnp.float32,
    )
    base.update(kw)
    return JitServeEngine(cfg, params, **base)


def _trace(seed, vocab, n=8, max_prompt=14, max_new=8):
    rng = np.random.default_rng(seed)
    return [
        (
            i,
            rng.integers(
                0, vocab, size=int(rng.integers(1, max_prompt))
            ).astype(np.int32),
            int(rng.integers(1, max_new)),
        )
        for i in range(n)
    ]


class TestJitServeEngine:
    def _setup(self):
        cfg = get_config("stablelm-3b").reduced()
        return cfg, init_params(cfg, KEY)

    @pytest.mark.parametrize(
        "n_shards,layout,chunk",
        [(1, "unpacked", 1), (2, "unpacked", 1), (2, "bunch-packed", 4)],
    )
    def test_differential_vs_host_oracle(self, n_shards, layout, chunk):
        """The compiled step must match the host-driven oracle replay of
        the same trace: identical page assignments while running,
        identical retirement order/steps, identical final occupancy.
        (eos=None, so scheduling is independent of token values.)"""
        from repro.serve.oracle import HostOracleEngine

        cfg, params = self._setup()
        eng = _jit_engine(cfg, params, n_shards=n_shards, layout=layout)
        orc = HostOracleEngine(
            num_pages=16, page_tokens=4, max_batch=4, max_lane_pages=8,
            max_out=16, n_shards=n_shards,
        )
        for i, p, mn in _trace(n_shards * 7 + chunk, cfg.vocab_size):
            eng.submit(Request(i, p, mn))
            orc.submit(Request(i, p.copy(), mn))
        for _ in range(100):
            eng._drain(), eng._admit()
            orc._drain(), orc._admit()
            assert sorted(eng.running) == sorted(orc.running)
            if not eng.running and not eng.waiting:
                break
            for sid in eng.running:  # page-for-page table equality
                assert (
                    eng.device_block_table(sid) == orc.block_table(sid)
                ).all(), sid
            assert eng.device_free_pages() == orc.free_pages()
            eng.decode_steps(chunk, fused=chunk > 1)
            orc.decode_steps(chunk)
        assert eng.retired_order == orc.retired_order
        assert eng.done_steps == orc.done_steps
        assert len(eng.completed) == 8
        # final pool occupancy: fully coalesced on both sides, per shard
        assert eng.device_free_pages() == orc.free_pages() == 16
        from repro.core.pool import pool_free_units

        per_shard = np.asarray(
            pool_free_units(eng.ecfg.pool_config(), eng.state.trees)
        )
        assert per_shard.tolist() == orc.pool.per_shard_free()
        orc.pool.check_invariants()

    def test_matches_dense_greedy_decode(self):
        """End-to-end model correctness: the engine's generated tokens
        equal dense greedy decoding of the same prompt (prefill KV was
        scattered to the right page/slot addresses, in-graph argmax and
        the paged attention consume them coherently)."""
        cfg, params = self._setup()
        rng = np.random.default_rng(5)
        prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
        lg, cache = prefill(
            cfg, params, {"tokens": jnp.asarray(prompt[None])},
            max_len=16, dtype=jnp.float32,
        )
        want = [int(np.argmax(np.asarray(lg)[0]))]
        for _ in range(3):
            lg, cache = decode_step(
                cfg, params, cache, jnp.asarray([want[-1]], jnp.int32),
                dtype=jnp.float32,
            )
            want.append(int(np.argmax(np.asarray(lg)[0])))
        eng = _jit_engine(cfg, params)
        eng.submit(Request(0, prompt, max_new_tokens=4))
        eng.run_to_completion(max_steps=20)
        assert eng.completed[0].out_tokens == want

    def test_single_trace_no_recompile_no_transfer(self):
        """The acceptance gate: after warmup, N compiled steps re-trace
        nothing and move no data between host and device."""
        from repro.serve import jit_engine as je

        cfg, params = self._setup()
        eng = _jit_engine(cfg, params)
        rng = np.random.default_rng(6)
        for i in range(3):
            eng.submit(Request(
                i, rng.integers(0, cfg.vocab_size, 5).astype(np.int32), 12
            ))
        eng._admit()
        eng.decode_steps(1)  # warmup: compile engine_step once
        traced = je.TRACE_COUNTS[eng.ecfg]
        with jax.transfer_guard("disallow"):
            eng.decode_steps(8)
        assert je.TRACE_COUNTS[eng.ecfg] == traced  # zero re-traces
        # the scan-fused chunk path compiles its own executable once,
        # then is likewise stable
        eng.decode_steps(2, fused=True)
        traced = je.TRACE_COUNTS[eng.ecfg]
        with jax.transfer_guard("disallow"):
            eng.decode_steps(2, fused=True)
        assert je.TRACE_COUNTS[eng.ecfg] == traced

    def test_rejects_oversized_without_blocking(self):
        """PR-1 hardening holds in the jitted path: an impossible
        request is rejected at admission, never head-of-line blocks,
        and the queue behind it still serves."""
        cfg, params = self._setup()
        eng = _jit_engine(cfg, params, max_lane_pages=4)
        rng = np.random.default_rng(12)
        # 30 prompt + 10 out = 40 tokens -> 10 pages > 4 lane pages
        eng.submit(Request(0, rng.integers(0, 200, 30).astype(np.int32), 10))
        eng.submit(Request(1, rng.integers(0, 200, 4).astype(np.int32), 3))
        eng.run_to_completion(max_steps=100)
        assert eng.stats["rejected"] == 1
        assert not eng.completed[0].out_tokens  # rejected, never decoded
        assert len(eng.completed[1].out_tokens) == 3
        assert eng.device_free_pages() == 16

    def test_overflow_retirement_matches_oracle(self):
        """Pool exhaustion mid-decode retires the losing lane in-graph
        (burst-freeing its pages) instead of deadlocking — and the
        oracle agrees on who lost and when."""
        from repro.serve.oracle import HostOracleEngine

        cfg, params = self._setup()
        kw = dict(num_pages=4, page_tokens=2, max_batch=2,
                  max_lane_pages=4, max_out=8)
        eng = _jit_engine(cfg, params, **{**kw, "dtype": jnp.float32})
        orc = HostOracleEngine(**kw)
        rng = np.random.default_rng(7)
        for i in range(2):  # 2 lanes x 4 lifetime pages > 4-page pool
            p = rng.integers(0, cfg.vocab_size, 3).astype(np.int32)
            eng.submit(Request(i, p, 5))
            orc.submit(Request(i, p.copy(), 5))
        eng.run_to_completion(max_steps=60)
        orc.run_to_completion(max_steps=60)
        assert eng.stats["overflow_retired"] >= 1
        assert eng.stats["overflow_retired"] == orc.stats["overflow_retired"]
        assert eng.retired_order == orc.retired_order
        assert eng.device_free_pages() == orc.free_pages() == 4

    def test_junk_handles_dropped_in_jitted_free(self):
        """PR-3 hardening holds in the leaf-only free path the engine
        retires through: out-of-geometry handles and double frees are
        dropped by the validity masks, never aliased onto live pages."""
        from repro.core.nbbs_jax import (
            nb_pool_alloc_pages, nb_pool_free_pages,
        )
        from repro.core.pool import pool_free_units

        cfg = get_config("stablelm-3b").reduced()  # unused; geometry only
        del cfg
        from repro.core.concurrent import TreeConfig, UNPACKED
        from repro.core.pool import PoolConfig

        pcfg = PoolConfig(TreeConfig(depth=3, max_level=0, layout=UNPACKED), 2)
        trees = pcfg.empty_trees()
        ids = jnp.arange(4, dtype=jnp.int32)
        trees, shard, off, ok, _ = nb_pool_alloc_pages(
            pcfg, trees, jnp.ones(4, bool), ids
        )
        assert bool(ok.all())
        # burst: 4 valid + junk shard + junk offset + duplicate handle
        shards = jnp.concatenate([shard, jnp.asarray([9, 0, shard[0]], jnp.int32)])
        offs = jnp.concatenate([off, jnp.asarray([0, 99, off[0]], jnp.int32)])
        trees, freed, _ = nb_pool_free_pages(
            pcfg, trees, shards, offs, jnp.ones(7, bool)
        )
        assert freed[:4].all()            # live handles freed
        assert not bool(freed[4:6].any())  # junk dropped by geometry mask
        # the duplicate raced its twin in the same burst: exactly one won
        assert int(pool_free_units(pcfg, trees).sum()) == 16  # all back
        # and a second burst of the now-stale handles is a no-op
        trees, freed2, _ = nb_pool_free_pages(
            pcfg, trees, shard, off, jnp.ones(4, bool)
        )
        assert not bool(freed2.any())
        assert int(pool_free_units(pcfg, trees).sum()) == 16

    def test_step_stats_accumulate(self):
        """Satellite observability: per-step stats come back from the
        compiled step and the shim accumulates them (pages allocated ==
        pages freed once everything retires, occupancy gauges land on
        the empty-pool values)."""
        cfg, params = self._setup()
        eng = _jit_engine(cfg, params, n_shards=2)
        rng = np.random.default_rng(8)
        for i in range(5):
            eng.submit(Request(
                i,
                rng.integers(0, cfg.vocab_size,
                             int(rng.integers(2, 10))).astype(np.int32),
                int(rng.integers(2, 6)),
            ))
        eng.run_to_completion(max_steps=100)
        tot = eng.stat_totals()
        assert tot["retired"] == 5
        assert tot["freed_pages"] >= tot["alloc_pages"] > 0
        assert tot["free_pages"] == 16 and tot["largest_run"] == 8
        assert tot["active_lanes"] == 0
        assert tot["merged_writes"] > 0 and tot["free_merged_writes"] > 0
