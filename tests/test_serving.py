"""Serving stack tests: NBBS page manager, continuous-batching engine,
paged-vs-dense decode equivalence, admission control, fragmentation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.memory.kv_cache import PagedKVManager
from repro.models import init_params, prefill, decode_step
from repro.serve.engine import Request, ServeEngine

KEY = jax.random.PRNGKey(0)


class TestPagedKVManager:
    def test_admission_and_release(self):
        kv = PagedKVManager(64, page_tokens=16)
        assert kv.add_sequence(1, 100)  # -> 7 pages -> run of 8
        assert kv.seqs[1].n_pages == 8
        assert kv.free_pages() == 56
        kv.free_sequence(1)
        assert kv.free_pages() == 64

    def test_block_table_contiguous_runs(self):
        kv = PagedKVManager(64, page_tokens=16)
        kv.add_sequence(1, 64)  # 4 pages, one buddy run
        bt = kv.block_table(1, 8)
        run = bt[bt >= 0]
        assert len(run) == 4
        assert (np.diff(run) == 1).all()  # buddy contiguity

    def test_growth_by_doubling(self):
        kv = PagedKVManager(64, page_tokens=4)
        kv.add_sequence(1, 4)  # 1 page
        for _ in range(12):
            assert kv.append_tokens(1, 1)
        s = kv.seqs[1]
        assert s.n_pages >= kv.pages_for_tokens(s.n_tokens)
        # O(log T) runs
        assert len(s.runs) <= 4

    def test_admission_control_when_full(self):
        kv = PagedKVManager(16, page_tokens=16)
        assert kv.add_sequence(1, 16 * 12)
        assert not kv.add_sequence(2, 16 * 8)  # would exceed pool
        assert 2 not in kv.seqs  # rollback left no partial allocation
        kv.free_sequence(1)
        assert kv.add_sequence(2, 16 * 8)

    def test_append_tokens_failure_rolls_back_partial_growth(self):
        """Regression: a failed grow must release runs appended by earlier
        iterations of the same call — a partially grown sequence would
        leak pages the token count never accounts for."""
        kv = PagedKVManager(16, page_tokens=1, max_run_pages=2)
        assert kv.add_sequence(1, 2)          # one run of 2 pages
        assert kv.add_sequence(2, 8)          # 4 runs of 2
        assert kv.add_sequence(3, 4)          # 2 runs of 2
        assert kv.free_pages() == 2
        # growing to 8 pages needs 3 more runs of 2; only one fits
        assert not kv.append_tokens(1, 6)
        s = kv.seqs[1]
        assert s.n_tokens == 2                # token rollback
        assert s.n_pages == 2                 # run rollback
        assert kv.free_pages() == 2           # nothing leaked
        # the sequence is still fully usable after the failed grow
        kv.free_sequence(2)
        kv.free_sequence(3)
        assert kv.append_tokens(1, 6)
        assert kv.seqs[1].n_pages >= kv.pages_for_tokens(8)

    def test_free_sequences_batch_release(self):
        kv = PagedKVManager(64, page_tokens=16)
        for i in range(4):
            assert kv.add_sequence(i, 16 * 4)
        kv.free_sequences([0, 2])
        assert kv.free_pages() == 56
        assert set(kv.seqs) == {1, 3}
        kv.free_sequences([1, 3])
        assert kv.free_pages() == 64
        kv.buddy.check_invariants()

    def test_free_sequences_unknown_id_leaves_state_intact(self):
        kv = PagedKVManager(64, page_tokens=16)
        for i in range(2):
            assert kv.add_sequence(i, 16 * 4)
        with pytest.raises(KeyError):
            kv.free_sequences([0, 99])
        # nothing was popped or freed: the batch validates before mutating
        assert set(kv.seqs) == {0, 1}
        assert kv.free_pages() == 56
        kv.free_sequences([0, 0, 1])  # duplicates collapse
        assert kv.free_pages() == 64

    def test_fragmentation_stats(self):
        kv = PagedKVManager(64, page_tokens=16)
        ids = []
        for i in range(8):
            kv.add_sequence(i, 16 * 4)  # 4 pages each
            ids.append(i)
        for i in ids[::2]:
            kv.free_sequence(i)
        f = kv.fragmentation()
        assert f["used_pages"] == 16
        assert f["largest_run"] >= 4
        # buddy coalescing: freeing neighbours re-creates large runs
        for i in ids[1::2]:
            kv.free_sequence(i)
        assert kv.fragmentation()["largest_run"] == 64


class TestServeEngine:
    def _engine(self, **kw):
        cfg = get_config("stablelm-3b").reduced()
        params = init_params(cfg, KEY)
        return cfg, params, ServeEngine(
            cfg, params, num_pages=64, page_tokens=4, max_batch=4,
            dtype=jnp.float32, **kw
        )

    def test_run_to_completion_and_full_release(self):
        _, _, eng = self._engine()
        rng = np.random.default_rng(0)
        for i in range(6):
            eng.submit(Request(
                i,
                rng.integers(0, 200, size=int(rng.integers(3, 9))).astype(np.int32),
                max_new_tokens=5,
            ))
        eng.run_to_completion()
        assert len(eng.completed) == 6
        assert all(len(r.out_tokens) == 5 for r in eng.completed.values())
        assert eng.kv.free_pages() == 64  # everything coalesced back

    def test_paged_equals_dense_decode(self):
        cfg, params, eng = self._engine()
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, cfg.vocab_size, size=6).astype(np.int32)
        lg, cache = prefill(
            cfg, params, {"tokens": jnp.asarray(prompt[None])},
            max_len=16, dtype=jnp.float32,
        )
        t0 = int(np.argmax(np.asarray(lg)[0]))
        lg_dense, _ = decode_step(
            cfg, params, cache, jnp.asarray([t0], jnp.int32),
            dtype=jnp.float32,
        )
        t1_dense = int(np.argmax(np.asarray(lg_dense)[0]))
        eng.submit(Request(0, prompt, max_new_tokens=2))
        eng.step()
        req = (list(eng.completed.values()) or list(eng.running.values()))[0]
        assert req.out_tokens[:2] == [t0, t1_dense]

    def test_continuous_batching_mixed_positions(self):
        _, _, eng = self._engine()
        rng = np.random.default_rng(2)
        eng.submit(Request(0, rng.integers(0, 200, 8).astype(np.int32), 6))
        eng.step()  # req 0 starts decoding
        eng.submit(Request(1, rng.integers(0, 200, 3).astype(np.int32), 4))
        eng.run_to_completion()
        assert len(eng.completed) == 2

    def test_queueing_under_memory_pressure(self):
        cfg = get_config("stablelm-3b").reduced()
        params = init_params(cfg, KEY)
        eng = ServeEngine(
            cfg, params, num_pages=16, page_tokens=4, max_batch=8,
            dtype=jnp.float32,
        )
        rng = np.random.default_rng(3)
        for i in range(6):
            eng.submit(Request(i, rng.integers(0, 200, 12).astype(np.int32), 8))
        eng.step()
        assert eng.stats["queued_full"] > 0  # admission control engaged
        eng.run_to_completion(max_steps=500)
        assert len(eng.completed) == 6  # but everyone eventually served


class TestMoEServing:
    def test_moe_engine(self):
        cfg = get_config("phi3.5-moe-42b-a6.6b").reduced()
        params = init_params(cfg, KEY)
        eng = ServeEngine(
            cfg, params, num_pages=32, page_tokens=4, max_batch=2,
            dtype=jnp.float32,
        )
        rng = np.random.default_rng(4)
        eng.submit(Request(0, rng.integers(0, 200, 5).astype(np.int32), 3))
        eng.run_to_completion()
        assert len(eng.completed) == 1
