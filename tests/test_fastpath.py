"""Fast-path battery: the bitmap-slab front end (core/fastpath.py).

Differential contract: a fastpath pool must behave exactly like the
fallback-only pool on everything a caller can observe — per-lane
success/failure, total pages outstanding, drain-to-empty — while
serving fast-octave hits through the O(1) slab claim.  On *pure
leaf-octave* traffic the equivalence is bit-for-bit on addresses too:
the slab's find-first-zero order equals the plain pool's rank order
over the same leftmost leaves.

Runs as its own CI matrix cell (`-m fastpath`).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fastpath as fpmod
from repro.core.concurrent import BUNCH_PACKED, TreeConfig, UNPACKED
from repro.core.fastpath import FastPathConfig
from repro.core.pool import (
    PoolConfig,
    pool_free_units,
    pool_largest_run,
    pool_wavefront_alloc,
    pool_wavefront_free,
)

pytestmark = pytest.mark.fastpath

LAYOUTS = [("unpacked", UNPACKED), ("bunch-packed", BUNCH_PACKED)]
SHARDS = [1, 4]


def _pair(depth, S, layout, slab_level=2):
    """(fastpath pool, plain pool) over identical tree geometry."""
    tree = TreeConfig(depth=depth, layout=layout)
    fp = FastPathConfig(level=None, slab_level=slab_level)
    return PoolConfig(tree, S, fastpath=fp), PoolConfig(tree, S)


def _alloc(pcfg, trees, levels, lane_ids):
    K = len(levels)
    return pool_wavefront_alloc(
        pcfg,
        trees,
        jnp.asarray(levels, jnp.int32),
        jnp.ones(K, bool),
        64,
        jnp.asarray(lane_ids, jnp.int32),
    )


class TestFastPathConfig:
    def test_validation(self):
        tree = TreeConfig(depth=3)
        with pytest.raises(ValueError):
            PoolConfig(tree, 1, fastpath=FastPathConfig(slab_level=0))
        with pytest.raises(ValueError):
            PoolConfig(tree, 1, fastpath=FastPathConfig(slab_level=4))
        with pytest.raises(ValueError):
            PoolConfig(tree, 1, fastpath=FastPathConfig(level=1, slab_level=2))
        with pytest.raises(ValueError):
            # slab shallower than max_level: its slots are unservable
            PoolConfig(
                TreeConfig(depth=4, max_level=3),
                1,
                fastpath=FastPathConfig(slab_level=2),
            )

    def test_geometry(self):
        tree = TreeConfig(depth=5)
        fp = FastPathConfig(level=None, slab_level=2)
        assert fpmod.fp_level(tree, fp) == 5  # None -> leaf octave
        assert fpmod.fp_carve_node(fp) == 4
        assert fpmod.fp_n_slots(tree, fp) == 8
        assert fpmod.fp_units_per_slot(tree, fp) == 1
        pcfg = PoolConfig(tree, 2, fastpath=fp)
        trees = pcfg.empty_trees()
        # carved baseline: every slab slot free, tree minus the subtree
        assert int(pool_free_units(pcfg, trees).sum()) == 64
        for row in np.asarray(trees):
            slab = jnp.asarray(row[tree.n_state_words:])
            assert int(fpmod.slab_free_slots(tree, fp, slab)) == 8


class TestFastPathDifferential:
    """The fastpath pool vs the fallback-only pool on shared traces."""

    @pytest.mark.parametrize("S", SHARDS)
    @pytest.mark.parametrize("name,layout", LAYOUTS)
    def test_pure_leaf_traffic_is_address_identical(self, name, layout, S):
        depth = 5
        fpc, plain = _pair(depth, S, layout)
        ta, tb = fpc.empty_trees(), plain.empty_trees()
        rng = np.random.default_rng(S)
        live = []  # (node, shard), identical in both pools
        hits = 0
        for step in range(8):
            K = int(rng.integers(4, 12))
            lv = [depth] * K
            ids = rng.integers(0, 100, K)
            ta, na, sa, oka, st_a = _alloc(fpc, ta, lv, ids)
            tb, nb, sb, okb, st_b = _alloc(plain, tb, lv, ids)
            assert (np.asarray(oka) == np.asarray(okb)).all()
            assert (np.asarray(na) == np.asarray(nb)).all()  # addresses
            assert (np.asarray(sa) == np.asarray(sb)).all()
            hits += int(st_a["fastpath_hits"])
            assert int(st_b["fastpath_hits"]) == 0
            live += [
                (int(n), int(s))
                for n, s, o in zip(np.asarray(na), np.asarray(sa),
                                   np.asarray(oka))
                if o
            ]
            if step % 3 == 2 and live:
                k = len(live) // 2
                rng.shuffle(live)
                drop, live = live[:k], live[k:]
                fn = jnp.asarray([n for n, _ in drop], jnp.int32)
                fs = jnp.asarray([s for _, s in drop], jnp.int32)
                act = jnp.ones(len(drop), bool)
                ta, fa, _ = pool_wavefront_free(fpc, ta, fn, fs, act)
                tb, fb, _ = pool_wavefront_free(plain, tb, fn, fs, act)
                assert bool(fa.all()) and bool(fb.all())
            assert int(pool_free_units(fpc, ta).sum()) == int(
                pool_free_units(plain, tb).sum()
            )
        assert hits > 0  # the slab actually served traffic
        # drain: both pools return to their empty baseline
        if live:
            fn = jnp.asarray([n for n, _ in live], jnp.int32)
            fs = jnp.asarray([s for _, s in live], jnp.int32)
            act = jnp.ones(len(live), bool)
            ta, fa, _ = pool_wavefront_free(fpc, ta, fn, fs, act)
            tb, fb, _ = pool_wavefront_free(plain, tb, fn, fs, act)
            assert bool(fa.all()) and bool(fb.all())
        assert (np.asarray(ta) == np.asarray(fpc.empty_trees())).all()
        assert (np.asarray(tb) == np.asarray(plain.empty_trees())).all()

    @pytest.mark.parametrize("S", SHARDS)
    @pytest.mark.parametrize("name,layout", LAYOUTS)
    def test_mixed_octave_capacity_equality(self, name, layout, S):
        """Mixed-octave traces: coarse requests spill around the carve,
        so addresses may differ, but per-lane success/failure and total
        pages outstanding must match the fallback-only pool whenever
        coarse demand fits outside the slab (the carve-out trades
        leftmost coarse chunks for slab pages one-for-one in units)."""
        depth = 5
        fpc, plain = _pair(depth, S, layout)
        ta, tb = fpc.empty_trees(), plain.empty_trees()
        rng = np.random.default_rng(7 * S)
        live_a, live_b = [], []  # position-aligned (ok masks are equal)
        for step in range(10):
            K = int(rng.integers(3, 9))
            # mostly leaf traffic with some level-3/4 chunks: per shard
            # the non-leaf demand stays below the uncarved 3/4 subtree
            lv = [
                depth if rng.random() < 0.7 else int(rng.integers(3, depth))
                for _ in range(K)
            ]
            ids = rng.integers(0, 100, K)
            ta, na, sa, oka, st_a = _alloc(fpc, ta, lv, ids)
            tb, nb, sb, okb, st_b = _alloc(plain, tb, lv, ids)
            assert (np.asarray(oka) == np.asarray(okb)).all(), (name, S, step)
            for n, s, o in zip(np.asarray(na), np.asarray(sa),
                               np.asarray(oka)):
                if o:
                    live_a.append((int(n), int(s)))
            for n, s, o in zip(np.asarray(nb), np.asarray(sb),
                               np.asarray(okb)):
                if o:
                    live_b.append((int(n), int(s)))
            assert len(live_a) == len(live_b)
            assert int(pool_free_units(fpc, ta).sum()) == int(
                pool_free_units(plain, tb).sum()
            )
            if step % 4 == 3 and live_a:
                k = max(1, len(live_a) // 2)
                idx = rng.choice(len(live_a), size=k, replace=False)
                keep = [i for i in range(len(live_a)) if i not in set(idx)]
                for pool, trees_, live in (
                    (fpc, "a", live_a), (plain, "b", live_b)
                ):
                    drop = [live[i] for i in idx]
                    fn = jnp.asarray([n for n, _ in drop], jnp.int32)
                    fs = jnp.asarray([s for _, s in drop], jnp.int32)
                    act = jnp.ones(k, bool)
                    if trees_ == "a":
                        ta, fa, _ = pool_wavefront_free(pool, ta, fn, fs, act)
                        assert bool(fa.all())
                    else:
                        tb, fb, _ = pool_wavefront_free(pool, tb, fn, fs, act)
                        assert bool(fb.all())
                live_a = [live_a[i] for i in keep]
                live_b = [live_b[i] for i in keep]

    @pytest.mark.parametrize("name,layout", LAYOUTS)
    def test_slab_exhaustion_spills_into_the_climb(self, name, layout):
        """More leaf demand than slab slots: exactly n_slots requests
        hit, the rest spill into the buddy climb, everyone succeeds."""
        depth = 5
        fpc, _ = _pair(depth, 1, layout)
        n_slots = fpmod.fp_n_slots(fpc.tree, fpc.fastpath)
        K = n_slots + 10
        trees, nodes, _, ok, stats = _alloc(
            fpc, fpc.empty_trees(), [depth] * K, np.arange(K)
        )
        assert bool(ok.all())
        assert int(stats["fastpath_hits"]) == n_slots
        assert int(stats["fastpath_spills"]) == K - n_slots
        assert len(set(np.asarray(nodes).tolist())) == K  # no aliasing

    @pytest.mark.parametrize("S", SHARDS)
    @pytest.mark.parametrize("name,layout", LAYOUTS)
    def test_full_fill_no_aliasing(self, name, layout, S):
        """Filling the pool page by page hands out every leaf offset of
        every shard exactly once — the slab and the tree can never serve
        the same page (the carve-out invariant)."""
        depth = 4
        fpc, _ = _pair(depth, S, layout)
        per = 1 << depth
        total = S * per
        trees, nodes, shard, ok, stats = _alloc(
            fpc, fpc.empty_trees(), [depth] * total, np.arange(total)
        )
        assert bool(ok.all())
        pages = sorted(
            int(s) * per + int(n) - per
            for n, s in zip(np.asarray(nodes), np.asarray(shard))
        )
        assert pages == list(range(total))
        assert int(pool_free_units(fpc, trees).sum()) == 0
        # one more request must fail cleanly
        _, _, _, ok1, _ = _alloc(fpc, trees, [depth], [0])
        assert not bool(ok1[0])

    def test_stats_keys_always_present(self):
        tree = TreeConfig(depth=4)
        plain = PoolConfig(tree, 1)
        _, _, _, _, stats = _alloc(plain, plain.empty_trees(), [4, 4], [0, 1])
        assert int(stats["fastpath_hits"]) == 0
        assert int(stats["fastpath_spills"]) == 0

    def test_largest_run_sees_the_slab(self):
        fpc, _ = _pair(4, 1, UNPACKED)
        trees = fpc.empty_trees()
        # empty carved pool: largest tree run is 3/4 of the shard
        assert int(pool_largest_run(fpc, trees)) == 8
        # fill everything, then release one slab page: the only free
        # capacity is a slab slot and largest_run must report it
        trees, nodes, _, ok, _ = _alloc(fpc, trees, [4] * 16, np.arange(16))
        assert bool(ok.all())
        assert int(pool_largest_run(fpc, trees)) == 0
        slab_leaf = int(np.asarray(nodes).min())  # leftmost leaf = slab
        trees, freed, _ = pool_wavefront_free(
            fpc, trees, jnp.asarray([slab_leaf], jnp.int32),
            jnp.zeros(1, jnp.int32), jnp.ones(1, bool),
        )
        assert bool(freed.all())
        assert int(pool_free_units(fpc, trees).sum()) == 1
        assert int(pool_largest_run(fpc, trees)) == 1


class TestFastPathKernelParity:
    """The Pallas pool kernel (interpret mode) against the reference
    router on fastpath pools — slab words travel inside the VMEM row."""

    @pytest.mark.parametrize("S", SHARDS)
    @pytest.mark.parametrize("name,layout", LAYOUTS)
    def test_step_parity(self, name, layout, S):
        from repro.kernels.ops import nbbs_pool_wavefront_step

        depth = 4
        fpc, _ = _pair(depth, S, layout)
        trees0 = fpc.empty_trees()
        K = 10
        lv = jnp.full((K,), depth, jnp.int32)
        ids = jnp.arange(K, dtype=jnp.int32)
        nf = jnp.zeros((K,), jnp.int32)
        sf = jnp.zeros((K,), jnp.int32)
        fa0 = jnp.zeros((K,), bool)
        out = {}
        for impl in ("reference", "interpret"):
            t, n, s, ok, st = nbbs_pool_wavefront_step(
                fpc, trees0, nf, sf, fa0, lv, lane_ids=ids, impl=impl
            )
            # mixed step: free half of what we just claimed, allocate more
            half = jnp.asarray([i % 2 == 0 for i in range(K)]) & ok
            t2, n2, s2, ok2, st2 = nbbs_pool_wavefront_step(
                fpc, t, n, s, half, lv, lane_ids=ids + K, impl=impl
            )
            out[impl] = (t2, n, ok, n2, ok2, st["fastpath_hits"],
                         st2["fastpath_hits"])
        for a, b in zip(out["reference"], out["interpret"]):
            assert (np.asarray(a) == np.asarray(b)).all()
        assert int(out["reference"][5]) > 0


class TestFastPathEngine:
    """Trace-replay regressions: the jit-resident engine with the
    fastpath on must stay step-exact vs the host oracle and vs itself
    with the fastpath off (same tokens, tables, retirements)."""

    @classmethod
    def setup_class(cls):
        from repro.configs import get_config
        from repro.models import init_params

        cls.cfg = get_config("stablelm-3b").reduced()
        cls.params = init_params(cls.cfg, jax.random.PRNGKey(0))

    def _engine(self, **kw):
        from repro.serve.jit_engine import JitServeEngine

        base = dict(
            num_pages=16, page_tokens=4, max_batch=4, max_lane_pages=8,
            max_out=16, dtype=jnp.float32,
        )
        base.update(kw)
        return JitServeEngine(self.cfg, self.params, **base)

    @staticmethod
    def _trace(seed, vocab, n=8):
        rng = np.random.default_rng(seed)
        return [
            (
                i,
                rng.integers(
                    0, vocab, size=int(rng.integers(1, 14))
                ).astype(np.int32),
                int(rng.integers(1, 8)),
            )
            for i in range(n)
        ]

    @pytest.mark.parametrize(
        "n_shards,layout", [(1, "unpacked"), (2, "bunch-packed")]
    )
    def test_matches_host_oracle_with_fastpath(self, n_shards, layout):
        from repro.serve.engine import Request
        from repro.serve.oracle import HostOracleEngine

        eng = self._engine(n_shards=n_shards, layout=layout, fastpath=True)
        orc = HostOracleEngine(
            num_pages=16, page_tokens=4, max_batch=4, max_lane_pages=8,
            max_out=16, n_shards=n_shards, fastpath=True,
        )
        for i, p, mn in self._trace(3 * n_shards, self.cfg.vocab_size):
            eng.submit(Request(i, p, mn))
            orc.submit(Request(i, p.copy(), mn))
        for _ in range(100):
            eng._drain(), eng._admit()
            orc._drain(), orc._admit()
            assert sorted(eng.running) == sorted(orc.running)
            if not eng.running and not eng.waiting:
                break
            for sid in eng.running:
                assert (
                    eng.device_block_table(sid) == orc.block_table(sid)
                ).all(), sid
            assert eng.device_free_pages() == orc.free_pages()
            eng.decode_steps(1)
            orc.decode_steps(1)
        assert eng.retired_order == orc.retired_order
        assert eng.done_steps == orc.done_steps
        assert eng.device_free_pages() == orc.free_pages() == 16
        tot = eng.stat_totals()
        assert tot["fastpath_hits"] == orc.pool.fastpath_hits > 0
        assert tot["fastpath_spills"] == orc.pool.fastpath_spills
        orc.pool.check_invariants()

    def test_fastpath_on_off_step_exact(self):
        """The fast path is a pure mechanism change: with it on or off
        the engine emits the same tokens, the same block tables, the
        same retirement steps (leaf traffic is address-identical)."""
        from repro.serve.engine import Request

        e_on = self._engine(n_shards=2, fastpath=True)
        e_off = self._engine(n_shards=2)
        for i, p, mn in self._trace(13, self.cfg.vocab_size):
            e_on.submit(Request(i, p, mn))
            e_off.submit(Request(i, p.copy(), mn))
        for _ in range(100):
            e_on._drain(), e_on._admit()
            e_off._drain(), e_off._admit()
            assert sorted(e_on.running) == sorted(e_off.running)
            if not e_on.running and not e_on.waiting:
                break
            for sid in e_on.running:
                assert (
                    e_on.device_block_table(sid)
                    == e_off.device_block_table(sid)
                ).all()
            assert e_on.device_free_pages() == e_off.device_free_pages()
            e_on.decode_steps(1)
            e_off.decode_steps(1)
        assert e_on.retired_order == e_off.retired_order
        assert e_on.done_steps == e_off.done_steps
        for sid in e_on.completed:
            assert (
                e_on.completed[sid].out_tokens
                == e_off.completed[sid].out_tokens
            )
        assert e_on.stat_totals()["fastpath_hits"] > 0
        assert e_off.stat_totals()["fastpath_hits"] == 0

    def test_slab_probe_adds_no_host_sync(self):
        """The fastpath decode loop stays transfer-free and re-trace-free
        (the slab probe lives inside the compiled step)."""
        from repro.serve import jit_engine as je
        from repro.serve.engine import Request

        eng = self._engine(fastpath=True)
        rng = np.random.default_rng(0)
        for i in range(3):
            eng.submit(Request(
                i, rng.integers(0, self.cfg.vocab_size, 6).astype(np.int32), 8
            ))
        eng._drain(), eng._admit()
        eng.decode_steps(1)  # compile outside the guard
        traced = je.TRACE_COUNTS[eng.ecfg]
        with jax.transfer_guard("disallow"):
            eng.decode_steps(8)
        assert je.TRACE_COUNTS[eng.ecfg] == traced  # zero re-traces
