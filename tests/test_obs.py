"""Telemetry-plane tests: the metric registry/slot orders (drift
guards), functional metric accumulation, the in-graph event ring,
the Chrome-trace exporter, the bench-artifact schema checker, and the
engine-level acceptance gates (telemetry on: still trace-once, still
transfer-free; stat totals exactly equal to the host oracle's)."""

import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import metrics as om
from repro.obs import ring as oring
from repro.obs import schema as osch
from repro.obs.trace_export import (
    SNAPSHOT_VERSION,
    chrome_trace,
    validate_snapshot,
    validate_trace,
)

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# schema: the single catalogue + positional slot orders
# ---------------------------------------------------------------------------


class TestSchema:
    def test_unregistered_name_raises_with_guidance(self):
        with pytest.raises(KeyError, match="obs/schema.py"):
            osch.spec("definitely_not_a_metric")

    def test_registry_entries_are_well_formed(self):
        for name, s in osch.REGISTRY.items():
            assert s.name == name
            assert s.kind in ("counter", "gauge", "histogram", "derived")
            if s.kind == "histogram":
                assert s.buckets, name
                assert list(s.buckets) == sorted(s.buckets), name
                assert s.n_slots == len(s.buckets) + 1  # overflow slot

    def test_kernel_slot_orders_are_locked(self):
        """The positional stat rows the Pallas kernels emit: width and
        order are load-bearing (producers pack, consumers unpack by
        these tuples).  Reordering or renaming must fail loudly here,
        not silently misattribute counters."""
        assert osch.WAVEFRONT_ALLOC_SLOTS == (
            "rounds", "merged_writes", "logical_rmws",
        )
        assert osch.WAVEFRONT_STEP_SLOTS == (
            "rounds", "merged_writes", "logical_rmws",
            "free_merged_writes", "free_logical_rmws", "freed",
        )
        assert osch.POOL_STEP_SLOTS == osch.WAVEFRONT_STEP_SLOTS + (
            "fastpath_hits", "magazine_hits", "magazine_spills",
            "magazine_refills",
        )
        for slots in (osch.WAVEFRONT_ALLOC_SLOTS,
                      osch.WAVEFRONT_STEP_SLOTS, osch.POOL_STEP_SLOTS):
            for name in slots:
                osch.spec(name)  # every slot is a registered metric

    def test_pack_unpack_roundtrip(self):
        slots = osch.POOL_STEP_SLOTS
        vals = {n: jnp.int32(10 + i) for i, n in enumerate(slots)}
        rowv = osch.pack_slots(slots, vals)
        assert rowv.shape == (len(slots),)
        back = osch.unpack_slots(slots, rowv)
        for i, n in enumerate(slots):
            assert int(back[n]) == 10 + i

    def test_unpack_rejects_wrong_width(self):
        with pytest.raises(ValueError):
            osch.unpack_slots(
                osch.WAVEFRONT_STEP_SLOTS, jnp.zeros(3, jnp.int32)
            )


# ---------------------------------------------------------------------------
# metrics: functional accumulation by registered kind
# ---------------------------------------------------------------------------


class TestMetrics:
    def test_zeros_shapes(self):
        m = om.zeros(
            ("merged_writes", "free_pages_shard", "alloc_rounds_hist"),
            vector_lens={"free_pages_shard": 4},
        )
        assert m["merged_writes"].shape == ()
        assert m["free_pages_shard"].shape == (4,)
        n = osch.spec("alloc_rounds_hist").n_slots
        assert m["alloc_rounds_hist"].shape == (n,)

    def test_inc_counter_sums_gauge_overwrites(self):
        m = om.zeros(("merged_writes", "free_pages"))
        m = om.inc(m, "merged_writes", 3)
        m = om.inc(m, "merged_writes", 4)
        m = om.inc(m, "free_pages", 9)
        m = om.inc(m, "free_pages", 5)  # gauge: latest wins
        assert int(m["merged_writes"]) == 7
        assert int(m["free_pages"]) == 5

    def test_observe_buckets_and_overflow(self):
        # alloc_rounds_hist buckets (0, 1, 2, 4, 8, 16, 32): bucket i
        # counts value <= edge[i]; beyond the last edge -> overflow slot
        m = om.zeros(("alloc_rounds_hist",))
        for v in (0, 1, 3, 100):
            m = om.observe(m, "alloc_rounds_hist", v)
        counts = [int(x) for x in m["alloc_rounds_hist"]]
        edges = osch.spec("alloc_rounds_hist").buckets
        assert counts[edges.index(0)] == 1
        assert counts[edges.index(1)] == 1
        assert counts[edges.index(4)] == 1  # 3 -> first edge >= 3
        assert counts[-1] == 1              # 100 -> overflow
        assert sum(counts) == 4

    def test_observe_on_counter_raises(self):
        m = om.zeros(("merged_writes",))
        with pytest.raises(ValueError, match="not a histogram"):
            om.observe(m, "merged_writes", 1)

    def test_observe_many_masks_out_lanes(self):
        m = om.zeros(("probe_distance_hist",))
        vals = jnp.asarray([0, 1, 2, 7], jnp.int32)
        mask = jnp.asarray([True, False, True, True])
        m = om.observe_many(m, "probe_distance_hist", vals, mask)
        assert int(m["probe_distance_hist"].sum()) == 3  # masked lane dropped

    def test_merge_by_kind_and_drift_guard(self):
        a = om.zeros(("merged_writes", "free_pages"))
        a = om.inc(a, "merged_writes", 2)
        a = om.inc(a, "free_pages", 10)
        b = om.zeros(("merged_writes", "free_pages"))
        b = om.inc(b, "merged_writes", 5)
        b = om.inc(b, "free_pages", 6)
        out = om.merge(a, b)
        assert int(out["merged_writes"]) == 7  # counter: sum
        assert int(out["free_pages"]) == 6     # gauge: new wins
        with pytest.raises(ValueError, match="metric key drift"):
            om.merge(a, om.zeros(("merged_writes",)))

    def test_reduce_trajectory(self):
        traj = {
            "merged_writes": jnp.asarray([1, 2, 3], jnp.int32),
            "free_pages": jnp.asarray([9, 7, 5], jnp.int32),
            "alloc_rounds_hist": jnp.ones(
                (3, osch.spec("alloc_rounds_hist").n_slots), jnp.int32
            ),
        }
        tot = om.reduce_trajectory(traj)
        assert int(tot["merged_writes"]) == 6
        assert int(tot["free_pages"]) == 5  # gauge: final step
        assert int(tot["alloc_rounds_hist"].sum()) == 3 * osch.spec(
            "alloc_rounds_hist"
        ).n_slots

    def test_accumulates_inside_scan(self):
        """The point of the functional design: metrics are a scan carry."""
        def body(m, x):
            m = om.inc(m, "merged_writes", x)
            m = om.observe(m, "alloc_rounds_hist", x)
            return m, ()

        @jax.jit
        def run(xs):
            m0 = om.zeros(("merged_writes", "alloc_rounds_hist"))
            m, _ = jax.lax.scan(body, m0, xs)
            return m

        m = run(jnp.asarray([1, 2, 3, 4], jnp.int32))
        assert int(m["merged_writes"]) == 10
        assert int(m["alloc_rounds_hist"].sum()) == 4

    def test_to_host_and_host_counters(self):
        m = om.zeros(("merged_writes", "free_pages_shard"),
                     vector_lens={"free_pages_shard": 2})
        h = om.to_host(m)
        assert h == {"merged_writes": 0, "free_pages_shard": [0, 0]}
        hc = om.host_counters({"admitted": 3})
        assert int(hc["admitted"]) == 3
        with pytest.raises(KeyError):
            om.host_counters({"not_a_metric": 1})

    def test_hist_summary_labels(self):
        s = osch.spec("probe_distance_hist")
        lab = om.hist_summary(
            "probe_distance_hist", list(range(s.n_slots))
        )
        assert list(lab)[0] == f"<={s.buckets[0]}"
        assert list(lab)[-1] == "inf"
        assert lab["inf"] == s.n_slots - 1


# ---------------------------------------------------------------------------
# event ring
# ---------------------------------------------------------------------------


class TestEventRing:
    def test_push_drain_order(self):
        r = oring.make_ring(8)
        for i in range(3):
            r = oring.push(r, oring.event(oring.EV_STEP, step=i, rounds=i))
        evs = oring.drain(r)
        assert [e["step"] for e in evs] == [0, 1, 2]
        assert all(e["kind_name"] == "step" for e in evs)
        assert int(oring.dropped(r)) == 0

    def test_masked_push_is_a_noop(self):
        r = oring.make_ring(4)
        r = oring.push(r, oring.event(oring.EV_STEP, step=7), mask=False)
        assert int(r.count) == 0
        assert oring.drain(r) == []

    def test_overflow_drops_oldest(self):
        r = oring.make_ring(4)
        for i in range(6):
            r = oring.push(r, oring.event(oring.EV_STEP, step=i))
        assert int(oring.dropped(r)) == 2
        evs = oring.drain(r)
        assert [e["step"] for e in evs] == [2, 3, 4, 5]  # survivors, oldest first

    def test_push_many_exclusive_slots(self):
        r = oring.make_ring(8)
        rows = jnp.stack([
            oring.event(oring.EV_RETIRE, step=s) for s in range(4)
        ])
        mask = jnp.asarray([True, False, True, True])
        r = oring.push_many(r, rows, mask)
        evs = oring.drain(r)
        assert [e["step"] for e in evs] == [0, 2, 3]
        assert int(r.count) == 3

    def test_zero_capacity_counts_but_stores_nothing(self):
        r = oring.make_ring(0)
        r = oring.push(r, oring.event(oring.EV_STEP, step=1))
        rows = jnp.stack([oring.event(oring.EV_STEP, step=2)] * 2)
        r = oring.push_many(r, rows, jnp.asarray([True, True]))
        assert int(r.count) == 3
        assert oring.drain(r) == []
        assert int(oring.dropped(r)) == 3

    def test_event_rejects_unknown_field(self):
        with pytest.raises(KeyError, match="unknown event fields"):
            oring.event(oring.EV_STEP, bogus=1)

    def test_pushes_compile_inside_scan(self):
        def body(r, i):
            row = oring.event(oring.EV_STEP, step=i, lanes_won=i % 2)
            return oring.push(r, row, mask=i % 2 == 0), ()

        run = jax.jit(
            lambda r, xs: jax.lax.scan(body, r, xs)[0]
        )
        r2 = run(oring.make_ring(4), jnp.arange(6, dtype=jnp.int32))
        assert int(r2.count) == 3  # even steps only
        assert [e["step"] for e in oring.drain(r2)] == [0, 2, 4]


# ---------------------------------------------------------------------------
# trace exporter
# ---------------------------------------------------------------------------


def _synth_snapshot(n_steps=4):
    ring = oring.make_ring(16)
    for i in range(n_steps):
        ring = oring.push(ring, oring.event(
            oring.EV_STEP, step=i, lanes_won=1, rounds=2,
            free_pages=10 - i,
        ))
    return {
        "obs_schema": SNAPSHOT_VERSION,
        "source": "test",
        "config": {"num_pages": 16},
        "metrics": {"alloc_pages": n_steps, "free_pages": 10 - n_steps},
        "events": oring.drain(ring),
        "spans": [
            {"phase": "admit", "t0": 0.0, "t1": 0.01,
             "step0": 0, "step1": 0},
            {"phase": "decode", "t0": 0.01, "t1": 0.05,
             "step0": 0, "step1": n_steps},
        ],
    }


class TestTraceExport:
    def test_validate_snapshot_rejects_malformed(self):
        snap = _synth_snapshot()
        for key in ("obs_schema", "metrics", "events", "spans"):
            bad = {k: v for k, v in snap.items() if k != key}
            with pytest.raises(ValueError, match=key):
                validate_snapshot(bad)
        bad = dict(snap, metrics={"nope": 1})
        with pytest.raises(KeyError):
            validate_snapshot(bad)
        bad = dict(snap, spans=[{"phase": "x", "t0": 1.0, "t1": 0.5}])
        with pytest.raises(ValueError, match="ends before"):
            validate_snapshot(bad)

    def test_chrome_trace_renders_steps_and_counters(self):
        snap = _synth_snapshot(n_steps=4)
        trace = chrome_trace(snap)
        validate_trace(trace)
        evs = trace["traceEvents"]
        steps = [e for e in evs
                 if e["ph"] == "X" and e["name"].startswith("step ")]
        assert len(steps) == 4
        # each step carries schematic alloc/decode/retire sub-spans on
        # the device-steps thread (tid 2; the host loop is tid 1)
        subs = [e for e in evs if e["ph"] == "X" and e["tid"] == 2
                and e["name"] in ("alloc", "decode", "retire")]
        assert len(subs) == 12
        counters = [e for e in evs if e["ph"] == "C"]
        assert {e["name"] for e in counters} == {"free_pages", "lanes_won"}
        # occupancy counter replays the ring's free_pages series
        fp = [e["args"]["free_pages"] for e in counters
              if e["name"] == "free_pages"]
        assert fp == [10, 9, 8, 7]

    def test_steps_outside_decode_windows_are_skipped(self):
        snap = _synth_snapshot(n_steps=4)
        snap["spans"] = [s for s in snap["spans"]
                         if s["phase"] != "decode"]
        trace = chrome_trace(snap)  # no wall-clock window: no step spans
        assert not [e for e in trace["traceEvents"]
                    if e["ph"] == "X" and e["name"].startswith("step ")]


# ---------------------------------------------------------------------------
# bench-artifact schema
# ---------------------------------------------------------------------------


class TestBenchSchema:
    def _checker(self):
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        sys.path.insert(0, os.path.join(root, "tools"))
        try:
            import check_bench_schema
        finally:
            sys.path.pop(0)
        return check_bench_schema

    def test_bench_record_rejects_unregistered_metric(self):
        from benchmarks.common import bench_envelope, bench_record

        with pytest.raises(KeyError, match="obs/schema.py"):
            bench_record(dims={}, metrics={"made_up": 1})
        env = bench_envelope(
            "t", {"w": 1},
            [bench_record(dims={"n_shards": 1},
                          metrics={"merged_writes": 3})],
            extra_summary={"anything": True},
        )
        assert env["schema_version"] == 1
        assert env["extra_summary"] == {"anything": True}

    def test_checker_accepts_envelope_rejects_drift(self, tmp_path):
        cbs = self._checker()
        good = {
            "schema_version": 1, "benchmark": "t", "config": {},
            "records": [{"dims": {"s": 1},
                         "metrics": {"merged_writes": 2.0,
                                     "free_pages_shard": [1, 2]}}],
        }
        p = tmp_path / "BENCH_T.json"
        p.write_text(json.dumps(good))
        assert cbs.check_file(str(p)) == []
        for mutate in (
            lambda d: d.update(schema_version=2),
            lambda d: d.pop("benchmark"),
            lambda d: d["records"][0]["metrics"].update(bogus=1),
            lambda d: d["records"][0]["metrics"].update(
                merged_writes="three"
            ),
            lambda d: d["records"][0]["dims"].update(t=[1, 2]),
            lambda d: d.update(records=[]),
        ):
            bad = json.loads(json.dumps(good))
            mutate(bad)
            p.write_text(json.dumps(bad))
            assert cbs.check_file(str(p)), mutate

    def test_checker_validates_snapshots_too(self, tmp_path):
        cbs = self._checker()
        p = tmp_path / "BENCH_SNAP.json"
        p.write_text(json.dumps(_synth_snapshot()))
        assert cbs.check_file(str(p)) == []
        bad = _synth_snapshot()
        bad["metrics"] = {"invented": 1}
        p.write_text(json.dumps(bad))
        assert cbs.check_file(str(p))


# ---------------------------------------------------------------------------
# engine-level acceptance gates (telemetry plane on)
# ---------------------------------------------------------------------------


def _engine(cfg, params, **kw):
    from repro.serve.jit_engine import JitServeEngine

    base = dict(
        num_pages=16, page_tokens=4, max_batch=4, max_lane_pages=8,
        max_out=16, dtype=jnp.float32,
    )
    base.update(kw)
    return JitServeEngine(cfg, params, **base)


class TestEngineTelemetry:
    @pytest.fixture(scope="class")
    def setup(self):
        from repro.configs import get_config
        from repro.models import init_params

        cfg = get_config("stablelm-3b").reduced()
        return cfg, init_params(cfg, KEY)

    def _submit_trace(self, eng, vocab, n=6, seed=3):
        from repro.serve.engine import Request

        rng = np.random.default_rng(seed)
        for i in range(n):
            p = rng.integers(
                0, vocab, size=int(rng.integers(1, 10))
            ).astype(np.int32)
            eng.submit(Request(i, p, int(rng.integers(1, 6))))

    def test_telemetry_on_is_trace_once_and_transfer_free(self, setup):
        """The acceptance gate with the full plane enabled: metrics
        dict + event ring + histograms add zero re-traces and zero
        host<->device transfers to the steady decode loop."""
        from repro.serve import jit_engine as je

        cfg, params = setup
        eng = _engine(cfg, params, ring_capacity=32)
        self._submit_trace(eng, cfg.vocab_size)
        eng._admit()
        eng.decode_steps(1)          # warmup: compile engine_step
        eng.decode_steps(2, fused=True)  # warmup: compile fused chunk
        traced = je.TRACE_COUNTS[eng.ecfg]
        with jax.transfer_guard("disallow"):
            eng.decode_steps(4)
            eng.decode_steps(2, fused=True)
        assert je.TRACE_COUNTS[eng.ecfg] == traced  # zero re-traces

    def test_ring_records_engine_steps(self, setup):
        cfg, params = setup
        eng = _engine(cfg, params, ring_capacity=64)
        self._submit_trace(eng, cfg.vocab_size)
        eng.run_to_completion(max_steps=40)
        evs = oring.drain(eng.state.ring)
        assert evs, "active steps must be recorded"
        steps = [e["step"] for e in evs]
        assert steps == sorted(steps)
        assert all(e["kind_name"] == "step" for e in evs)
        tot = eng.stat_totals()
        assert tot["ring_events"] == int(eng.state.ring.count)
        assert tot["ring_dropped"] == 0
        # ring free_pages gauge agrees with the drained occupancy tail
        assert evs[-1]["free_pages"] == tot["free_pages"]

    def test_ring_overflow_reports_drops(self, setup):
        cfg, params = setup
        eng = _engine(cfg, params, ring_capacity=4)
        self._submit_trace(eng, cfg.vocab_size)
        eng.run_to_completion(max_steps=40)
        tot = eng.stat_totals()
        assert tot["ring_events"] > 4
        assert tot["ring_dropped"] == tot["ring_events"] - 4
        assert len(oring.drain(eng.state.ring)) == 4

    def test_negative_ring_capacity_rejected(self, setup):
        cfg, params = setup
        with pytest.raises(ValueError, match="ring_capacity"):
            _engine(cfg, params, ring_capacity=-1)

    def test_stat_totals_exactly_match_host_oracle(self, setup):
        """Satellite #2: host admission counters and device step metrics
        route through ONE schema-checked merge, so the engine's totals
        equal the oracle's — including the slab fastpath split across
        admission (host) and in-step (device) traffic."""
        from repro.serve.engine import Request
        from repro.serve.oracle import HostOracleEngine

        cfg, params = setup
        kw = dict(num_pages=16, page_tokens=4, max_batch=4,
                  max_lane_pages=8, max_out=16)
        eng = _engine(cfg, params, fastpath=True, ring_capacity=16, **kw)
        orc = HostOracleEngine(fastpath=True, **kw)
        rng = np.random.default_rng(11)
        for i in range(8):
            p = rng.integers(
                0, cfg.vocab_size, size=int(rng.integers(1, 12))
            ).astype(np.int32)
            mn = int(rng.integers(1, 7))
            eng.submit(Request(i, p, mn))
            orc.submit(Request(i, p.copy(), mn))
        for _ in range(60):
            eng._drain(), eng._admit()
            orc._drain(), orc._admit()
            if not eng.running and not eng.waiting:
                break
            eng.decode_steps(2, fused=True)
            orc.decode_steps(2)
        etot, otot = eng.stat_totals(), orc.stat_totals()
        for key in otot:
            assert etot[key] == otot[key], (key, etot[key], otot[key])

    def test_snapshot_exports_a_valid_trace(self, setup):
        """Tentpole exit path: a real engine run's snapshot validates
        and renders as a loadable Chrome/Perfetto trace with per-step
        device spans inside the measured decode windows."""
        cfg, params = setup
        eng = _engine(cfg, params, ring_capacity=64)
        self._submit_trace(eng, cfg.vocab_size)
        eng.run_to_completion(max_steps=40)
        snap = eng.snapshot()
        validate_snapshot(snap)
        assert json.loads(json.dumps(snap)) == snap  # JSON-serializable
        assert snap["config"]["ring_capacity"] == 64
        trace = chrome_trace(snap)
        validate_trace(trace)
        names = [e["name"] for e in trace["traceEvents"]]
        assert any(n.startswith("step ") for n in names)
        assert "decode" in names  # host decode-chunk span
