"""Hypothesis property tests: the paper's safety/progress guarantees.

S1 (Theorem A.2): a successful allocation returns a non-overlapping,
size-coherent address range.
S2 (Theorem A.3): a correct free releases exactly what was allocated.
Progress (lock-freedom analogue): every wavefront round commits or
definitively fails at least one request.
Plus: packed-bunch trace equivalence and full-coalescing recovery.
"""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.bunch import BunchBuddy  # noqa: E402
from repro.core.concurrent import (  # noqa: E402
    BUNCH_PACKED,
    TreeConfig,
    free_batch,
    free_batch_sequential,
    wavefront_alloc,
    wavefront_free,
    wavefront_step,
)
from repro.core.pool import (  # noqa: E402
    PoolConfig,
    pool_wavefront_alloc,
    pool_wavefront_free,
)
from repro.core.ref import NBBSRef  # noqa: E402

SETTINGS = dict(max_examples=40, deadline=None)


def op_stream(max_ops=80):
    """(is_alloc, size_choice_or_free_index) streams."""
    return st.lists(
        st.tuples(st.booleans(), st.integers(0, 10 ** 6)),
        min_size=1,
        max_size=max_ops,
    )


def run_trace(alloc, ops, total, min_size):
    """Replays a trace; returns live {addr: block_size} and checks S1."""
    live = {}
    sizes = [min_size, min_size, 2 * min_size, 4 * min_size,
             8 * min_size, total // 4, total]
    for is_alloc, r in ops:
        if not is_alloc and live:
            addr = sorted(live)[r % len(live)]
            alloc.nb_free(addr)
            del live[addr]
        else:
            size = sizes[r % len(sizes)]
            a = alloc.nb_alloc(size)
            if a is not None:
                blk = min_size
                while blk < size:
                    blk *= 2
                # S1: in-bounds, aligned, disjoint from all live blocks
                assert 0 <= a and a + blk <= total
                assert a % blk == 0  # AX2
                for o, ob in live.items():
                    assert a + blk <= o or o + ob <= a, "overlap!"
                live[a] = blk
    return live


@given(op_stream())
@settings(**SETTINGS)
def test_s1_no_overlap_ref(ops):
    a = NBBSRef(1024, 8)
    run_trace(a, ops, 1024, 8)


@given(op_stream())
@settings(**SETTINGS)
def test_s2_free_restores_state_ref(ops):
    a = NBBSRef(1024, 8)
    live = run_trace(a, ops, 1024, 8)
    for addr in list(live):
        a.nb_free(addr)
    a.check_invariants()
    # the ultimate S2 corollary: everything coalesces back to the root
    assert a.nb_alloc(1024) == 0


@given(op_stream(), st.sampled_from([(4, 64), (3, 32), (2, 32)]))
@settings(**SETTINGS)
def test_bunch_equals_ref_on_any_trace(ops, bw):
    B, w = bw
    ref = NBBSRef(1024, 8)
    bb = BunchBuddy(1024, 8, bunch_levels=B, word_bits=w)
    sizes = [8, 8, 16, 32, 64, 256, 1024]
    live = []
    for is_alloc, r in ops:
        if not is_alloc and live:
            addr = live.pop(r % len(live))
            ref.nb_free(addr)
            bb.nb_free(addr)
        else:
            size = sizes[r % len(sizes)]
            a1, a2 = ref.nb_alloc(size), bb.nb_alloc(size)
            assert a1 == a2
            if a1 is not None:
                live.append(a1)
    assert ref.free_bytes() == bb.free_bytes()


@given(
    st.lists(st.integers(2, 6), min_size=1, max_size=24),
    st.integers(0, 2 ** 31 - 1),
)
@settings(**SETTINGS)
def test_wavefront_s1_and_progress(levels, seed):
    cfg = TreeConfig(depth=6, max_level=0)
    lv = jnp.asarray(levels, jnp.int32)
    tree, nodes, ok, stats = wavefront_alloc(
        cfg, cfg.empty_tree(), lv, jnp.ones(len(levels), bool)
    )
    nodes = np.asarray(nodes)
    ok = np.asarray(ok)
    # progress: bounded rounds (>=1 commit-or-fail per round)
    assert int(stats["rounds"]) <= len(levels) + 1
    # S1 on the wavefront outcome: winners' address ranges disjoint
    spans = []
    for n, o, l in zip(nodes, ok, levels):
        if not o:
            continue
        level = int(n).bit_length() - 1
        size = 64 >> level
        start = (int(n) - (1 << level)) * size
        for s0, s1 in spans:
            assert start + size <= s0 or s1 <= start
        spans.append((start, start + size))
    # free everything: tree returns to all-zero (S2 corollary)
    tree, _ = free_batch(cfg, tree, jnp.asarray(nodes), jnp.asarray(ok))
    assert (np.asarray(tree) == 0).all()


@given(op_stream(40), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=15, deadline=None)
def test_vectorized_free_matches_sequential_scan(ops, seed):
    """The merged O(depth) release pass is indistinguishable from the
    faithful per-node FREENODE/UNMARK scan on any quiescent batch."""
    cfg = TreeConfig(depth=5, max_level=0)
    tree = cfg.empty_tree()
    rng = np.random.default_rng(seed)
    live = []
    for is_alloc, r in ops:
        if is_alloc or not live:
            lv = jnp.asarray([r % 6], jnp.int32)
            tree, nodes, ok, _ = wavefront_alloc(
                cfg, tree, lv, jnp.ones(1, bool)
            )
            if bool(ok[0]):
                live.append(int(nodes[0]))
        else:
            k = 1 + r % len(live)
            idx = rng.choice(len(live), size=k, replace=False)
            sel = [live[i] for i in idx]
            live = [n for i, n in enumerate(live) if i not in set(idx.tolist())]
            fn, fa = jnp.asarray(sel, jnp.int32), jnp.ones(k, bool)
            t_seq, _ = free_batch_sequential(cfg, tree, fn, fa)
            t_vec, _ = free_batch(cfg, tree, fn, fa)
            assert (np.asarray(t_seq) == np.asarray(t_vec)).all()
            tree = t_vec


@given(
    st.lists(
        st.tuples(st.integers(0, 2 ** 30), st.integers(0, 2 ** 30)),
        min_size=2,
        max_size=16,
    )
)
@settings(max_examples=15, deadline=None)
def test_wavefront_step_differential_vs_ref(bursts):
    """Interleaved alloc/free bursts through wavefront_step (vectorized
    release) vs NBBSRef replaying the same linearization: identical
    trees (hence identical reachable occupancy per level), and every
    failed request genuinely unsatisfiable on the post-step state."""
    import copy

    depth, K, F = 5, 4, 4
    cfg = TreeConfig(depth=depth, max_level=0)
    total = 1 << depth
    tree = cfg.empty_tree()
    ref = NBBSRef(total, 1)
    live = []
    for r_free, r_alloc in bursts:
        k = r_free % (min(len(live), F) + 1) if live else 0
        fnodes, keep = live[:k], live[k:]
        live = keep
        fn = np.zeros(F, np.int32)
        fa = np.zeros(F, bool)
        fn[:k] = fnodes
        fa[:k] = True
        a = 1 + r_alloc % K
        lv = np.zeros(K, np.int32)
        aa = np.zeros(K, bool)
        lv[:a] = [(r_alloc >> (3 * i)) % (depth + 1) for i in range(a)]
        aa[:a] = True
        tree, nodes, ok, _ = wavefront_step(
            cfg, tree, jnp.asarray(fn), jnp.asarray(fa),
            jnp.asarray(lv), jnp.asarray(aa),
        )
        nodes, ok = np.asarray(nodes), np.asarray(ok)
        for n in fnodes:
            ref.nb_free(ref.starting_address(n))
        for n, o in zip(nodes[:a], ok[:a]):
            if o:
                assert ref._try_alloc(int(n)) == 0
                ref.index[ref.starting_address(int(n)) // ref.min_size] = int(n)
                live.append(int(n))
        assert (np.asarray(tree) == np.array(ref.tree)).all()
        for L, o in zip(lv[:a], ok[:a]):
            if not o:
                assert copy.deepcopy(ref).nb_alloc(total >> int(L)) is None
    # drain: everything coalesces back to an empty tree
    if live:
        tree, _ = free_batch(
            cfg, tree, jnp.asarray(live, jnp.int32), jnp.ones(len(live), bool)
        )
    assert (np.asarray(tree) == 0).all()


@given(
    st.lists(
        st.tuples(st.booleans(), st.integers(0, 2 ** 30)),
        min_size=1,
        max_size=30,
    ),
    st.sampled_from([2, 4]),
    st.integers(0, 2 ** 31 - 1),
)
@settings(max_examples=15, deadline=None)
def test_pool_never_double_allocates_across_shards(ops, S, seed):
    """Overflow routing safety (sharded pool): no matter how lanes
    bounce between shards, a (shard, node) pair is never handed to two
    live owners, per-shard address ranges stay disjoint (S1 per shard),
    and draining returns every tree to all-zero (S2 corollary)."""
    depth = 4
    pcfg = PoolConfig(TreeConfig(depth=depth), S)
    trees = pcfg.empty_trees()
    rng = np.random.default_rng(seed)
    live = {}  # (shard, node) -> (start, size)
    for is_alloc, r in ops:
        if not is_alloc and live:
            k = 1 + r % len(live)
            keys = list(live)
            idx = rng.choice(len(keys), size=k, replace=False)
            sel = [keys[i] for i in idx]
            fn = jnp.asarray([n for _, n in sel], jnp.int32)
            fs = jnp.asarray([s for s, _ in sel], jnp.int32)
            trees, freed, _ = pool_wavefront_free(
                pcfg, trees, fn, fs, jnp.ones(k, bool)
            )
            assert bool(freed.all())  # live handles always release
            for key in sel:
                del live[key]
        else:
            K = 1 + r % 6
            lv = jnp.asarray(
                [(r >> (3 * i)) % (depth + 1) for i in range(K)], jnp.int32
            )
            lane_ids = jnp.asarray(rng.integers(0, 1000, size=K), jnp.int32)
            trees, nodes, shard, ok, _ = pool_wavefront_alloc(
                pcfg, trees, lv, jnp.ones(K, bool), 64, lane_ids
            )
            spans = {}
            for n, s, o, L in zip(
                np.asarray(nodes), np.asarray(shard), np.asarray(ok),
                np.asarray(lv),
            ):
                if not o:
                    continue
                key = (int(s), int(n))
                assert key not in live, "double allocation across the pool!"
                level = int(n).bit_length() - 1
                assert level == int(L)  # served at the requested level
                size = (1 << depth) >> level
                start = (int(n) - (1 << level)) * size
                # S1 per shard: disjoint from every live block there
                for (os_, _), (ostart, osize) in {**live, **spans}.items():
                    if os_ != int(s):
                        continue
                    assert start + size <= ostart or ostart + osize <= start
                spans[key] = (start, size)
            live.update(spans)
    if live:
        fn = jnp.asarray([n for _, n in live], jnp.int32)
        fs = jnp.asarray([s for s, _ in live], jnp.int32)
        trees, freed, _ = pool_wavefront_free(
            pcfg, trees, fn, fs, jnp.ones(len(live), bool)
        )
        assert bool(freed.all())
    assert (np.asarray(trees) == 0).all()


@given(
    op_stream(30),
    st.sampled_from([1, 4]),
    st.integers(0, 2 ** 31 - 1),
)
@settings(max_examples=15, deadline=None)
@pytest.mark.fastpath
def test_fastpath_pool_safety_on_any_trace(ops, S, seed):
    """Fast-path safety (S1/S2 with the slab in the loop): random
    interleaved alloc/free traces on a fastpath pool never hand the
    same (shard, node) to two live owners — the slab and the buddy
    climb can never alias, because the slab subtree is pre-marked
    occupied — never leak units when a round fails, and every
    free(alloc(x)) round-trips whether x was served by the slab or the
    tree (handles are path-agnostic).  Draining returns every tree to
    the carved baseline."""
    from repro.core.fastpath import FastPathConfig
    from repro.core.pool import pool_free_units

    depth = 4
    pcfg = PoolConfig(
        TreeConfig(depth=depth), S,
        fastpath=FastPathConfig(level=None, slab_level=2),
    )
    trees = pcfg.empty_trees()
    baseline = np.asarray(pcfg.empty_trees())
    total = S << depth
    rng = np.random.default_rng(seed)
    live = {}  # (shard, node) -> units
    for is_alloc, r in ops:
        if not is_alloc and live:
            k = 1 + r % len(live)
            keys = list(live)
            idx = rng.choice(len(keys), size=k, replace=False)
            sel = [keys[i] for i in idx]
            fn = jnp.asarray([n for _, n in sel], jnp.int32)
            fs = jnp.asarray([s for s, _ in sel], jnp.int32)
            trees, freed, _ = pool_wavefront_free(
                pcfg, trees, fn, fs, jnp.ones(k, bool)
            )
            assert bool(freed.all())  # live handles always release
            for key in sel:
                del live[key]
        else:
            K = 1 + r % 6
            # bias toward the fast octave so the slab stays hot, with
            # coarse chunks mixed in to exercise the spill boundary
            lv = jnp.asarray(
                [
                    depth if (r >> i) & 1 else 2 + (r >> (2 * i)) % 3
                    for i in range(K)
                ],
                jnp.int32,
            )
            ids = jnp.asarray(rng.integers(0, 1000, size=K), jnp.int32)
            trees, nodes, shard, ok, _ = pool_wavefront_alloc(
                pcfg, trees, lv, jnp.ones(K, bool), 64, ids
            )
            for n, s, o, L in zip(
                np.asarray(nodes), np.asarray(shard), np.asarray(ok),
                np.asarray(lv),
            ):
                if not o:
                    continue
                key = (int(s), int(n))
                assert key not in live, "slab/tree double allocation!"
                level = int(n).bit_length() - 1
                assert level == int(L)
                live[key] = (1 << depth) >> level
        # no leaks: free units account for exactly the live allocations
        assert int(pool_free_units(pcfg, trees).sum()) == total - sum(
            live.values()
        )
    if live:
        fn = jnp.asarray([n for _, n in live], jnp.int32)
        fs = jnp.asarray([s for s, _ in live], jnp.int32)
        trees, freed, _ = pool_wavefront_free(
            pcfg, trees, fn, fs, jnp.ones(len(live), bool)
        )
        assert bool(freed.all())
    assert (np.asarray(trees) == baseline).all()


@given(op_stream(40))
@settings(max_examples=20, deadline=None)
def test_wavefront_matches_ref_single_requests(ops):
    """K=1 wavefronts replay the sequential specification exactly."""
    cfg = TreeConfig(depth=5, max_level=0)
    tree = cfg.empty_tree()
    ref = NBBSRef(32, 1)
    live = []
    for is_alloc, r in ops:
        if not is_alloc and live:
            node = live.pop(r % len(live))
            tree, _ = free_batch(
                cfg, tree, jnp.asarray([node], jnp.int32), jnp.ones(1, bool)
            )
            ref.nb_free(ref.starting_address(node))
        else:
            lv = r % 6
            tree, nodes, ok, _ = wavefront_alloc(
                cfg, tree, jnp.asarray([lv], jnp.int32), jnp.ones(1, bool)
            )
            a = ref.nb_alloc(32 >> lv)
            if a is None:
                assert not bool(ok[0])
            else:
                assert bool(ok[0])
                live.append(int(nodes[0]))
        assert (np.asarray(tree) == np.array(ref.tree)).all()


@given(op_stream(40))
@settings(max_examples=15, deadline=None)
def test_device_layouts_and_bunch_buddy_agree_on_any_trace(ops):
    """Three-way layout equivalence on arbitrary mixed alloc/free
    traces (docs/design.md §3): the `BunchPacked` device layout, the
    `Unpacked` oracle, and the host `BunchBuddy(B=3, word_bits=32)`
    hand out identical addresses and end at identical occupancy."""
    depth = 7                       # 128 units of 8 bytes = 1024 total
    total, min_size = 1024, 8
    cu = TreeConfig(depth=depth, max_level=0)
    cp = TreeConfig(depth=depth, max_level=0, layout=BUNCH_PACKED)
    tu, tp = cu.empty_tree(), cp.empty_tree()
    bb = BunchBuddy(total, min_size, bunch_levels=3, word_bits=32)
    sizes = [8, 8, 16, 32, 64, 256, 1024]
    live = []                       # (node, addr, block_size)
    for is_alloc, r in ops:
        if not is_alloc and live:
            node, addr, _ = live.pop(r % len(live))
            fn, fa = jnp.asarray([node], jnp.int32), jnp.ones(1, bool)
            tu, fu, _ = wavefront_free(cu, tu, fn, fa)
            tp, fp, _ = wavefront_free(cp, tp, fn, fa)
            assert bool(fu[0]) and bool(fp[0])
            bb.nb_free(addr)
        else:
            size = sizes[r % len(sizes)]
            lv = depth - ((size // min_size) - 1).bit_length()
            lvj = jnp.asarray([lv], jnp.int32)
            tu, nu, oku, _ = wavefront_alloc(cu, tu, lvj, jnp.ones(1, bool))
            tp, np_, okp, _ = wavefront_alloc(cp, tp, lvj, jnp.ones(1, bool))
            a_bb = bb.nb_alloc(size)
            assert bool(oku[0]) == bool(okp[0]) == (a_bb is not None)
            if a_bb is not None:
                node = int(nu[0])
                assert node == int(np_[0])
                level = node.bit_length() - 1
                addr = (node - (1 << level)) * (total >> level)
                assert addr == a_bb
                live.append((node, addr, size))
    # final occupancy: identical free bytes, and a full drain returns
    # every structure to empty
    occupied = sum(
        (total >> (n.bit_length() - 1)) for n, _, _ in live
    )
    assert bb.free_bytes() == total - occupied
    for node, addr, _ in live:
        bb.nb_free(addr)
    if live:
        fn = jnp.asarray([n for n, _, _ in live], jnp.int32)
        fa = jnp.ones(len(live), bool)
        tu, _, _ = wavefront_free(cu, tu, fn, fa)
        tp, _, _ = wavefront_free(cp, tp, fn, fa)
    assert (np.asarray(tu) == 0).all()
    assert (np.asarray(tp) == 0).all()
    assert bb.free_bytes() == total


# ---------------------------------------------------------------------------
# Jit-resident engine vs host oracle (docs/design.md §8)
# ---------------------------------------------------------------------------

_ENGINE_CACHE = {}


def _jit_engine_fixture():
    """One (cfg, params) pair per session; geometry is fixed so every
    hypothesis example reuses the same compiled engine_step."""
    if "v" not in _ENGINE_CACHE:
        import jax

        from repro.configs import get_config
        from repro.models import init_params

        cfg = get_config("stablelm-3b").reduced()
        _ENGINE_CACHE["v"] = (cfg, init_params(cfg, jax.random.PRNGKey(0)))
    return _ENGINE_CACHE["v"]


@given(
    st.lists(
        st.tuples(st.integers(1, 12), st.integers(1, 6)),
        min_size=1,
        max_size=8,
    )
)
@settings(max_examples=10, deadline=None)
def test_jit_engine_matches_host_oracle(trace):
    """Property form of the differential contract: for any trace of
    (prompt_len, max_new) pairs, the compiled engine and the host-driven
    oracle replay agree on retirement order, retirement steps, and final
    pool occupancy.  Token values are irrelevant by construction
    (eos=None), so prompts are constant."""
    from repro.serve.engine import Request
    from repro.serve.jit_engine import JitServeEngine
    from repro.serve.oracle import HostOracleEngine

    cfg, params = _jit_engine_fixture()
    geom = dict(num_pages=16, page_tokens=4, max_batch=4,
                max_lane_pages=8, max_out=8, n_shards=2)
    eng = JitServeEngine(cfg, params, dtype=jnp.float32, **geom)
    orc = HostOracleEngine(**geom)
    for i, (S, mn) in enumerate(trace):
        p = np.ones(S, np.int32)
        eng.submit(Request(i, p, mn))
        orc.submit(Request(i, p.copy(), mn))
    eng.run_to_completion(max_steps=400)
    orc.run_to_completion(max_steps=400)
    assert eng.retired_order == orc.retired_order
    assert eng.done_steps == orc.done_steps
    assert len(eng.completed) == len(orc.completed) == len(trace)
    assert eng.stats == orc.stats
    assert eng.device_free_pages() == orc.free_pages() == 16
    orc.pool.check_invariants()


@given(
    op_stream(30),
    st.sampled_from([1, 4]),
    st.integers(0, 2 ** 31 - 1),
)
@settings(max_examples=15, deadline=None)
@pytest.mark.magazine
def test_magazine_pool_safety_on_any_trace(ops, S, seed):
    """Magazine safety (S1/S2 with lane-local recycling in the loop):
    random interleaved alloc/free traces where frees stash into random
    lanes' magazines never hand the same (shard, node) to two live
    owners — a popped page cannot alias a tree/slab grant because a
    stashed page stays marked allocated in its tree — conserve units as
    `pool_free_units + mag_total + live == total` after every burst,
    and draining every magazine restores the exact magazines-off
    baseline."""
    from repro.core.magazine import MagazineConfig, mag_total
    from repro.core.pool import (
        pool_free_units,
        pool_init_magazines,
        pool_magazine_drain,
        pool_wavefront_alloc_mag,
        pool_wavefront_free_mag,
    )

    depth, L = 4, 4
    pcfg = PoolConfig(
        TreeConfig(depth=depth), S,
        magazines=MagazineConfig(mag_cap=3),
    )
    trees = pcfg.empty_trees()
    mags = pool_init_magazines(pcfg, L)
    baseline = np.asarray(pcfg.empty_trees())
    total = S << depth
    rng = np.random.default_rng(seed)
    live = {}  # (shard, node) -> units
    for is_alloc, r in ops:
        if not is_alloc and live:
            k = 1 + r % len(live)
            keys = list(live)
            idx = rng.choice(len(keys), size=k, replace=False)
            sel = [keys[i] for i in idx]
            fn = jnp.asarray([n for _, n in sel], jnp.int32)
            fs = jnp.asarray([s for s, _ in sel], jnp.int32)
            ml = jnp.asarray(
                rng.integers(-1, L, size=k), jnp.int32
            )  # -1 opts out of stashing
            trees, mags, freed, _ = pool_wavefront_free_mag(
                pcfg, trees, mags, fn, fs, jnp.ones(k, bool), ml
            )
            assert bool(freed.all())  # stashed or released, never lost
            for key in sel:
                del live[key]
        else:
            K = 1 + r % 6
            # bias toward the leaf octave so magazines stay hot, with
            # coarse chunks mixed in (those bypass the magazines)
            lv = jnp.asarray(
                [
                    depth if (r >> i) & 1 else 2 + (r >> (2 * i)) % 3
                    for i in range(K)
                ],
                jnp.int32,
            )
            ids = jnp.asarray(rng.integers(0, 1000, size=K), jnp.int32)
            ml = jnp.asarray(rng.integers(-1, L, size=K), jnp.int32)
            trees, mags, nodes, shard, ok, _ = pool_wavefront_alloc_mag(
                pcfg, trees, mags, lv, jnp.ones(K, bool), 64, ids, ml
            )
            for n, s, o, lvl in zip(
                np.asarray(nodes), np.asarray(shard), np.asarray(ok),
                np.asarray(lv),
            ):
                if not o:
                    continue
                key = (int(s), int(n))
                assert key not in live, "magazine double allocation!"
                level = int(n).bit_length() - 1
                assert level == int(lvl)
                live[key] = (1 << depth) >> level
        # conservation: tree-free + stashed + live covers every unit
        assert (
            int(pool_free_units(pcfg, trees).sum())
            + int(mag_total(mags))
            + sum(live.values())
            == total
        )
    if live:
        fn = jnp.asarray([n for _, n in live], jnp.int32)
        fs = jnp.asarray([s for s, _ in live], jnp.int32)
        trees, mags, freed, _ = pool_wavefront_free_mag(
            pcfg, trees, mags, fn, fs, jnp.ones(len(live), bool),
            jnp.full(len(live), -1, jnp.int32),
        )
        assert bool(freed.all())
    trees, mags, _ = pool_magazine_drain(pcfg, trees, mags)
    assert int(mag_total(mags)) == 0
    assert (np.asarray(trees) == baseline).all()
