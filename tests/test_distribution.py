"""Distribution tests run in subprocesses with forced host device counts
(jax locks the device count at first init): pipeline parallelism via
ppermute, compressed psum on a mesh, sharded train step on a 2x2 mesh,
elastic restore across mesh sizes, and the dry-run cell builder on a
small production-mesh-shaped mesh."""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(n, code):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True, text=True,
        timeout=540,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_pipeline_parallel_forward_and_grad():
    out = run_with_devices(4, """
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_test_mesh
from repro.train.pp import pipeline_apply

mesh = make_test_mesh((4,), ("pipe",))
L, n_micro, mb, d = 8, 4, 2, 16
key = jax.random.PRNGKey(0)
W = jax.random.normal(key, (L, d, d)) * 0.3

def body(w, x):
    return jnp.tanh(x @ w)

x = jax.random.normal(jax.random.fold_in(key, 1), (n_micro, mb, d))
y = pipeline_apply(body, W, x, mesh)
# reference: plain sequential layers
ref = x
for l in range(L):
    ref = jnp.tanh(ref @ W[l])
np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)

# differentiable through the pipeline
def loss(W):
    return jnp.square(pipeline_apply(body, W, x, mesh)).sum()
g = jax.grad(loss)(W)
gref = jax.grad(lambda W: jnp.square(
    jnp.tanh(jnp.tanh(x @ W[0]) @ W[1]) if False else loss_ref(W)))(W) if False else None
def loss_ref(W):
    r = x
    for l in range(L):
        r = jnp.tanh(r @ W[l])
    return jnp.square(r).sum()
gref = jax.grad(loss_ref)(W)
np.testing.assert_allclose(np.asarray(g), np.asarray(gref), atol=1e-4)
print("PP OK")
""")
    assert "PP OK" in out


def test_compressed_psum_on_mesh():
    out = run_with_devices(4, """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.launch.mesh import make_test_mesh
from repro.optim.compression import compressed_psum

mesh = make_test_mesh((4,), ("dp",))
x = jax.random.normal(jax.random.PRNGKey(0), (4, 512))

f = shard_map(
    lambda g: compressed_psum(g[0], "dp"),
    mesh=mesh, in_specs=P("dp", None), out_specs=P(),
)
out = f(x)
ref = x.sum(0)
err = float(jnp.abs(out - ref).max())
rel = err / float(jnp.abs(ref).max())
assert rel < 0.02, (err, rel)  # int8 quantization error bound
print("CPSUM OK", rel)
""")
    assert "CPSUM OK" in out


def test_sharded_train_step_and_elastic_restore():
    out = run_with_devices(8, """
import tempfile, jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.launch.mesh import make_test_mesh, use_mesh
from repro.configs import get_config
from repro.models.sharding import MeshAxes, param_specs
from repro.train.trainer import TrainConfig, init_train_state, make_train_step
from repro.data.pipeline import SyntheticLM
from repro.ckpt.checkpoint import CheckpointManager

cfg = get_config("stablelm-3b").reduced()
tcfg = TrainConfig(microbatches=1, remat=True, dtype=jnp.float32)
axes = MeshAxes(dp=("data",), tp="model", fsdp=True)
data = SyntheticLM(cfg.vocab_size, 16, 8)

def steps_on_mesh(mesh, state, n, start):
    ns = lambda s: NamedSharding(mesh, s)
    specs = param_specs(axes, state)
    state = jax.device_put(state, jax.tree.map(ns, specs))
    step = jax.jit(make_train_step(cfg, tcfg, axes), donate_argnums=0)
    with use_mesh(mesh):
        for i in range(start, start + n):
            state, m = step(state, data.batch_at(i))
    return state, float(m["loss"])

mesh42 = make_test_mesh((4, 2), ("data", "model"))
state = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
state, loss1 = steps_on_mesh(mesh42, state, 3, 0)

# elastic: save on (4,2), restore on (2,4), keep training
with tempfile.TemporaryDirectory() as d:
    mgr = CheckpointManager(d, async_io=False)
    mgr.save(3, state)
    mesh24 = make_test_mesh((2, 4), ("data", "model"))
    like = init_train_state(cfg, tcfg, jax.random.PRNGKey(0))
    ns2 = lambda s: NamedSharding(mesh24, s)
    shardings = jax.tree.map(ns2, param_specs(axes, like))
    restored = mgr.restore(3, like=like, shardings=shardings)
    state2, loss2 = steps_on_mesh(mesh24, restored, 3, 3)
assert np.isfinite(loss1) and np.isfinite(loss2)
print("ELASTIC OK", loss1, loss2)
""")
    assert "ELASTIC OK" in out


def test_single_device_vs_sharded_same_loss():
    out = run_with_devices(4, """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding
from repro.launch.mesh import make_test_mesh, use_mesh
from repro.configs import get_config
from repro.models.sharding import MeshAxes, param_specs
from repro.models import init_params
from repro.models.transformer import train_loss
from repro.data.pipeline import SyntheticLM

cfg = get_config("stablelm-3b").reduced()
data = SyntheticLM(cfg.vocab_size, 16, 4)
params = init_params(cfg, jax.random.PRNGKey(0))
batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
l_single = float(train_loss(cfg, params, batch, dtype=jnp.float32, remat=False))

mesh = make_test_mesh((2, 2), ("data", "model"))
axes = MeshAxes(dp=("data",), tp="model", fsdp=True)
ns = lambda s: NamedSharding(mesh, s)
p_sh = jax.device_put(params, jax.tree.map(ns, param_specs(axes, params)))
with use_mesh(mesh):
    l_shard = float(jax.jit(
        lambda p, b: train_loss(cfg, p, b, axes=axes, dtype=jnp.float32,
                                remat=False)
    )(p_sh, batch))
assert abs(l_single - l_shard) < 1e-3, (l_single, l_shard)
print("SPMD-EQUIV OK", l_single, l_shard)
""")
    assert "SPMD-EQUIV OK" in out


def test_dryrun_cell_builder_on_small_mesh():
    """The launch-layer cell builder (shardings, specs, step functions)
    lowers AND compiles on a small production-shaped mesh for a reduced
    arch — the fast CI version of the 512-device dry-run."""
    out = run_with_devices(8, """
import jax
from jax.sharding import Mesh
from repro.launch.mesh import make_test_mesh, use_mesh
from repro.launch import dryrun
from repro.configs import get_config
from repro.configs.base import ShapeSpec

cfg = get_config("stablelm-3b").reduced()
mesh = make_test_mesh((4, 2), ("data", "model"))
for spec in (ShapeSpec("t", 32, 8, "train"),
             ShapeSpec("p", 32, 8, "prefill"),
             ShapeSpec("d", 32, 8, "decode")):
    with use_mesh(mesh):
        lowered, meta = dryrun.build_cell(cfg, spec, mesh, False)
        compiled = lowered.compile()
        assert compiled.cost_analysis() is not None
    print("cell", spec.kind, "OK")
print("BUILDER OK")
""")
    assert "BUILDER OK" in out
