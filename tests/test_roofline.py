"""Unit tests for the loop-aware HLO roofline analyzer — the instrument
behind §Roofline/§Perf must itself be trustworthy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_analysis import (
    Analyzer,
    analyze_hlo,
    roofline_terms,
    shape_bytes,
    shape_elems,
)


def compiled_text(fn, *specs):
    return jax.jit(fn).lower(*specs).compile().as_text()


class TestShapeParsing:
    def test_shape_bytes(self):
        assert shape_bytes("f32[64,64]{1,0}") == 64 * 64 * 4
        assert shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
        assert shape_bytes("(s32[], f32[2,2]{1,0})") == 4 + 16
        assert shape_bytes("pred[]") == 1
        assert shape_elems("f32[3,5]") == 15


class TestLoopAwareness:
    def test_scan_trip_count_multiplies_flops(self):
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((64, 64), jnp.float32)

        def scan_n(n):
            def f(x, w):
                def body(x, _):
                    return jnp.tanh(x @ w), None
                return jax.lax.scan(body, x, None, length=n)[0]
            return f

        f1 = analyze_hlo(compiled_text(scan_n(1), x, w))["flops"]
        f10 = analyze_hlo(compiled_text(scan_n(10), x, w))["flops"]
        # XLA cost_analysis would report f10 == f1; ours must scale
        assert 9.0 < f10 / f1 < 11.0

    def test_nested_scans_compose(self):
        x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
        w = jax.ShapeDtypeStruct((32, 32), jnp.float32)

        def f(x, w):
            def outer(x, _):
                def inner(x, _):
                    return x @ w, None
                x, _ = jax.lax.scan(inner, x, None, length=4)
                return x, None
            return jax.lax.scan(outer, x, None, length=3)[0]

        flops = analyze_hlo(compiled_text(f, x, w))["flops"]
        expect = 12 * 2 * 32 ** 3
        assert 0.95 < flops / expect < 1.2


class TestDotFlops:
    def test_matmul_flops_exact(self):
        a = jax.ShapeDtypeStruct((128, 256), jnp.float32)
        b = jax.ShapeDtypeStruct((256, 64), jnp.float32)
        r = analyze_hlo(compiled_text(lambda a, b: a @ b, a, b))
        expect = 2 * 128 * 256 * 64
        assert abs(r["flops"] - expect) / expect < 0.01

    def test_batched_dot(self):
        a = jax.ShapeDtypeStruct((4, 32, 64), jnp.float32)
        b = jax.ShapeDtypeStruct((4, 64, 16), jnp.float32)
        r = analyze_hlo(
            compiled_text(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b)
        )
        expect = 2 * 4 * 32 * 64 * 16
        assert abs(r["flops"] - expect) / expect < 0.02


class TestTrafficModel:
    def test_inplace_dus_in_scan_not_full_buffer(self):
        """A KV-cache-style scan carry update must cost update-sized
        traffic per step, not full-buffer copies."""
        cache = jax.ShapeDtypeStruct((64, 1024, 16), jnp.float32)
        upd = jax.ShapeDtypeStruct((64, 1, 16), jnp.float32)

        def f(cache, upd):
            def body(c, i):
                c = jax.lax.dynamic_update_slice(c, upd, (0, i, 0))
                return c, None
            return jax.lax.scan(body, cache, jnp.arange(8))[0]

        r = analyze_hlo(compiled_text(f, cache, upd))
        full = 64 * 1024 * 16 * 4
        # 8 steps of full-buffer read+write would be 16x the buffer;
        # the in-place model must stay well under 2 buffer's worth
        assert r["bytes"] < 2.5 * full

    def test_collective_bytes_and_classification(self):
        import subprocess, sys, os
        code = """
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.roofline.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_test_mesh
mesh = make_test_mesh((4,), ("d",))
x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
xs = NamedSharding(mesh, P(None, "d"))
ws = NamedSharding(mesh, P("d", None))
txt = jax.jit(lambda x, w: (x @ w).sum(),
              in_shardings=(xs, ws)).lower(x, w).compile().as_text()
r = analyze_hlo(txt)
assert r["collective_bytes"] > 0
assert "all-reduce" in r["per_collective"]
print("OK", r["per_collective"])
"""
        env = dict(os.environ)
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        env["PYTHONPATH"] = os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
        )
        res = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, timeout=300)
        assert res.returncode == 0, res.stderr
        assert "OK" in res.stdout


class TestRooflineTerms:
    def test_dominant_and_fraction(self):
        t = roofline_terms(
            {"flops": 197e12, "bytes": 8.19e9, "collective_bytes": 5e8}
        )
        assert t["compute_s"] == pytest.approx(1.0)
        assert t["memory_s"] == pytest.approx(0.01)
        assert t["dominant"] == "compute_s"
        assert 0.97 < t["overlap_fraction"] <= 1.0
