"""Sharded allocator pool tests: S=1 bit-identity with the single tree,
shard-by-shard differential replay through the sequential release oracle,
overflow routing, and the cross-shard no-double-allocation property."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.concurrent import (
    BUNCH_PACKED,
    TreeConfig,
    UNPACKED,
    free_batch_sequential,
    wavefront_alloc,
    wavefront_step,
)

LAYOUTS = pytest.mark.parametrize(
    "layout", [UNPACKED, BUNCH_PACKED], ids=["unpacked", "packed"]
)
from repro.core.nbbs_jax import (
    init_pool_state,
    nb_pool_alloc,
    nb_pool_free_batch,
)
from repro.core.pool import (
    PoolConfig,
    home_shard,
    pool_free_round,
    pool_wavefront_alloc,
    pool_wavefront_free,
    pool_wavefront_step,
    probe_shard,
)


class TestPoolSingleShardIdentity:
    """With S=1 every pool entry point must be bit-identical to its
    single-tree counterpart (the acceptance bar for the refactor)."""

    @LAYOUTS
    def test_alloc_bit_identical(self, layout):
        cfg = TreeConfig(depth=7, max_level=0, layout=layout)
        pcfg = PoolConfig(cfg, 1)
        rng = np.random.default_rng(0)
        lv = jnp.asarray(rng.integers(2, 8, size=24), jnp.int32)
        act = jnp.ones(24, bool)
        t1, n1, ok1, s1 = wavefront_alloc(cfg, cfg.empty_tree(), lv, act)
        tp, np_, sh, okp, sp = pool_wavefront_alloc(
            pcfg, pcfg.empty_trees(), lv, act
        )
        assert (np.asarray(t1) == np.asarray(tp[0])).all()
        assert (np.asarray(n1) == np.asarray(np_)).all()
        assert (np.asarray(ok1) == np.asarray(okp)).all()
        assert not np.asarray(sh).any()  # only shard 0 exists
        assert int(s1["rounds"]) == int(sp["rounds"])
        assert int(s1["merged_writes"]) == int(sp["merged_writes"])
        assert int(s1["logical_rmws"]) == int(sp["logical_rmws"])
        assert int(sp["overflows"]) == 0

    def test_mixed_step_bit_identical(self):
        cfg = TreeConfig(depth=6, max_level=0)
        pcfg = PoolConfig(cfg, 1)
        rng = np.random.default_rng(1)
        tree, nodes, ok, _ = wavefront_alloc(
            cfg, cfg.empty_tree(),
            jnp.asarray(rng.integers(2, 7, size=16), jnp.int32),
            jnp.ones(16, bool),
        )
        fn, fa = nodes[:8], ok[:8]
        lv = jnp.asarray(rng.integers(1, 7, size=12), jnp.int32)
        aa = jnp.ones(12, bool)
        t1, n1, ok1, s1 = wavefront_step(cfg, tree, fn, fa, lv, aa)
        tp, np_, sh, okp, sp = pool_wavefront_step(
            pcfg, tree[None, :], fn, jnp.zeros(8, jnp.int32), fa, lv, aa
        )
        assert (np.asarray(t1) == np.asarray(tp[0])).all()
        assert (np.asarray(n1) == np.asarray(np_)).all()
        assert int(s1["freed"]) == int(sp["freed"])
        assert int(s1["free_merged_writes"]) == int(sp["free_merged_writes"])
        assert int(s1["free_logical_rmws"]) == int(sp["free_logical_rmws"])


class TestPoolRouting:
    def test_home_shard_deterministic_and_spread(self):
        pcfg = PoolConfig(TreeConfig(depth=5), 4)
        ids = jnp.arange(64, dtype=jnp.int32)
        h1 = np.asarray(home_shard(pcfg, ids))
        h2 = np.asarray(home_shard(pcfg, ids))
        assert (h1 == h2).all()
        assert set(h1.tolist()) == {0, 1, 2, 3}  # hash uses every shard
        assert (np.asarray(probe_shard(pcfg, jnp.asarray(h1), 1))
                == (h1 + 1) % 4).all()

    def test_overflow_routes_to_next_shard(self):
        """Lanes homed to one shard overflow to the probe successor when
        their home exhausts — the burst completes across the pool."""
        pcfg = PoolConfig(TreeConfig(depth=5), 4)  # 32 units per shard
        K = 40
        lane_ids = jnp.zeros(K, jnp.int32)  # everyone homes to one shard
        home = int(home_shard(pcfg, lane_ids)[0])
        lv = jnp.full(K, 5, jnp.int32)      # unit leaves: 32 per shard
        trees, nodes, shard, ok, stats = pool_wavefront_alloc(
            pcfg, pcfg.empty_trees(), lv, jnp.ones(K, bool),
            64, lane_ids,
        )
        assert bool(ok.all())               # one tree alone would fail
        shard = np.asarray(shard)
        assert (shard == home).sum() == 32  # home filled first
        assert (shard == (home + 1) % 4).sum() == 8  # overflow to successor
        assert int(stats["overflows"]) == 8

    def test_exhausted_pool_fails_after_probing_every_shard(self):
        pcfg = PoolConfig(TreeConfig(depth=3), 2)
        K = 20                               # 16 leaves exist in total
        lv = jnp.full(K, 3, jnp.int32)
        trees, nodes, shard, ok, _ = pool_wavefront_alloc(
            pcfg, pcfg.empty_trees(), lv, jnp.ones(K, bool)
        )
        assert int(ok.sum()) == 16
        assert not np.asarray(nodes)[~np.asarray(ok)].any()

    def test_free_releases_on_recorded_shard(self):
        pcfg = PoolConfig(TreeConfig(depth=4), 4)
        lv = jnp.asarray([2, 3, 4, 4, 1, 2], jnp.int32)
        trees, nodes, shard, ok, _ = pool_wavefront_alloc(
            pcfg, pcfg.empty_trees(), lv, jnp.ones(6, bool)
        )
        assert bool(ok.all())
        trees, freed, _ = pool_wavefront_free(pcfg, trees, nodes, shard, ok)
        assert bool(freed.all())
        assert (np.asarray(trees) == 0).all()
        # a second release of the same handles is dropped on every shard
        trees2, freed2, _ = pool_wavefront_free(pcfg, trees, nodes, shard, ok)
        assert not bool(freed2.any())
        assert (np.asarray(trees2) == 0).all()


class TestPoolDifferential:
    def test_pooled_free_matches_shard_by_shard_sequential_scan(self):
        """A pooled alloc/free trace replayed shard-by-shard through the
        single-tree sequential oracle (`free_batch_sequential`) must
        yield identical tree states — the pool adds routing, never new
        release semantics."""
        rng = np.random.default_rng(11)
        for S, depth in [(2, 5), (4, 6)]:
            pcfg = PoolConfig(TreeConfig(depth=depth), S)
            trees = pcfg.empty_trees()
            live = []  # (node, shard)
            for step in range(8):
                K = 12
                lv = jnp.asarray(
                    rng.integers(1, depth + 1, size=K), jnp.int32
                )
                lane_ids = jnp.asarray(
                    rng.integers(0, 1000, size=K), jnp.int32
                )
                trees, nodes, shard, ok, _ = pool_wavefront_alloc(
                    pcfg, trees, lv, jnp.ones(K, bool), 64, lane_ids
                )
                live += [
                    (int(n), int(s))
                    for n, s, o in zip(
                        np.asarray(nodes), np.asarray(shard), np.asarray(ok)
                    )
                    if o
                ]
                k = int(rng.integers(0, len(live) + 1))
                if not k:
                    continue
                idx = rng.choice(len(live), size=k, replace=False)
                sel = [live[i] for i in idx]
                live = [
                    x for i, x in enumerate(live) if i not in set(idx.tolist())
                ]
                fn = jnp.asarray([n for n, _ in sel], jnp.int32)
                fs = jnp.asarray([s for _, s in sel], jnp.int32)
                fa = jnp.ones(k, bool)
                t_vec, _, _, freed = pool_free_round(
                    pcfg, trees, fn, fs, fa
                )
                assert bool(np.asarray(freed).all())
                # shard-by-shard sequential replay of the same frees
                for s in range(S):
                    mask = jnp.asarray(np.asarray(fs) == s)
                    t_seq, _ = free_batch_sequential(
                        pcfg.tree, trees[s], fn, fa & mask
                    )
                    assert (np.asarray(t_seq) == np.asarray(t_vec[s])).all()
                trees = t_vec
            # drain everything; every shard coalesces back to empty
            if live:
                fn = jnp.asarray([n for n, _ in live], jnp.int32)
                fs = jnp.asarray([s for _, s in live], jnp.int32)
                trees, freed, _ = pool_wavefront_free(
                    pcfg, trees, fn, fs, jnp.ones(len(live), bool)
                )
                assert bool(freed.all())
            assert (np.asarray(trees) == 0).all()


class TestPoolStateAPI:
    def test_alloc_free_roundtrip(self):
        pcfg = PoolConfig(TreeConfig(depth=4), 4)
        st = init_pool_state(pcfg)
        handles = []
        for i in range(6):
            st, s, off, ok = nb_pool_alloc(pcfg, st, jnp.int32(2), i)
            assert bool(ok)
            handles.append((int(s), int(off)))
        assert len(set(handles)) == 6
        sh = jnp.asarray([s for s, _ in handles], jnp.int32)
        off = jnp.asarray([o for _, o in handles], jnp.int32)
        st, freed = nb_pool_free_batch(
            pcfg, st, sh, off, jnp.ones(6, bool)
        )
        assert bool(freed.all())
        assert (np.asarray(st.trees) == 0).all()

    def test_stale_and_junk_handles_dropped(self):
        pcfg = PoolConfig(TreeConfig(depth=4), 2)
        st = init_pool_state(pcfg)
        st, s, off, ok = nb_pool_alloc(pcfg, st, jnp.int32(1), 3)
        assert bool(ok)
        st, freed = nb_pool_free_batch(
            pcfg, st, jnp.asarray([int(s)]), jnp.asarray([int(off)]),
            jnp.ones(1, bool),
        )
        assert bool(freed[0])
        # double free, out-of-range shard, out-of-range offset: all dropped
        st2, freed2 = nb_pool_free_batch(
            pcfg, st,
            jnp.asarray([int(s), 7, 0]),
            jnp.asarray([int(off), 0, 99]),
            jnp.ones(3, bool),
        )
        assert not bool(freed2.any())
        assert (np.asarray(st2.trees) == np.asarray(st.trees)).all()


class TestPoolFastPathHandles:
    """Handle semantics with the bitmap-slab front end in the pool
    (core/fastpath.py): handles stay ordinary (shard, node) pairs."""

    @pytest.mark.parametrize("use_fastpath", [False, True],
                             ids=["plain", "fastpath"])
    def test_free_then_realloc_same_handle_after_overflow(
        self, use_fastpath
    ):
        """A handle served by overflow routing round-trips: freeing it
        and re-requesting with the same lane id lands on the same
        (shard, node) — whether the successor shard served it from the
        slab or the tree (the home shard is still full, so the probe
        path repeats deterministically)."""
        from repro.core.fastpath import FastPathConfig

        fp = FastPathConfig(level=None, slab_level=2) if use_fastpath else None
        pcfg = PoolConfig(TreeConfig(depth=4), 4, fastpath=fp)
        K = 17  # 16 leaves per shard + 1 overflow lane
        lane_ids = jnp.zeros(K, jnp.int32)
        home = int(home_shard(pcfg, lane_ids)[0])
        lv = jnp.full(K, 4, jnp.int32)
        trees, nodes, shard, ok, _ = pool_wavefront_alloc(
            pcfg, pcfg.empty_trees(), lv, jnp.ones(K, bool), 64, lane_ids
        )
        assert bool(ok.all())
        sh = np.asarray(shard)
        over = int(np.nonzero(sh != home)[0][0])
        h_node, h_shard = int(nodes[over]), int(sh[over])
        assert h_shard == (home + 1) % 4
        trees, freed, _ = pool_wavefront_free(
            pcfg, trees, jnp.asarray([h_node], jnp.int32),
            jnp.asarray([h_shard], jnp.int32), jnp.ones(1, bool),
        )
        assert bool(freed.all())
        trees, n2, s2, ok2, _ = pool_wavefront_alloc(
            pcfg, trees, jnp.full(1, 4, jnp.int32), jnp.ones(1, bool),
            64, jnp.zeros(1, jnp.int32),
        )
        assert bool(ok2[0])
        assert (int(n2[0]), int(s2[0])) == (h_node, h_shard)

    def test_junk_handles_into_slab_range_dropped(self):
        """Regression: handles pointing *into* the carved region — an
        unallocated slab leaf, the carve node itself, an interior node
        of the carved subtree, a node on the carve path — are dropped,
        never release slab bits or corrupt the pre-marked subtree."""
        from repro.core.fastpath import FastPathConfig

        pcfg = PoolConfig(
            TreeConfig(depth=4), 2,
            fastpath=FastPathConfig(level=None, slab_level=2),
        )
        trees, nodes, shard, ok, _ = pool_wavefront_alloc(
            pcfg, pcfg.empty_trees(), jnp.full(2, 4, jnp.int32),
            jnp.ones(2, bool), 64, jnp.asarray([0, 1], jnp.int32),
        )
        assert bool(ok.all())
        before = np.asarray(trees)
        # slab covers leaves 16..19; lanes above claimed some of them.
        # Junk: an unclaimed slab leaf on the other shard, the carve
        # node (4), a carved-subtree interior (8), path nodes (1, 2).
        other = 1 - int(shard[0])
        junk_nodes = jnp.asarray([19, 4, 8, 1, 2], jnp.int32)
        junk_shards = jnp.asarray([other, 0, 0, 1, 1], jnp.int32)
        t2, freed, _ = pool_wavefront_free(
            pcfg, trees, junk_nodes, junk_shards, jnp.ones(5, bool)
        )
        assert not bool(freed.any())
        assert (np.asarray(t2) == before).all()
        # the real handles still release fine afterwards
        t3, freed3, _ = pool_wavefront_free(pcfg, t2, nodes, shard, ok)
        assert bool(freed3.all())
        assert (np.asarray(t3) == np.asarray(pcfg.empty_trees())).all()


# The hypothesis properties for overflow routing (a pool trace never
# double-allocates a (shard, node) pair — with and without the fastpath
# slab) live in tests/test_properties.py with the other hypothesis
# suites so this module stays dependency-free.


class TestPoolLayouts:
    """The packed tree-state layout through the pool layer: identical
    routing and allocation outcomes to the unpacked pool, smaller
    stacked state (docs/design.md §3)."""

    def test_packed_pool_equals_unpacked_pool(self):
        S, depth, K = 4, 5, 24
        pu = PoolConfig(TreeConfig(depth=depth), S)
        pp = PoolConfig(TreeConfig(depth=depth, layout=BUNCH_PACKED), S)
        assert pp.n_state_words * 4 <= pu.n_state_words
        rng = np.random.default_rng(9)
        lv = jnp.asarray(rng.integers(0, depth + 1, size=K), jnp.int32)
        lane_ids = jnp.asarray(rng.integers(0, 1000, size=K), jnp.int32)
        tu, nu, su, oku, stu = pool_wavefront_alloc(
            pu, pu.empty_trees(), lv, jnp.ones(K, bool), 64, lane_ids
        )
        tp, np_, sp, okp, stp = pool_wavefront_alloc(
            pp, pp.empty_trees(), lv, jnp.ones(K, bool), 64, lane_ids
        )
        assert (np.asarray(nu) == np.asarray(np_)).all()
        assert (np.asarray(su) == np.asarray(sp)).all()
        assert (np.asarray(oku) == np.asarray(okp)).all()
        assert int(stu["rounds"]) == int(stp["rounds"])
        assert int(stu["overflows"]) == int(stp["overflows"])
        # the packed pool's merged writes are the §III-D payoff
        assert int(stp["merged_writes"]) < int(stu["merged_writes"])
        # release: identical freed masks, both pools drain to zero
        tu, fu, _ = pool_wavefront_free(pu, tu, nu, su, oku)
        tp, fp, _ = pool_wavefront_free(pp, tp, np_, sp, okp)
        assert (np.asarray(fu) == np.asarray(fp)).all()
        assert (np.asarray(tu) == 0).all()
        assert (np.asarray(tp) == 0).all()

    def test_packed_pool_state_shapes(self):
        pp = PoolConfig(TreeConfig(depth=6, layout=BUNCH_PACKED), 2)
        trees = pp.empty_trees()
        assert trees.shape == (2, pp.n_state_words)
        assert trees.dtype == jnp.uint32
        st = init_pool_state(pp)
        assert st.trees.shape == (2, pp.n_state_words)
        assert st.index.shape == (2, 64)
