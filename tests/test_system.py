"""End-to-end behaviour tests: tiny-LM training convergence through the
full stack (trainer + supervisor + checkpoints + failure injection) and
the serving engine driven through the public launch CLIs."""

import json
import os
import subprocess
import sys
import tempfile

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))


def run_cli(args, timeout=540):
    r = subprocess.run(
        [sys.executable, "-m"] + args, env=ENV, capture_output=True,
        text=True, timeout=timeout,
    )
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


def test_train_cli_loss_decreases_with_failure_recovery():
    with tempfile.TemporaryDirectory() as d:
        out = run_cli([
            "repro.launch.train", "--arch", "stablelm-3b", "--reduced",
            "--steps", "40", "--batch", "8", "--seq", "32", "--lr", "3e-3",
            "--ckpt-dir", d, "--ckpt-every", "10", "--fail-at", "17",
        ])
        stats = json.loads(out.strip().splitlines()[-1])
        assert stats["last_loss"] < stats["first_loss"]
        # a checkpoint survived
        assert any(n.startswith("step_") for n in os.listdir(d))


def test_serve_cli_completes_requests():
    out = run_cli([
        "repro.launch.serve", "--arch", "stablelm-3b", "--reduced",
        "--requests", "6", "--max-new", "4", "--num-pages", "64",
        "--page-tokens", "4",
    ])
    stats = json.loads(out.strip().splitlines()[-1])
    assert stats["completed"] == 6
    assert stats["kv"]["used_pages"] == 0  # everything freed + coalesced
