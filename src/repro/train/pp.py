"""Pipeline parallelism: GPipe fill-drain over a 'pipe' mesh axis.

Operates on exactly the shape our backbone already has — a scanned
per-layer body with stacked parameters.  Layers are split into
`n_stages` contiguous stages (stacked params sharded on the leading
layer dim over the 'pipe' axis); microbatches stream through stages
with `jax.lax.ppermute` handing activations to the next stage.

Inside shard_map each device runs `steps = n_micro + n_stages - 1`
iterations (fill + steady state + drain); stage s computes on iteration
t the microbatch m = t - s when 0 <= m < n_micro.  Differentiable:
jax.grad flows through ppermute (its transpose is the reverse permute),
giving 1F1B-equivalent compute with GPipe scheduling.

The production (16,16)/(2,16,16) meshes use DP x TP; PP is exercised on
auxiliary meshes (tests use a 4-device 'pipe' mesh) and composes with
the same body functions — see tests/test_pipeline.py.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

Array = jax.Array


def pipeline_apply(
    body: Callable,  # (layer_params, x) -> x, one layer
    stacked_params,  # leaves [L, ...]
    x: Array,        # [n_micro, mb, ...] microbatched activations
    mesh: Mesh,
    axis: str = "pipe",
):
    """Run L = n_stages*layers_per_stage layers over microbatches."""
    n_stages = mesh.shape[axis]
    L = jax.tree.leaves(stacked_params)[0].shape[0]
    assert L % n_stages == 0, (L, n_stages)
    n_micro = x.shape[0]

    def stage_fn(params_stage, xs):
        # params_stage: leaves [L/n_stages, ...] (this stage's layers)
        # xs: [n_micro, mb, ...] (only stage 0 reads real inputs)
        idx = lax.axis_index(axis)
        steps = n_micro + n_stages - 1
        mb_shape = xs.shape[1:]
        buf = jnp.zeros((n_micro,) + mb_shape, xs.dtype)  # outputs (last stage)

        def apply_stage(x):
            def layer(x, lp):
                return body(lp, x), None
            x, _ = lax.scan(layer, x, params_stage)
            return x

        def step(carry, t):
            buf, cur = carry
            m = t - idx  # microbatch index at this stage
            # stage 0 injects fresh microbatch m = t
            inject = jnp.where(t < n_micro, t, 0)
            x_in = jnp.where(idx == 0, xs[inject], cur)
            active = (m >= 0) & (m < n_micro)
            y = jnp.where(active, apply_stage(x_in), x_in)
            # last stage records its finished microbatch
            buf = jnp.where(
                (idx == n_stages - 1) & active,
                lax.dynamic_update_index_in_dim(
                    buf, y, jnp.clip(m, 0, n_micro - 1), 0
                ),
                buf,
            )
            # hand activations to the next stage
            nxt = lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)]
            )
            return (buf, nxt), None

        # initial carry must be marked varying over the pipe axis (each
        # stage's carry evolves independently between ppermutes); JAX
        # before 0.5 has no varying-type system (no lax.pcast) and needs
        # no marking.
        pcast = getattr(lax, "pcast", None)
        mark_varying = (
            (lambda a: pcast(a, (axis,), to="varying")) if pcast else (lambda a: a)
        )
        init = jax.tree.map(
            mark_varying, (buf, jnp.zeros(mb_shape, xs.dtype))
        )
        (buf, _), _ = lax.scan(step, init, jnp.arange(steps))
        # broadcast the last stage's outputs to all stages (masked psum:
        # ppermute requires unique sources, one-to-all is a reduction)
        out = lax.psum(
            jnp.where(idx == n_stages - 1, buf, jnp.zeros_like(buf)), axis
        )
        return out

    pspec_params = jax.tree.map(lambda _: P(axis), stacked_params)
    fn = shard_map(
        stage_fn,
        mesh=mesh,
        in_specs=(pspec_params, P()),
        out_specs=P(),
    )
    return fn(stacked_params, x)


def make_pp_loss(body, n_micro: int):
    """Loss over the pipelined stack (for tests / PP training demos)."""

    def loss_fn(stacked_params, x, targets, mesh):
        y = pipeline_apply(body, stacked_params, x, mesh)
        return jnp.mean(jnp.square(y - targets))

    return loss_fn
