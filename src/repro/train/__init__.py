"""train substrate."""
