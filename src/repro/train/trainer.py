"""Training step + driver loop.

`make_train_step` builds the jitted step for any arch config: gradient
accumulation over microbatches (lax.scan), per-layer remat (inside the
model's scan body), optional error-feedback int8 gradient compression,
donation of the train state.

`Trainer` is the host-side driver: data pipeline, periodic async
checkpoints, step timing (feeding the straggler detector of
`runtime.supervisor`), and metrics.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.sharding import MeshAxes
from repro.models.transformer import train_loss
from repro.optim import adamw
from repro.optim.compression import ef_roundtrip, init_error_buf

Array = jax.Array


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    error_buf: Any  # compression error feedback (None-like empty dict if off)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat: bool = True
    dtype: Any = jnp.bfloat16
    compress_grads: bool = False
    # cast f32 master weights to the compute dtype ONCE before the layer
    # stack: the per-layer FSDP all-gathers then move bf16 (2x fewer ICI
    # bytes) and the backward produces bf16 gradients for the wire
    cast_params_once: bool = False
    # constrain gradients to the parameter shardings: XLA then emits
    # reduce-scatter into the FSDP shard instead of a full all-reduce
    constrain_grads: bool = False
    optimizer: adamw.AdamWConfig = dataclasses.field(
        default_factory=adamw.AdamWConfig
    )


def init_train_state(
    cfg: ArchConfig, tcfg: TrainConfig, key: Array
) -> TrainState:
    from repro.models.transformer import init_params

    params = init_params(cfg, key)
    opt = adamw.init(params)
    ebuf = init_error_buf(params) if tcfg.compress_grads else {}
    return TrainState(params, opt, ebuf)


def make_train_step(
    cfg: ArchConfig,
    tcfg: TrainConfig,
    axes: Optional[MeshAxes] = None,
) -> Callable[[TrainState, dict], tuple]:
    """Returns step(state, batch) -> (state, metrics). jit at call site
    (the launcher jits with shardings + donation)."""

    def loss_fn(params, batch):
        if tcfg.cast_params_once:
            params = jax.tree.map(
                lambda p: p.astype(tcfg.dtype)
                if p.dtype == jnp.float32 and p.ndim >= 2
                else p,
                params,
            )
        return train_loss(
            cfg, params, batch, axes=axes, dtype=tcfg.dtype, remat=tcfg.remat
        )

    def step(state: TrainState, batch: dict):
        n_micro = tcfg.microbatches
        if n_micro > 1:
            # grad accumulation: split leading batch dim, scan microbatches
            def split(x):
                b = x.shape[0]
                return x.reshape((n_micro, b // n_micro) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                loss_sum, grads_sum = carry
                loss, grads = jax.value_and_grad(loss_fn)(state.params, mb)
                grads_sum = jax.tree.map(jnp.add, grads_sum, grads)
                return (loss_sum + loss, grads_sum), None

            zero_grads = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (loss_sum, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), zero_grads), micro
            )
            loss = loss_sum / n_micro
            grads = jax.tree.map(lambda g: g / n_micro, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)

        if tcfg.constrain_grads and axes is not None:
            from repro.models.sharding import param_specs

            grads = jax.lax.with_sharding_constraint(
                grads, param_specs(axes, grads)
            )

        ebuf = state.error_buf
        if tcfg.compress_grads:
            grads, ebuf = ef_roundtrip(grads, ebuf)

        params, opt, om = adamw.update(
            tcfg.optimizer, grads, state.opt, state.params
        )
        metrics = {"loss": loss, **om}
        return TrainState(params, opt, ebuf), metrics

    return step


class Trainer:
    """Host driver: data, checkpoints, timing, failure hooks."""

    def __init__(
        self,
        cfg: ArchConfig,
        tcfg: TrainConfig,
        data_iter,
        step_fn: Callable,
        state: TrainState,
        ckpt_manager=None,
        ckpt_every: int = 100,
        hooks: Optional[Dict[str, Callable]] = None,
    ) -> None:
        self.cfg = cfg
        self.tcfg = tcfg
        self.data = iter(data_iter)
        self.step_fn = step_fn
        self.state = state
        self.ckpt = ckpt_manager
        self.ckpt_every = ckpt_every
        self.step_idx = 0
        self.step_times: list = []
        self.metrics_log: list = []
        self.hooks = hooks or {}

    def run(self, n_steps: int) -> Dict[str, float]:
        last = {}
        for _ in range(n_steps):
            batch = next(self.data)
            if "pre_step" in self.hooks:
                self.hooks["pre_step"](self.step_idx)
            t0 = time.perf_counter()
            self.state, metrics = self.step_fn(self.state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            last = {k: float(v) for k, v in metrics.items()}
            last["step_time_s"] = dt
            self.metrics_log.append({"step": self.step_idx, **last})
            self.step_idx += 1
            if self.ckpt is not None and self.step_idx % self.ckpt_every == 0:
                self.ckpt.save(self.step_idx, self.state)
        return last
