"""Jit-resident continuous-batching engine — one compiled decode step.

The host-orchestrated `serve.engine.ServeEngine` proves the buddy
system *admits* realistic serving traffic, but every decode token pays
a host round-trip: tables are rebuilt in numpy, logits sync back for
argmax, and the allocator of record is the host-side `NBBSRef`.  This
module is the ROADMAP's "millions-of-users" refactor: the whole per-
iteration loop — paged decode attention, in-graph page allocation for
lanes crossing a page boundary, greedy sampling, retirement detection,
and the burst free of retired sequences — is one `jax.jit`-compiled
`engine_step` over a device-resident `EngineState`, with **zero host
synchronization inside the step** (verified by the trace-count /
transfer-guard test in tests/test_serving.py).  The Python
`JitServeEngine` is reduced to a thin request-queue shim that drains
arrivals into the compiled step at chunk boundaries.

Design (docs/design.md §8):

  * the engine runs `max_batch` fixed *lanes*; a lane is either empty
    (`seq_id == -1`) or carries one sequence.  All shapes are static,
    so N decode steps re-use one executable;
  * KV pages are allocated *one leaf unit at a time*: admission claims
    the prompt's pages through the same in-graph wavefront
    (`nb_pool_alloc_pages`, all-or-nothing with in-graph rollback), and
    decode steps claim one page for every lane whose next token starts
    a page (`ctx == n_pages * page_tokens`).  Leaf-only allocation
    means the engine pytree needs no index[]: a page handle is the
    (shard, unit_offset) pair stored directly in the lane's page
    table, and the global page id is `shard * pages_per_shard + off`;
  * retirement (out-budget reached, EOS, or an in-step allocation
    overflow) frees **all** of a lane's pages as one merged
    `pool_free_round` burst inside the same compiled step;
  * the prompt's last token is decoded by the *engine*, not prefill:
    prefill (bucketed to power-of-two lengths so compiles are bounded)
    only populates the KV pages of positions `0..S-2`, and the lane
    enters with `ctx = S-1, last_tok = prompt[-1]`.  The first engine
    step then computes position S-1 through the paged path — identical
    attention set, and no per-prompt-length recompiles;
  * `engine_step` returns a schema-checked metrics dict (obs/schema.py
    `ENGINE_METRICS`: pages allocated/freed, overflow lanes, probe
    overflows, free pages + largest allocatable run from the in-graph
    occupancy scan, RMW counters, rounds/probe-distance histograms)
    that the shim accumulates lazily through `obs.metrics.merge` —
    reading them is the *caller's* sync, never the step's.  With
    `ring_capacity > 0` the state also carries an in-graph event ring
    (obs/ring.py) recording one event per step; `snapshot()` drains
    metrics + ring + host-phase spans into the export format
    `obs/trace_export.py` renders as a Perfetto trace.

Failure semantics mirror the PR 1/3 hardening exactly (regression
tests in tests/test_serving.py): requests that can never fit the lane
geometry are rejected at admission instead of head-of-line blocking,
and junk page handles in a lane table are dropped by the free round's
validity mask instead of aliasing live pages.

The differential oracle is `serve.oracle.HostOracleEngine` — the same
scheduling policy run from Python against per-shard `NBBSRef` trees —
which must produce identical page assignments, retirement order, and
final pool occupancy on a replayed trace.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from collections import Counter
from typing import Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.concurrent import BUNCH_PACKED, TreeConfig, UNPACKED
from repro.core.fastpath import FastPathConfig
from repro.core.magazine import MagazineConfig, MagazineState, mag_total
from repro.core.nbbs_jax import (
    nb_pool_alloc_pages,
    nb_pool_alloc_pages_mag,
    nb_pool_free_pages,
    nb_pool_free_pages_mag,
)
from repro.core.pool import (
    PoolConfig,
    home_shard,
    pool_free_units,
    pool_init_magazines,
    pool_largest_run,
    pool_mag_free_per_shard,
)
from repro.obs import metrics as om
from repro.obs import ring as oring
from repro.obs.schema import ENGINE_METRICS
from repro.obs.trace_export import SNAPSHOT_VERSION
from repro.serve.engine import Request
from repro.serve.paged_decode import paged_decode_step, serve_prefill

Array = jax.Array
Metrics = om.Metrics

# Incremented inside the traced step body: tracing happens only at
# compile time, so tests can assert "N steps, one trace" (the
# no-recompilation guarantee) by watching this counter.
TRACE_COUNTS: Counter = Counter()


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static geometry of the jitted engine (hashable -> one compile
    per geometry, shared across engine instances)."""

    arch: ArchConfig
    num_pages: int
    page_tokens: int
    max_batch: int
    max_lane_pages: int
    max_out: int
    n_shards: int = 1
    layout: str = "unpacked"
    eos: Optional[int] = None
    impl: str = "auto"
    dtype: str = "float32"
    max_rounds: int = 64
    # fixed-size fast path (core/fastpath.py): a per-shard bitmap slab
    # of single pages carved out of the buddy tree, probed in-graph
    # before the buddy climb on every decode-boundary alloc
    fastpath: bool = False
    fastpath_slab_level: int = 2
    # per-lane magazine capacity (core/magazine.py): every engine lane
    # keeps a LIFO of its own retired pages and recycles them with zero
    # shared-state RMWs; 0 disables magazines entirely (no state, no
    # graph ops)
    magazines: int = 0
    magazine_refill: int = 0
    # in-graph event ring capacity (obs/ring.py); 0 disables the ring
    # (pushes become no-op scatters, so telemetry-off pays nothing)
    ring_capacity: int = 0

    def __post_init__(self):
        if self.num_pages & (self.num_pages - 1):
            raise ValueError("num_pages must be a power of two")
        if self.ring_capacity < 0:
            raise ValueError("ring_capacity must be >= 0")
        if self.n_shards < 1 or (self.n_shards & (self.n_shards - 1)):
            raise ValueError("n_shards must be a power of two >= 1")
        if self.num_pages % self.n_shards:
            raise ValueError("num_pages must divide evenly across shards")
        if self.layout not in ("unpacked", "bunch-packed"):
            raise ValueError(f"unknown tree layout {self.layout!r}")
        if self.magazines < 0 or self.magazine_refill < 0:
            raise ValueError("magazines/magazine_refill must be >= 0")
        if self.fastpath or self.magazines:
            self.pool_config()  # fail fast on bad slab/magazine geometry

    @property
    def pages_per_shard(self) -> int:
        return self.num_pages // self.n_shards

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def pool_config(self) -> PoolConfig:
        depth = (self.pages_per_shard - 1).bit_length()
        layout = BUNCH_PACKED if self.layout == "bunch-packed" else UNPACKED
        fp = (
            FastPathConfig(level=None, slab_level=self.fastpath_slab_level)
            if self.fastpath
            else None
        )
        mcfg = (
            MagazineConfig(
                mag_cap=self.magazines, refill_batch=self.magazine_refill
            )
            if self.magazines
            else None
        )
        return PoolConfig(
            TreeConfig(depth=depth, max_level=0, layout=layout),
            self.n_shards,
            fastpath=fp,
            magazines=mcfg,
        )

    def lane_capacity_tokens(self) -> int:
        return self.max_lane_pages * self.page_tokens


class EngineState(NamedTuple):
    """Device-resident engine state, threaded through `engine_step`."""

    trees: Array       # [S, n_state_words] pool tree state (layout dtype)
    kv_k: Array        # [L, P, page, Hkv, D] global KV page pool
    kv_v: Array
    page_shard: Array  # int32[B, MP]  page handle shard, -1 = no page
    page_off: Array    # int32[B, MP]  page handle unit offset
    seq_id: Array      # int32[B]      -1 = empty lane
    ctx: Array         # int32[B]      tokens currently in the KV cache
    n_pages: Array     # int32[B]      pages mapped in the lane table
    last_tok: Array    # int32[B]      next decode input token
    out_toks: Array    # int32[B, MO]  generated tokens
    n_out: Array       # int32[B]      generated-so-far
    max_new: Array     # int32[B]      per-lane output budget
    active: Array      # bool[B]       decoding this step?
    overflowed: Array  # bool[B]       retired by in-step alloc failure
    done_step: Array   # int32[B]      step index of retirement, -1 live
    step_no: Array     # int32 scalar  global step counter
    ring: oring.EventRing  # in-graph event ring (cap 0 = disabled)
    mag_pages: Array   # int32[B, mag_cap] per-lane magazine (gid, -1=empty)
    mag_depth: Array   # int32[B]          magazine fill depth


def _engine_mags(ecfg: EngineConfig, state: EngineState) -> MagazineState:
    return MagazineState(pages=state.mag_pages, depth=state.mag_depth)


def _zero_metrics(ecfg: EngineConfig) -> Metrics:
    """Fresh all-zero engine metrics (the schema's `ENGINE_METRICS` set;
    per-shard gauges sized to the pool geometry)."""
    return om.zeros(
        ENGINE_METRICS, vector_lens={"free_pages_shard": ecfg.n_shards}
    )


def init_engine_state(ecfg: EngineConfig) -> EngineState:
    arch = ecfg.arch
    B, MP, MO = ecfg.max_batch, ecfg.max_lane_pages, ecfg.max_out
    pcfg = ecfg.pool_config()
    kv_shape = (
        arch.n_layers, ecfg.num_pages, ecfg.page_tokens,
        arch.n_kv_heads, arch.head_dim,
    )
    return EngineState(
        trees=pcfg.empty_trees(),
        kv_k=jnp.zeros(kv_shape, ecfg.jdtype),
        kv_v=jnp.zeros(kv_shape, ecfg.jdtype),
        page_shard=jnp.full((B, MP), -1, jnp.int32),
        page_off=jnp.full((B, MP), -1, jnp.int32),
        seq_id=jnp.full((B,), -1, jnp.int32),
        ctx=jnp.zeros((B,), jnp.int32),
        n_pages=jnp.zeros((B,), jnp.int32),
        last_tok=jnp.zeros((B,), jnp.int32),
        out_toks=jnp.zeros((B, MO), jnp.int32),
        n_out=jnp.zeros((B,), jnp.int32),
        max_new=jnp.zeros((B,), jnp.int32),
        active=jnp.zeros((B,), bool),
        overflowed=jnp.zeros((B,), bool),
        done_step=jnp.full((B,), -1, jnp.int32),
        step_no=jnp.int32(0),
        ring=oring.make_ring(ecfg.ring_capacity),
        **_init_mag_fields(ecfg),
    )


def _init_mag_fields(ecfg: EngineConfig) -> dict:
    """Fresh magazine arrays: one lane per engine lane when magazines
    are on; zero-width placeholders (no memory, no graph ops) when off."""
    B = ecfg.max_batch
    if ecfg.magazines:
        mags = pool_init_magazines(ecfg.pool_config(), B)
        return {"mag_pages": mags.pages, "mag_depth": mags.depth}
    return {
        "mag_pages": jnp.zeros((B, 0), jnp.int32),
        "mag_depth": jnp.zeros((B,), jnp.int32),
    }


def global_tables(ecfg: EngineConfig, page_shard: Array, page_off: Array) -> Array:
    """Device-table view: global page ids, -1 padded — what the paged-
    attention kernel consumes (shard base folded in, mirroring the host
    `PagedKVManager.block_table` numbering)."""
    return jnp.where(
        page_shard >= 0,
        page_shard * ecfg.pages_per_shard + page_off,
        -1,
    )


# ---------------------------------------------------------------------------
# The compiled step
# ---------------------------------------------------------------------------


def _engine_step_impl(
    ecfg: EngineConfig, params: dict, state: EngineState
) -> Tuple[EngineState, Metrics]:
    TRACE_COUNTS[ecfg] += 1  # python side effect: fires at trace only
    pcfg = ecfg.pool_config()
    B, MP, MO = ecfg.max_batch, ecfg.max_lane_pages, ecfg.max_out
    pt = ecfg.page_tokens
    bidx = jnp.arange(B)

    # -- 1. in-graph page allocation for lanes crossing a page boundary
    with jax.named_scope("nbbs_alloc"):
        need = state.active & (state.ctx == state.n_pages * pt)
        need = need & (state.n_pages < MP)  # lane table full = overflow
        if ecfg.magazines:
            # magazine-first claim: a lane that stashed a page at a
            # previous retirement pops it back with zero shared-state
            # RMWs; misses fall through into the same round's
            # fastpath-then-tree wavefront.  Every engine lane owns its
            # own magazine, so the claim rank is identically zero — no
            # group-rank sort in the compiled step
            trees, mags, a_shard, a_off, ok, astats = nb_pool_alloc_pages_mag(
                pcfg, state.trees, _engine_mags(ecfg, state), need,
                state.seq_id, ecfg.max_rounds, mag_lane=bidx,
                mag_rank=jnp.zeros(B, jnp.int32),
            )
        else:
            mags = _engine_mags(ecfg, state)
            trees, a_shard, a_off, ok, astats = nb_pool_alloc_pages(
                pcfg, state.trees, need, state.seq_id, ecfg.max_rounds
            )
        pos = jnp.clip(state.n_pages, 0, MP - 1)
        page_shard = state.page_shard.at[bidx, pos].set(
            jnp.where(ok, a_shard, state.page_shard[bidx, pos])
        )
        page_off = state.page_off.at[bidx, pos].set(
            jnp.where(ok, a_off, state.page_off[bidx, pos])
        )
        n_pages = state.n_pages + ok.astype(jnp.int32)
        overflow_now = (
            state.active & (state.ctx == state.n_pages * pt)
        ) & ~ok

    # -- 2. one paged decode for every writable lane ------------------
    with jax.named_scope("paged_decode"):
        writable = state.active & ~overflow_now
        tables = global_tables(ecfg, page_shard, page_off)
        pool = {"k": state.kv_k, "v": state.kv_v}
        logits, pool = paged_decode_step(
            ecfg.arch, params, pool, tables, state.ctx, state.last_tok,
            page_tokens=pt, impl=ecfg.impl, dtype=ecfg.jdtype,
            active=writable,
        )
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)

        wrote = writable
        ctx = state.ctx + wrote.astype(jnp.int32)
        out_pos = jnp.clip(state.n_out, 0, MO - 1)
        out_toks = state.out_toks.at[bidx, out_pos].set(
            jnp.where(wrote, nxt, state.out_toks[bidx, out_pos])
        )
        n_out = state.n_out + wrote.astype(jnp.int32)
        last_tok = jnp.where(wrote, nxt, state.last_tok)

    # -- 3+4. retirement + burst free of every retired lane's pages ---
    with jax.named_scope("retire_free"):
        finished = wrote & (n_out >= state.max_new)
        if ecfg.eos is not None:
            finished = finished | (wrote & (nxt == ecfg.eos))
        retire = finished | overflow_now

        f_active = (retire[:, None] & (page_shard >= 0)).reshape(-1)
        if ecfg.magazines:
            # retired lanes stash their pages into their own magazine
            # first (up to mag_cap); the overflow falls through into
            # the same merged free burst.  Block tables fill prefix-
            # wise with distinct pages the lane allocated, so the
            # stash rank is the column index and the handles are
            # known-owned — both stash-phase fast paths apply
            # (no B*MP-wide sort, no [S, B*MP] occupancy re-derivation)
            f_lane = jnp.broadcast_to(bidx[:, None], (B, MP)).reshape(-1)
            f_rank = jnp.broadcast_to(
                jnp.arange(MP, dtype=jnp.int32)[None, :], (B, MP)
            ).reshape(-1)
            trees, mags, freed, fstats = nb_pool_free_pages_mag(
                pcfg, trees, mags,
                page_shard.reshape(-1), page_off.reshape(-1), f_active,
                mag_lane=f_lane, mag_rank=f_rank, assume_owned=True,
            )
        else:
            trees, freed, fstats = nb_pool_free_pages(
                pcfg, trees,
                page_shard.reshape(-1), page_off.reshape(-1), f_active,
            )
        page_shard = jnp.where(retire[:, None], -1, page_shard)
        page_off = jnp.where(retire[:, None], -1, page_off)
        n_pages = jnp.where(retire, 0, n_pages)
        active = state.active & ~retire
        overflowed = state.overflowed | overflow_now
        done_step = jnp.where(
            retire & (state.done_step < 0), state.step_no, state.done_step
        )

    # -- 5. telemetry: named metrics + one ring event per live step ---
    with jax.named_scope("telemetry"):
        fp_shard = pool_free_units(pcfg, trees)  # int32[S], one scan
        if ecfg.magazines:
            # stashed pages are allocated in the tree's eyes but
            # instantly claimable: capacity gauges must count them
            fp_shard = fp_shard + pool_mag_free_per_shard(pcfg, mags)
        free_total = fp_shard.sum(dtype=jnp.int32)
        won = ok.sum(dtype=jnp.int32)
        freed_n = freed.sum(dtype=jnp.int32)
        ring = oring.push(
            state.ring,
            oring.event(
                oring.EV_STEP,
                step=state.step_no,
                lanes_won=won,
                lanes_overflowed=overflow_now.sum(dtype=jnp.int32),
                lanes_spilled=astats["fastpath_spills"],
                frees_merged=freed_n,
                rounds=astats["rounds"],
                free_pages=free_total,
            ),
            mask=state.active.any(),
        )

        m = _zero_metrics(ecfg)
        m["alloc_pages"] = won
        m["freed_pages"] = freed_n
        m["overflow_lanes"] = overflow_now.sum(dtype=jnp.int32)
        m["probe_overflows"] = astats["overflows"]
        m["retired"] = retire.sum(dtype=jnp.int32)
        m["active_lanes"] = active.sum(dtype=jnp.int32)
        m["alloc_rounds"] = astats["rounds"]
        m["merged_writes"] = astats["merged_writes"]
        m["logical_rmws"] = astats["logical_rmws"]
        m["free_merged_writes"] = fstats["free_merged_writes"]
        m["free_logical_rmws"] = fstats["free_logical_rmws"]
        m["free_pages"] = free_total
        m["free_pages_shard"] = fp_shard
        run = pool_largest_run(pcfg, trees)
        if ecfg.magazines:
            # a non-empty magazine can always serve a 1-run
            run = jnp.where(mag_total(mags) > 0, jnp.maximum(run, 1), run)
            m["magazine_hits"] = astats["magazine_hits"]
            m["magazine_spills"] = (
                astats["magazine_spills"] + fstats["magazine_spills"]
            )
            m["magazine_refills"] = astats["magazine_refills"]
        m["largest_run"] = run
        m["fastpath_hits"] = astats["fastpath_hits"]
        m["fastpath_spills"] = astats["fastpath_spills"]
        # ring counters as per-step deltas (merge sums them back up)
        m["ring_events"] = ring.count - state.ring.count
        m["ring_dropped"] = oring.dropped(ring) - oring.dropped(state.ring)
        # rounds-to-completion of this step's page-boundary wavefront
        m = om.observe(m, "alloc_rounds_hist", astats["rounds"])
        # probe distance of each won allocation (0 = home shard)
        home = home_shard(pcfg, state.seq_id)
        dist = (a_shard - home) % pcfg.n_shards
        m = om.observe_many(m, "probe_distance_hist", dist, ok)

    new_state = EngineState(
        trees=trees, kv_k=pool["k"], kv_v=pool["v"],
        page_shard=page_shard, page_off=page_off,
        seq_id=state.seq_id, ctx=ctx, n_pages=n_pages,
        last_tok=last_tok, out_toks=out_toks, n_out=n_out,
        max_new=state.max_new, active=active, overflowed=overflowed,
        done_step=done_step, step_no=state.step_no + 1,
        ring=ring, mag_pages=mags.pages, mag_depth=mags.depth,
    )
    return new_state, m


# the EngineState argument is donated everywhere below: the KV pool is
# by far the largest buffer in the state, and donation lets XLA update
# it in place instead of copying pool-sized buffers every dispatch
@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
def engine_step(
    ecfg: EngineConfig, params: dict, state: EngineState
) -> Tuple[EngineState, Metrics]:
    """One fully-fused decode iteration (alloc + decode + free)."""
    return _engine_step_impl(ecfg, params, state)


@functools.partial(jax.jit, static_argnums=(0, 3), donate_argnums=(2,))
def engine_run(
    ecfg: EngineConfig, params: dict, state: EngineState, num_steps: int
) -> Tuple[EngineState, Metrics]:
    """`num_steps` fused decode iterations under one `lax.scan` — a
    whole chunk of tokens per dispatch, still zero host syncs.  Returns
    (state, metrics with a leading [num_steps] axis)."""
    def body(st, _):
        return _engine_step_impl(ecfg, params, st)

    return jax.lax.scan(body, state, None, length=num_steps)


# ---------------------------------------------------------------------------
# Admission-boundary helpers (host calls these *between* decode bursts)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnums=(0,))
def admit_pages(
    ecfg: EngineConfig,
    trees: Array,
    mag_pages: Array,
    mag_depth: Array,
    seq_id: Array,
    need: Array,
) -> Tuple[Array, ...]:
    """All-or-nothing in-graph claim of `need` prompt pages for one
    sequence: every page is a leaf-unit wavefront lane homed by the
    sequence id; on partial failure the successes are rolled back by
    the same merged free pass, so a failed admission leaves the pool
    bit-identical.  Returns (trees, mag_pages, mag_depth, shards[MP],
    offs[MP], admitted, probe_overflows, fastpath_hits,
    fastpath_spills, magazine_spills) — the fastpath counters include
    rolled-back claims, matching the oracle's accounting.

    Admission is *magazine-oblivious* on the claim side (a prompt's
    pages are not any lane's recycled working set), but the exhaustion
    spill-back still applies: when every probe fails and magazines
    hold pages, the whole stash spills back in one burst and the
    failed pages retry — so a full-looking pool whose capacity is
    parked in magazines still admits.  A spill mutates trees and
    magazines even when the admission ultimately fails; callers must
    persist both unconditionally."""
    pcfg = ecfg.pool_config()
    MP = ecfg.max_lane_pages
    lanes = jnp.arange(MP)
    active = lanes < need
    lane_ids = jnp.full((MP,), seq_id, jnp.int32)
    mag_spills = jnp.int32(0)
    if ecfg.magazines:
        mags = MagazineState(pages=mag_pages, depth=mag_depth)
        trees1, mags, shard, off, ok, stats = nb_pool_alloc_pages_mag(
            pcfg, trees, mags, active, lane_ids, ecfg.max_rounds
        )
        mag_pages, mag_depth = mags.pages, mags.depth
        mag_spills = stats["magazine_spills"]
    else:
        trees1, shard, off, ok, stats = nb_pool_alloc_pages(
            pcfg, trees, active, lane_ids, ecfg.max_rounds
        )
    admitted = ok.sum(dtype=jnp.int32) == need
    trees_rb, _, _ = nb_pool_free_pages(
        pcfg, trees1, shard, off, ok & jnp.logical_not(admitted)
    )
    trees_out = jnp.where(admitted, trees1, trees_rb)
    keep = admitted & ok
    return (
        trees_out,
        mag_pages,
        mag_depth,
        jnp.where(keep, shard, -1),
        jnp.where(keep, off, -1),
        admitted,
        stats["overflows"],
        stats["fastpath_hits"],
        stats["fastpath_spills"],
        mag_spills,
    )


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def prefill_insert(
    ecfg: EngineConfig,
    state: EngineState,
    lane: Array,        # int32 scalar: empty lane index
    seq_id: Array,      # int32 scalar
    shards: Array,      # int32[MP] from admit_pages
    offs: Array,        # int32[MP]
    n_pages: Array,     # int32 scalar: pages actually claimed
    kv_len: Array,      # int32 scalar: prompt tokens to copy (= S-1)
    cache_k: Array,     # [L, Spad, Hkv, D] prefill KV (bucketed)
    cache_v: Array,
    last_tok: Array,    # int32 scalar: prompt's final token
    max_new: Array,     # int32 scalar
) -> EngineState:
    """Insert an admitted sequence into an empty lane: scatter the
    prefill KV of positions 0..kv_len-1 into its pages and set the lane
    registers so the next `engine_step` decodes position kv_len (the
    prompt's last token) through the paged path."""
    pt, P, MP = ecfg.page_tokens, ecfg.num_pages, ecfg.max_lane_pages
    gpage = jnp.where(shards >= 0, shards * ecfg.pages_per_shard + offs, P)
    Spad = cache_k.shape[1]
    t = jnp.arange(Spad)
    mask = t < kv_len
    pidx = gpage[jnp.clip(t // pt, 0, MP - 1)]
    pidx = jnp.where(mask, pidx, P)  # OOB page -> dropped write
    slot = t % pt
    kv_k = state.kv_k.at[:, pidx, slot].set(cache_k, mode="drop")
    kv_v = state.kv_v.at[:, pidx, slot].set(cache_v, mode="drop")
    return state._replace(
        kv_k=kv_k, kv_v=kv_v,
        page_shard=state.page_shard.at[lane].set(shards),
        page_off=state.page_off.at[lane].set(offs),
        seq_id=state.seq_id.at[lane].set(seq_id),
        ctx=state.ctx.at[lane].set(kv_len),
        n_pages=state.n_pages.at[lane].set(n_pages),
        last_tok=state.last_tok.at[lane].set(last_tok),
        n_out=state.n_out.at[lane].set(0),
        max_new=state.max_new.at[lane].set(max_new),
        active=state.active.at[lane].set(True),
        overflowed=state.overflowed.at[lane].set(False),
        done_step=state.done_step.at[lane].set(-1),
    )


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1,))
def clear_lanes(
    ecfg: EngineConfig, state: EngineState, mask: Array
) -> EngineState:
    """Reset drained lanes to empty (their pages were already freed by
    the retirement burst inside `engine_step`)."""
    return state._replace(
        seq_id=jnp.where(mask, -1, state.seq_id),
        ctx=jnp.where(mask, 0, state.ctx),
        n_out=jnp.where(mask, 0, state.n_out),
        overflowed=jnp.where(mask, False, state.overflowed),
        done_step=jnp.where(mask, -1, state.done_step),
    )


def _next_pow2(n: int) -> int:
    return 1 << max(n - 1, 0).bit_length() if n > 1 else 1


# jitted so chunked accumulation stays transfer-free; the kind-aware
# semantics (counters/histograms sum, gauges keep the latest value)
# live in obs/metrics.py, keyed off the schema — no hand-listed fields
_reduce_traj = jax.jit(om.reduce_trajectory)
_acc_stats = jax.jit(om.merge)


# ---------------------------------------------------------------------------
# The thin host shim
# ---------------------------------------------------------------------------


class JitServeEngine:
    """Request-queue shim around the compiled step.

    The public surface mirrors `ServeEngine` (submit / step /
    run_to_completion / stats / completed) so callers migrate by
    swapping the class; the difference is *where the loop lives*: all
    per-token work happens on device inside `engine_step`, and the host
    only touches the state at drain/admission boundaries (`decode_steps`
    runs whole chunks with no host sync at all)."""

    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        num_pages: int = 256,
        page_tokens: int = 16,
        max_batch: int = 8,
        max_lane_pages: Optional[int] = None,
        max_out: int = 64,
        eos_token: Optional[int] = None,
        dtype=jnp.float32,
        impl: str = "auto",
        n_shards: int = 1,
        layout: Optional[str] = None,
        max_rounds: int = 64,
        fastpath: bool = False,
        fastpath_slab_level: int = 2,
        magazines: int = 0,
        magazine_refill: int = 0,
        ring_capacity: int = 0,
    ) -> None:
        assert cfg.family in ("dense", "moe", "vlm", "audio"), (
            "paged engine covers attention families (docs/design.md §5)"
        )
        if max_lane_pages is None:
            max_lane_pages = min(num_pages, 128)
        self.ecfg = EngineConfig(
            arch=cfg,
            num_pages=num_pages,
            page_tokens=page_tokens,
            max_batch=max_batch,
            max_lane_pages=max_lane_pages,
            max_out=max_out,
            n_shards=n_shards,
            layout=layout or "unpacked",
            eos=eos_token,
            impl=impl,
            dtype=jnp.dtype(dtype).name,
            max_rounds=max_rounds,
            fastpath=fastpath,
            fastpath_slab_level=fastpath_slab_level,
            magazines=magazines,
            magazine_refill=magazine_refill,
            ring_capacity=ring_capacity,
        )
        self.cfg = cfg
        self.params = params
        self.page_tokens = page_tokens
        self.max_batch = max_batch
        self.state = init_engine_state(self.ecfg)
        self.waiting: List[Request] = []
        self.running: Dict[int, Request] = {}   # seq_id -> request
        self._lane_of: Dict[int, int] = {}
        self.completed: Dict[int, Request] = {}
        self.done_steps: Dict[int, int] = {}    # seq_id -> retire step
        self.retired_order: List[int] = []      # drain-observed order
        self.stats = {
            "admitted": 0, "queued_full": 0, "rejected": 0,
            "steps": 0, "overflow_retired": 0,
            # admission-path slab counters (decode-path ones live in
            # the device-side metric accumulator; `stat_totals` folds
            # both through one schema-aware merge)
            "admit_fastpath_hits": 0, "admit_fastpath_spills": 0,
            # admission-path magazine spill-backs (the decode-path
            # magazine counters live in the device accumulator)
            "admit_magazine_spills": 0,
        }
        self.acc = _zero_metrics(self.ecfg)  # device-side totals
        # host-phase span log for the trace exporter: wall-clock
        # windows of admissions, fused decode chunks and drains,
        # relative to engine construction
        self.spans: List[Dict] = []
        self._t_origin = time.perf_counter()

    def _now(self) -> float:
        return time.perf_counter() - self._t_origin

    def _record_span(self, phase: str, t0: float, step0: int, **extra):
        self.spans.append({
            "phase": phase, "t0": t0, "t1": self._now(),
            "step0": step0, "step1": self.stats["steps"], **extra,
        })

    # -- admission ----------------------------------------------------
    def _pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_tokens)

    def _oversized(self, req: Request) -> bool:
        """A request that can never fit the lane geometry (mirrors the
        PagedKVManager ValueError semantics: reject, don't block)."""
        total = len(req.prompt) + req.max_new_tokens
        return (
            self._pages_for(total) > self.ecfg.max_lane_pages
            or self._pages_for(total) > self.ecfg.num_pages
            or req.max_new_tokens > self.ecfg.max_out
        )

    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def _free_lanes(self) -> List[int]:
        seq = np.asarray(self.state.seq_id)
        return [int(i) for i in np.nonzero(seq < 0)[0]]

    def _admit(self) -> None:
        t0, step0 = self._now(), self.stats["steps"]
        admitted0 = self.stats["admitted"]
        free = self._free_lanes()
        while self.waiting and free:
            req = self.waiting[0]
            if self._oversized(req):
                self.waiting.pop(0)
                req.done = True
                self.completed[req.req_id] = req
                self.stats["rejected"] += 1
                continue
            need = self._pages_for(len(req.prompt) - 1)
            (
                trees, mag_pages, mag_depth, shards, offs, admitted,
                _, fp_h, fp_s, mag_sp,
            ) = admit_pages(
                self.ecfg, self.state.trees,
                self.state.mag_pages, self.state.mag_depth,
                jnp.int32(req.req_id), jnp.int32(need),
            )
            # persist trees+magazines even on failure: an exhaustion
            # spill-back moves pages from magazines into the tree
            # whether or not the admission ultimately fits
            self.state = self.state._replace(
                trees=trees, mag_pages=mag_pages, mag_depth=mag_depth
            )
            if self.ecfg.fastpath:  # admission already syncs on `admitted`
                self.stats["admit_fastpath_hits"] += int(fp_h)
                self.stats["admit_fastpath_spills"] += int(fp_s)
            if self.ecfg.magazines:
                self.stats["admit_magazine_spills"] += int(mag_sp)
            if not bool(admitted):
                self.stats["queued_full"] += 1
                break  # pool full: natural admission control
            self.waiting.pop(0)
            self._insert(free.pop(0), req, shards, offs, need)
            self.stats["admitted"] += 1
        n_adm = self.stats["admitted"] - admitted0
        if n_adm:
            self._record_span("admit", t0, step0, admitted=n_adm)

    def _insert(self, lane: int, req: Request, shards, offs, n_pages) -> None:
        S = len(req.prompt)
        arch, ecfg = self.cfg, self.ecfg
        Spad = _next_pow2(S)
        if S > 1:
            toks = np.zeros((1, Spad), np.int32)
            toks[0, :S] = req.prompt
            _, cache = serve_prefill(
                arch, self.params, {"tokens": jnp.asarray(toks)},
                max_len=Spad, dtype=ecfg.jdtype,
            )
            cache_k, cache_v = cache["k"][:, 0], cache["v"][:, 0]
        else:
            kv_shape = (
                arch.n_layers, Spad, arch.n_kv_heads, arch.head_dim
            )
            cache_k = jnp.zeros(kv_shape, ecfg.jdtype)
            cache_v = jnp.zeros(kv_shape, ecfg.jdtype)
        self.state = prefill_insert(
            ecfg, self.state,
            jnp.int32(lane), jnp.int32(req.req_id), shards, offs,
            jnp.int32(n_pages), jnp.int32(S - 1), cache_k, cache_v,
            jnp.int32(req.prompt[S - 1]), jnp.int32(req.max_new_tokens),
        )
        self.running[req.req_id] = req
        self._lane_of[req.req_id] = lane

    # -- the device loop ----------------------------------------------
    def decode_steps(self, n: int, *, fused: bool = False) -> None:
        """Run n compiled decode iterations with no host sync.  With
        `fused=True` the whole chunk is one `lax.scan` dispatch."""
        t0, step0 = self._now(), self.stats["steps"]
        with jax.profiler.TraceAnnotation("engine.decode_steps"):
            if fused:
                self.state, traj = engine_run(
                    self.ecfg, self.params, self.state, n
                )
                self.acc = _acc_stats(self.acc, _reduce_traj(traj))
            else:
                for _ in range(n):
                    self.state, stat = engine_step(
                        self.ecfg, self.params, self.state
                    )
                    self.acc = _acc_stats(self.acc, stat)
        self.stats["steps"] += n
        self._record_span("decode", t0, step0, n=n, fused=int(fused))

    def _drain(self) -> List[int]:
        """Collect retired lanes (one host sync), clear them, and
        return the drained seq ids in retirement-step order."""
        t0, step0 = self._now(), self.stats["steps"]
        seq, act, n_out, out_toks, over, done = jax.device_get((
            self.state.seq_id, self.state.active, self.state.n_out,
            self.state.out_toks, self.state.overflowed,
            self.state.done_step,
        ))
        lanes = np.nonzero((seq >= 0) & ~act)[0]
        # deterministic retirement order: by retire step, then lane id
        lanes = sorted(lanes, key=lambda i: (int(done[i]), int(i)))
        drained = []
        for lane in lanes:
            sid = int(seq[lane])
            req = self.running.pop(sid)
            self._lane_of.pop(sid)
            req.out_tokens = [int(t) for t in out_toks[lane, : n_out[lane]]]
            req.done = True
            self.completed[sid] = req
            self.done_steps[sid] = int(done[lane])
            self.retired_order.append(sid)
            if over[lane]:
                self.stats["overflow_retired"] += 1
            drained.append(sid)
        if drained:
            mask = np.zeros((self.ecfg.max_batch,), bool)
            mask[list(lanes)] = True
            self.state = clear_lanes(
                self.ecfg, self.state, jnp.asarray(mask)
            )
            self._record_span("drain", t0, step0, drained=len(drained))
        return drained

    # -- ServeEngine-compatible surface --------------------------------
    def step(self) -> int:
        """Drain + admit + one compiled decode step.  Returns the
        number of running sequences (this *is* a host sync — use
        `decode_steps` for the no-sync hot loop)."""
        self._drain()
        self._admit()
        if not self.running:
            return 0
        self.decode_steps(1)
        return int(np.asarray(self.state.active).sum())

    def run_to_completion(
        self, max_steps: int = 10_000, chunk: int = 1
    ) -> None:
        steps = 0
        while steps < max_steps:
            self._drain()
            self._admit()
            if not self.running and not self.waiting:
                return
            if not self.running:  # waiting but pool full of nothing??
                break
            n = min(chunk, max_steps - steps)
            self.decode_steps(n, fused=chunk > 1)
            steps += n

    # -- observability -------------------------------------------------
    def stat_totals(self) -> Dict[str, object]:
        """Sync and return all accumulated metrics: device accumulator
        and host scheduler counters folded through ONE schema-aware
        `obs.metrics.merge` (no hand-rolled `+=` per field).  The
        admission-path slab claims contribute to `fastpath_hits`/
        `fastpath_spills` as well as their `admit_*` breakouts, so the
        combined totals compare directly against `PageOracle`'s."""
        host = om.host_counters({
            "steps": self.stats["steps"],
            "admitted": self.stats["admitted"],
            "queued_full": self.stats["queued_full"],
            "rejected": self.stats["rejected"],
            "overflow_retired": self.stats["overflow_retired"],
            "admit_fastpath_hits": self.stats["admit_fastpath_hits"],
            "admit_fastpath_spills": self.stats["admit_fastpath_spills"],
            "fastpath_hits": self.stats["admit_fastpath_hits"],
            "fastpath_spills": self.stats["admit_fastpath_spills"],
            "admit_magazine_spills": self.stats["admit_magazine_spills"],
            "magazine_spills": self.stats["admit_magazine_spills"],
        })
        # pad both sides to the union key set (merge refuses drift);
        # device values ride the "new" side so gauges keep theirs
        acc = dict(self.acc)
        for k in host:
            acc.setdefault(k, jnp.int32(0))
        base = {k: host.get(k, jnp.zeros_like(v)) for k, v in acc.items()}
        return om.to_host(om.merge(base, acc))

    def snapshot(self) -> Dict[str, object]:
        """Drain the whole telemetry plane into the exporter's snapshot
        format (obs/trace_export.py): schema-checked metric totals, the
        event ring's surviving window, and the host-phase span log.
        This is a deliberate host sync — call it at run boundaries."""
        ecfg = self.ecfg
        return {
            "obs_schema": SNAPSHOT_VERSION,
            "source": "jit_engine",
            "config": {
                "num_pages": ecfg.num_pages,
                "page_tokens": ecfg.page_tokens,
                "max_batch": ecfg.max_batch,
                "max_lane_pages": ecfg.max_lane_pages,
                "n_shards": ecfg.n_shards,
                "layout": ecfg.layout,
                "fastpath": ecfg.fastpath,
                "magazines": ecfg.magazines,
                "ring_capacity": ecfg.ring_capacity,
            },
            "metrics": self.stat_totals(),
            "events": oring.drain(self.state.ring),
            "spans": list(self.spans),
        }

    def device_free_pages(self) -> int:
        free = int(
            pool_free_units(self.ecfg.pool_config(), self.state.trees).sum()
        )
        if self.ecfg.magazines:  # stashed pages are instantly claimable
            free += int(self.state.mag_depth.sum())
        return free

    def device_block_table(self, seq_id: int) -> np.ndarray:
        """Global-page-id table of one running sequence (debug/test
        sync; mirrors `PagedKVManager.block_table` numbering)."""
        lane = self._lane_of[seq_id]
        tables = global_tables(
            self.ecfg, self.state.page_shard, self.state.page_off
        )
        return np.asarray(tables[lane])
