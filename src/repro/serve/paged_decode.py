"""Paged decode step — the full-model consumer of the NBBS page pool.

For attention families (dense/moe/vlm/audio, and the hybrid's shared
attention sites), the decode-time KV cache lives in a global page pool
[L, P, page, Hkv, D] addressed through per-sequence block tables
produced by `memory.PagedKVManager` (buddy runs).  Each decode step:

  1. computes this token's K/V per layer,
  2. scatters them into the pool page/slot given by the block table
     (page = table[b, pos // page_tokens], slot = pos % page_tokens),
  3. attends over the pages via `kernels.ops.paged_attention`
     (Pallas on TPU, jnp reference elsewhere — same math).

Per-sequence context lengths make this the continuous-batching step:
sequences at different positions decode together in one jitted call.

The step accepts an optional `active` lane mask so it can run at a
*static* batch width inside the jit-resident engine (docs/design.md
§8): inactive lanes contribute nothing — their K/V scatter is dropped
(the page index is redirected out of bounds and the scatter uses
``mode="drop"``) and their attention context is forced to zero, so the
kernel skips every page and emits zeros.  With `active=None` the
behavior is exactly the historical all-lanes-live step.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels import ops
from repro.models import moe as moe_lib
from repro.models.attention import apply_rope
from repro.models.layers import apply_swiglu, embed, logits as lm_logits, rms_norm
from repro.models.transformer import prefill, window_array

Array = jax.Array


@functools.partial(
    jax.jit, static_argnums=(0,), static_argnames=("max_len", "dtype")
)
def serve_prefill(cfg: ArchConfig, params, batch, *, max_len, dtype):
    """Jitted prefill for the serving engines (one compile per prompt
    bucket — both engines pad prompts to a bounded set of lengths)."""
    return prefill(cfg, params, batch, max_len, dtype=dtype)


def init_pool(
    cfg: ArchConfig, num_pages: int, page_tokens: int, dtype=jnp.bfloat16
) -> dict:
    shape = (cfg.n_layers, num_pages, page_tokens, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


@functools.partial(
    jax.jit,
    static_argnums=(0,),
    static_argnames=("page_tokens", "impl", "dtype"),
)
def paged_decode_step(
    cfg: ArchConfig,
    params: dict,
    pool: dict,
    block_tables: Array,  # [B, max_pages] int32, -1 padded
    context_lens: Array,  # [B] int32 — tokens already in cache
    tokens: Array,  # [B] int32 — the new token per sequence
    *,
    page_tokens: int,
    impl: str = "auto",
    dtype=jnp.bfloat16,
    active: Array | None = None,  # bool[B]; None = all lanes live
) -> Tuple[Array, dict]:
    """Returns (logits [B, V], updated pool). Dense-family archs only."""
    assert cfg.family in ("dense", "moe", "vlm", "audio"), cfg.family
    B = tokens.shape[0]
    P = pool["k"].shape[1]
    if active is None:
        active = jnp.ones((B,), dtype=bool)
    x = embed(params["embed"], tokens[:, None], dtype, scale=cfg.embed_scale)
    positions = context_lens[:, None]  # this token's position per seq
    windows = window_array(cfg)

    # page/slot of the new token per sequence; lanes that are inactive
    # (or whose table has no page mapped at this position) are steered
    # to the out-of-bounds page P so the scatter drops their write
    # instead of aliasing page 0 / the last page
    page_raw = block_tables[
        jnp.arange(B), context_lens // page_tokens
    ]  # [B]
    page_idx = jnp.where(active & (page_raw >= 0), page_raw, P)
    slot = context_lens % page_tokens
    ctx_att = jnp.where(active, context_lens + 1, 0)

    new_k, new_v = [], []

    def body(x, xs):
        lp, window, kp, vp = xs  # kp/vp: [P, page, Hkv, D] this layer
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        q = (h @ lp["attn"]["wq"].astype(dtype)).reshape(
            B, 1, cfg.n_heads, cfg.head_dim
        )
        k = (h @ lp["attn"]["wk"].astype(dtype)).reshape(
            B, 1, cfg.n_kv_heads, cfg.head_dim
        )
        v = (h @ lp["attn"]["wv"].astype(dtype)).reshape(
            B, 1, cfg.n_kv_heads, cfg.head_dim
        )
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        # scatter this token's K/V into its page (inactive lanes were
        # redirected to the OOB page above and are dropped here)
        kp = kp.at[page_idx, slot].set(k[:, 0], mode="drop")
        vp = vp.at[page_idx, slot].set(v[:, 0], mode="drop")
        o = ops.paged_attention(
            q[:, 0],
            kp,
            vp,
            block_tables,
            ctx_att,
            softcap=cfg.attn_softcap or None,
            impl=impl,
        )
        # NOTE: sliding-window masking for local layers happens via
        # context_lens clamping at the engine level (window pages are
        # the only ones mapped); `window` kept for interface parity.
        del window
        h = o.reshape(B, 1, -1) @ lp["attn"]["wo"].astype(dtype)
        if cfg.post_norm:
            h = rms_norm(h, lp["ln1_post"], cfg.norm_eps)
        x = x + h
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            h, _ = moe_lib.apply_moe(
                lp["moe"], h, top_k=cfg.top_k,
                capacity_factor=float(cfg.n_experts), dtype=dtype,
            )
        else:
            h = apply_swiglu(lp["mlp"], h, dtype=dtype)
        if cfg.post_norm:
            h = rms_norm(h, lp["ln2_post"], cfg.norm_eps)
        return x + h, (kp, vp)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], windows, pool["k"], pool["v"])
    )
    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    lg = lm_logits(h[:, 0], table, cfg.final_softcap or None)
    return lg, {"k": ks, "v": vs}
