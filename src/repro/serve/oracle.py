"""Host-driven oracle of the jit-resident serving engine.

`HostOracleEngine` replays a request trace through exactly the same
scheduling policy as `serve.jit_engine.JitServeEngine` — same lane
assignment (lowest free lane first), same FIFO admission with
all-or-nothing prompt-page claims and rollback, same in-step page
growth at page boundaries, same retirement rules (output budget or
allocation overflow), same burst frees — but entirely from Python
against per-shard host `NBBSRef` trees (`memory.kv_cache.PageOracle`,
which emulates the device pool rounds exactly).

It runs **no model**: a decode step simply advances every writable
lane by one token.  That is sufficient for the differential contract,
because with `eos=None` the jitted engine's page assignments,
retirement order, and pool occupancy depend only on prompt lengths,
output budgets, and arrival order — never on token values.  The
differential tests (tests/test_serving.py, tests/test_properties.py)
replay one trace through both engines and assert:

  * identical per-sequence page tables while running,
  * identical retirement order and retirement steps,
  * identical final pool occupancy (total and per shard).

Anything the compiled step gets wrong — a lane double-claiming a page,
a retirement burst freeing the wrong shard, an argmax tie flipping
scheduling — shows up as a divergence from this oracle.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.memory.kv_cache import PageOracle
from repro.serve.engine import Request


class _Lane:
    __slots__ = ("seq_id", "ctx", "pages", "n_out", "max_new",
                 "active", "overflowed", "done_step")

    def __init__(self) -> None:
        self.seq_id = -1
        self.ctx = 0
        self.pages: List[int] = []  # global page ids, in append order
        self.n_out = 0
        self.max_new = 0
        self.active = False
        self.overflowed = False
        self.done_step = -1


class HostOracleEngine:
    """Scheduling-exact host mirror of `JitServeEngine` (no model)."""

    def __init__(
        self,
        *,
        num_pages: int = 256,
        page_tokens: int = 16,
        max_batch: int = 8,
        max_lane_pages: Optional[int] = None,
        max_out: int = 64,
        n_shards: int = 1,
        max_rounds: int = 64,
        fastpath: bool = False,
        fastpath_slab_level: int = 2,
        magazines: int = 0,
    ) -> None:
        if max_lane_pages is None:
            max_lane_pages = min(num_pages, 128)
        self.page_tokens = page_tokens
        self.max_batch = max_batch
        self.max_lane_pages = max_lane_pages
        self.max_out = max_out
        self.num_pages = num_pages
        self.magazines = magazines
        # one magazine per engine lane, exactly the jitted engine's
        # `mag_lane = lane index` wiring
        self.pool = PageOracle(
            num_pages,
            page_tokens,
            n_shards=n_shards,
            max_rounds=max_rounds,
            fastpath=fastpath,
            fastpath_slab_level=fastpath_slab_level,
            magazines=magazines,
            mag_lanes=max_batch if magazines else 0,
        )
        self.lanes = [_Lane() for _ in range(max_batch)]
        self.waiting: List[Request] = []
        self.running: Dict[int, Request] = {}
        self._lane_of: Dict[int, int] = {}
        self.completed: Dict[int, Request] = {}
        self.done_steps: Dict[int, int] = {}
        self.retired_order: List[int] = []
        self.step_no = 0
        self.stats = {
            "admitted": 0, "queued_full": 0, "rejected": 0,
            "steps": 0, "overflow_retired": 0,
            "admit_fastpath_hits": 0, "admit_fastpath_spills": 0,
            "admit_magazine_spills": 0,
        }

    # -- admission (mirrors JitServeEngine line for line) -------------
    def _pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 0) // self.page_tokens)

    def _oversized(self, req: Request) -> bool:
        total = len(req.prompt) + req.max_new_tokens
        return (
            self._pages_for(total) > self.max_lane_pages
            or self._pages_for(total) > self.num_pages
            or req.max_new_tokens > self.max_out
        )

    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def _free_lanes(self) -> List[int]:
        return [i for i, ln in enumerate(self.lanes) if ln.seq_id < 0]

    def _admit(self) -> None:
        free = self._free_lanes()
        while self.waiting and free:
            req = self.waiting[0]
            if self._oversized(req):
                self.waiting.pop(0)
                req.done = True
                self.completed[req.req_id] = req
                self.stats["rejected"] += 1
                continue
            need = self._pages_for(len(req.prompt) - 1)
            # all-or-nothing wavefront claim, homed by the sequence id
            # (`admit_pages`: one wavefront lane per prompt page)
            h0, s0 = self.pool.fastpath_hits, self.pool.fastpath_spills
            m0 = self.pool.magazine_spills
            # magazine-oblivious claims (no mag_lanes): admission pages
            # are nobody's recycled working set, but the exhaustion
            # spill-back inside the wavefront still applies
            got = self.pool.alloc_wavefront(
                [(k, req.req_id) for k in range(need)]
            )
            self.stats["admit_fastpath_hits"] += self.pool.fastpath_hits - h0
            self.stats["admit_fastpath_spills"] += (
                self.pool.fastpath_spills - s0
            )
            self.stats["admit_magazine_spills"] += (
                self.pool.magazine_spills - m0
            )
            pages = [got[k] for k in range(need)]
            if any(p is None for p in pages):
                self.pool.free_burst(p for p in pages if p is not None)
                self.stats["queued_full"] += 1
                break
            self.waiting.pop(0)
            lane = self.lanes[free[0]]
            self._lane_of[req.req_id] = free.pop(0)
            lane.seq_id = req.req_id
            lane.ctx = len(req.prompt) - 1
            lane.pages = pages
            lane.n_out = 0
            lane.max_new = req.max_new_tokens
            lane.active = True
            lane.overflowed = False
            lane.done_step = -1
            self.running[req.req_id] = req
            self.stats["admitted"] += 1

    # -- the decode step (mirrors `_engine_step_impl`) ----------------
    def decode_steps(self, n: int) -> None:
        for _ in range(n):
            self._decode_one()
        self.stats["steps"] += n

    def _decode_one(self) -> None:
        pt, MP = self.page_tokens, self.max_lane_pages
        # 1. page growth for lanes crossing a page boundary, as one
        #    wavefront in lane order (lane ids = sequence ids)
        needers = [
            (i, ln.seq_id) for i, ln in enumerate(self.lanes)
            if ln.active and ln.ctx == len(ln.pages) * pt and len(ln.pages) < MP
        ]
        # decode growth claims each lane's own magazine first (the
        # engine's `mag_lane = arange(B)` wiring)
        got = self.pool.alloc_wavefront(
            needers, mag_lanes=[i for i, _ in needers]
        )
        overflow = set()
        for i, _ in needers:
            page = got[i]
            if page is None:
                overflow.add(i)
            else:
                self.lanes[i].pages.append(page)
        for i, ln in enumerate(self.lanes):  # lane table full = overflow
            if ln.active and ln.ctx == len(ln.pages) * pt and i not in overflow:
                overflow.add(i)
        # 2. decode: every writable lane advances one token
        retired = []
        for i, ln in enumerate(self.lanes):
            if not ln.active:
                continue
            if i in overflow:
                ln.overflowed = True
                retired.append(i)
                continue
            ln.ctx += 1
            ln.n_out += 1
            if ln.n_out >= ln.max_new:
                retired.append(i)
        # 3. burst free of every retired lane's pages; each page stashes
        #    into its own lane's magazine first (the engine's broadcast
        #    `mag_lane` over the retirement burst)
        freed: List[int] = []
        stash_lanes: List[int] = []
        for i in retired:
            ln = self.lanes[i]
            freed.extend(ln.pages)
            stash_lanes.extend([i] * len(ln.pages))
            ln.pages = []
            ln.active = False
            ln.done_step = self.step_no
        self.pool.free_burst(freed, stash_lanes=stash_lanes)
        self.step_no += 1

    def _drain(self) -> List[int]:
        lanes = [
            i for i, ln in enumerate(self.lanes)
            if ln.seq_id >= 0 and not ln.active
        ]
        lanes.sort(key=lambda i: (self.lanes[i].done_step, i))
        drained = []
        for i in lanes:
            ln = self.lanes[i]
            sid = ln.seq_id
            req = self.running.pop(sid)
            self._lane_of.pop(sid)
            req.out_tokens = [0] * ln.n_out  # token values are not modeled
            req.done = True
            self.completed[sid] = req
            self.done_steps[sid] = ln.done_step
            self.retired_order.append(sid)
            if ln.overflowed:
                self.stats["overflow_retired"] += 1
            drained.append(sid)
            ln.seq_id = -1
            ln.ctx = 0
            ln.n_out = 0
            ln.overflowed = False
            ln.done_step = -1
        return drained

    # -- the loop (mirrors JitServeEngine) ----------------------------
    def step(self) -> int:
        self._drain()
        self._admit()
        if not self.running:
            return 0
        self.decode_steps(1)
        return sum(ln.active for ln in self.lanes)

    def run_to_completion(
        self, max_steps: int = 10_000, chunk: int = 1
    ) -> None:
        steps = 0
        while steps < max_steps:
            self._drain()
            self._admit()
            if not self.running and not self.waiting:
                return
            if not self.running:
                break
            n = min(chunk, max_steps - steps)
            self.decode_steps(n)
            steps += n

    # -- observability (same numbering as the device tables) ----------
    def stat_totals(self) -> Dict[str, int]:
        """Metric totals under the same schema names the jitted
        engine's `stat_totals` reports (keys validated against
        obs/schema.py), so differential tests compare the two sides
        key-for-key.  The slab counters are the pool's combined
        admission+decode accounting — exactly what the engine's single
        merge of host admit counters and device accumulator yields."""
        from repro.obs.schema import spec

        out = dict(self.stats)
        out["fastpath_hits"] = self.pool.fastpath_hits
        out["fastpath_spills"] = self.pool.fastpath_spills
        out["magazine_hits"] = self.pool.magazine_hits
        out["magazine_spills"] = self.pool.magazine_spills
        out["magazine_refills"] = self.pool.magazine_refills
        for name in out:
            spec(name)  # raises on unregistered metric names
        return out

    def block_table(self, seq_id: int) -> np.ndarray:
        lane = self.lanes[self._lane_of[seq_id]]
        out = np.full((self.max_lane_pages,), -1, np.int32)
        out[: len(lane.pages)] = lane.pages
        return out

    def free_pages(self) -> int:
        return self.pool.free_pages()
