"""Continuous-batching serving engine on NBBS-paged KV memory.

Host scheduler loop (the paper's concurrency scenario made concrete):
bursts of variable-length requests hit one shared page pool; admission
= buddy allocation success, growth = buddy doubling, completion frees
coalesce.  The device step is the jitted `paged_decode_step` (dense
families) — sequences at arbitrary positions decode together.

Prefill currently runs through the dense `prefill` path per admitted
request batch and its KV is copied into the sequence's pages (prompt
tokens land exactly at their page/slot addresses); decode then proceeds
entirely paged.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.memory.kv_cache import PagedKVManager
from repro.serve.paged_decode import init_pool, paged_decode_step, serve_prefill

Array = jax.Array


@dataclasses.dataclass
class Request:
    req_id: int
    prompt: np.ndarray  # [S] int32
    max_new_tokens: int
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(
        self,
        cfg: ArchConfig,
        params,
        *,
        num_pages: int = 256,
        page_tokens: int = 16,
        max_batch: int = 8,
        eos_token: Optional[int] = None,
        dtype=jnp.float32,
        impl: str = "auto",
        n_shards: int = 1,
        layout: Optional[str] = None,
        max_table_pages: Optional[int] = None,
        log_stats: bool = False,
        fastpath: bool = False,
        fastpath_slab_level: int = 2,
        magazines: int = 0,
        magazine_refill: int = 0,
        mag_lanes: Optional[int] = None,
    ) -> None:
        assert cfg.family in ("dense", "moe", "vlm", "audio"), (
            "paged engine covers attention families; SSM/hybrid use "
            "fixed-size state slots (see docs/design.md §5)"
        )
        self.cfg = cfg
        self.params = params
        self.page_tokens = page_tokens
        self.max_batch = max_batch
        self.eos = eos_token
        self.dtype = dtype
        self.impl = impl
        # n_shards > 1 splits the page pool across replicated buddy
        # trees (home-shard hashing + overflow probing; one release
        # burst per shard when sequences retire — see memory/kv_cache).
        # `layout` picks the device tree-state format for wavefront-
        # backed admission ("bunch-packed" = the §III-D packed words,
        # docs/design.md §3); handles and the engine API are unchanged.
        # `fastpath` carves the O(1) bitmap-slab front end out of each
        # shard (core/fastpath.py): single-page runs — decode growth —
        # claim slab slots and spill into the buddy climb when full.
        # `magazines` puts a per-lane LIFO of recycled single pages in
        # front of both (core/magazine.py): freed decode pages park in
        # the retiring sequence group's magazine and the next growth in
        # that group pops them back with zero allocator work.
        self.kv = PagedKVManager(
            num_pages,
            page_tokens,
            n_shards=n_shards,
            layout=layout,
            fastpath=fastpath,
            fastpath_slab_level=fastpath_slab_level,
            magazines=magazines,
            magazine_refill=magazine_refill,
            mag_lanes=mag_lanes if mag_lanes is not None else max_batch,
        )
        self.pool = init_pool(cfg, num_pages, page_tokens, dtype)
        # width of the per-sequence block tables handed to the kernel;
        # capping it (e.g. to the longest admissible sequence) keeps the
        # attention gather proportional to sequence capacity instead of
        # pool capacity
        self.max_pages = min(num_pages, max_table_pages or num_pages)
        self.running: Dict[int, Request] = {}
        self.ctx_lens: Dict[int, int] = {}
        self.waiting: List[Request] = []
        self.completed: Dict[int, Request] = {}
        self.stats = {"admitted": 0, "queued_full": 0, "rejected": 0,
                      "steps": 0}
        # opt-in per-step observability (the host-loop counterpart of
        # the jitted engine's schema-checked metrics dict;
        # fragmentation() is an O(tree) host scan, hence the flag)
        self.log_stats = log_stats
        self.step_log: List[dict] = []

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def _admit(self) -> List[Request]:
        admitted = []
        while self.waiting and len(self.running) < self.max_batch:
            req = self.waiting[0]
            need_tokens = len(req.prompt) + req.max_new_tokens
            try:
                admitted_ok = self.kv.add_sequence(req.req_id, need_tokens)
            except ValueError:
                # request exceeds the pool geometry (can never be
                # admitted): reject it instead of letting it head-of-line
                # block the queue forever
                self.waiting.pop(0)
                req.done = True
                self.completed[req.req_id] = req
                self.stats["rejected"] += 1
                continue
            if not admitted_ok:
                self.stats["queued_full"] += 1
                break  # pool full: natural admission control
            self.waiting.pop(0)
            self.running[req.req_id] = req
            self.ctx_lens[req.req_id] = len(req.prompt)
            admitted.append(req)
            self.stats["admitted"] += 1
        return admitted

    def _prefill_into_pages(self, reqs: List[Request]) -> None:
        """Run prefill per request; copy KV into its buddy pages."""
        for req in reqs:
            S = len(req.prompt)
            batch = {"tokens": jnp.asarray(req.prompt[None, :], jnp.int32)}
            lg, cache = serve_prefill(
                self.cfg, self.params, batch, max_len=S, dtype=self.dtype
            )
            table = self.kv.block_table(req.req_id, self.max_pages)
            k = np.asarray(cache["k"][:, 0])  # [L, S, Hkv, D]
            v = np.asarray(cache["v"][:, 0])
            pk = np.array(self.pool["k"])  # host copies (writable)
            pv = np.array(self.pool["v"])
            for t0 in range(0, S, self.page_tokens):
                page = int(table[t0 // self.page_tokens])
                n = min(self.page_tokens, S - t0)
                pk[:, page, :n] = k[:, t0 : t0 + n]
                pv[:, page, :n] = v[:, t0 : t0 + n]
            self.pool = {
                "k": jnp.asarray(pk),
                "v": jnp.asarray(pv),
            }
            req.out_tokens.append(int(np.argmax(np.asarray(lg)[0])))

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One engine iteration: admit + prefill + one decode step.
        Returns number of running sequences."""
        self._prefill_into_pages(self._admit())
        if not self.running:
            return 0
        ids = sorted(self.running)
        B = len(ids)
        # pad the decode batch to a power-of-two bucket (inactive rows
        # masked out inside paged_decode_step): bounds the number of
        # compiled batch shapes to log2(max_batch) instead of one per
        # distinct running-count
        B2 = 1 << max(B - 1, 0).bit_length()
        tables = np.full((B2, self.max_pages), -1, np.int32)
        tables[:B] = np.stack(
            [self.kv.block_table(i, self.max_pages) for i in ids]
        )
        ctx = np.zeros(B2, np.int32)
        ctx[:B] = [
            self.ctx_lens[i] + len(self.running[i].out_tokens) - 1
            for i in ids
        ]
        toks = np.zeros(B2, np.int32)
        toks[:B] = [self.running[i].out_tokens[-1] for i in ids]
        active = np.arange(B2) < B
        lg, self.pool = paged_decode_step(
            self.cfg,
            self.params,
            self.pool,
            jnp.asarray(tables),
            jnp.asarray(ctx),
            jnp.asarray(toks),
            page_tokens=self.page_tokens,
            impl=self.impl,
            dtype=self.dtype,
            active=jnp.asarray(active),
        )
        nxt = np.argmax(np.asarray(lg)[:B], axis=-1)
        self.stats["steps"] += 1
        retired = []
        for i, t in zip(ids, nxt):
            req = self.running[i]
            req.out_tokens.append(int(t))
            # pages for prompt+max_new were reserved at admission
            # (guaranteed-completion mode; PagedKVManager.append_tokens
            # provides the grow-on-demand mode, exercised in tests)
            hit_eos = self.eos is not None and int(t) == self.eos
            if len(req.out_tokens) >= req.max_new_tokens or hit_eos:
                req.done = True
                retired.append(i)
                self.completed[i] = req
                del self.running[i]
                del self.ctx_lens[i]
        if retired:
            # all sequences finishing this step release as one burst
            self.kv.free_sequences(retired)
        if self.log_stats:
            frag = self.kv.fragmentation()
            self.step_log.append({
                "step": self.stats["steps"],
                "active_lanes": len(self.running),
                "retired": len(retired),
                "free_pages": frag["free_pages"],
                "largest_run": frag["largest_run"],
            })
        return len(self.running)

    def run_to_completion(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.waiting and not self.running:
                return
            self.step()
