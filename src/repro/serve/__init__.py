"""serve substrate."""
