"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]

Faithfulness note (docs/design.md §5): Zamba2 interleaves one *shared*
full-attention block into the Mamba2 stack; we apply the shared block
after every `attn_every=2` Mamba2 layers (19 sites), matching the
alternation density of the reference model.  The per-site LoRA deltas of
the shared block are omitted (weight-sharing is the modelled feature).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,  # Mamba2 layers; shared attn applied every 2
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    d_inner_mult=2,
    attn_every=2,
    tie_embeddings=True,
    subquadratic=True,  # SSM backbone: long_500k runs (attention sites
    # hold the only KV caches; decode state is O(1) in the Mamba trunk)
    source="arXiv:2411.15242",
)
