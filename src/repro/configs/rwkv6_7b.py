"""rwkv6-7b [ssm] — Finch: attention-free, data-dependent decay.

32L d_model=4096 (attn-free) d_ff=14336 vocab=65536
[arXiv:2404.05892; hf]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,  # wkv heads (d_model / rwkv_head_dim); no attention
    n_kv_heads=64,
    head_dim=64,
    d_ff=14336,
    vocab_size=65536,
    rwkv_head_dim=64,
    tie_embeddings=False,
    subquadratic=True,  # O(1)-state decode: long_500k runs
    source="arXiv:2404.05892",
)
