"""Architecture registry + dry-run input specs.

`get_config(name)` resolves any assigned architecture (`--arch <id>`);
`input_specs(cfg, shape)` builds the ShapeDtypeStruct stand-ins for
every model input of a (arch x shape) dry-run cell — weak-type-correct,
shardable, no device allocation.
"""

from __future__ import annotations

import importlib
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec

_MODULES = {
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b_a66b",
    "zamba2-1.2b": "zamba2_1p2b",
    "phi3-medium-14b": "phi3_medium_14b",
    "minitron-4b": "minitron_4b",
    "gemma2-27b": "gemma2_27b",
    "stablelm-3b": "stablelm_3b",
    "llava-next-34b": "llava_next_34b",
    "musicgen-large": "musicgen_large",
    "rwkv6-7b": "rwkv6_7b",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct only — nothing is allocated)
# ---------------------------------------------------------------------------


def input_specs(cfg: ArchConfig, shape: ShapeSpec, dtype=jnp.bfloat16) -> dict:
    """Model inputs for one dry-run cell.

    train:   {tokens|embeds, labels}
    prefill: {tokens|embeds}
    decode:  {tokens [B]} (the KV cache is built by cache_specs below)
    """
    B, S = shape.global_batch, shape.seq_len
    f = jax.ShapeDtypeStruct
    if shape.kind == "train":
        batch = {"labels": f((B, S), jnp.int32)}
        if cfg.frontend != "none":
            batch["embeds"] = f((B, S, cfg.d_model), dtype)
        else:
            batch["tokens"] = f((B, S), jnp.int32)
        return batch
    if shape.kind == "prefill":
        if cfg.frontend != "none":
            return {"embeds": f((B, S, cfg.d_model), dtype)}
        return {"tokens": f((B, S), jnp.int32)}
    if shape.kind == "decode":
        return {"tokens": f((B,), jnp.int32)}
    raise ValueError(shape.kind)


def cache_specs(cfg: ArchConfig, shape: ShapeSpec, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct pytree of the decode cache for a shape cell."""
    from repro.models.transformer import init_cache

    B, S = shape.global_batch, shape.seq_len
    shapes = jax.eval_shape(
        lambda: init_cache(cfg, B, S, dtype)
    )
    return shapes
