"""gemma2-27b [dense] — local+global alternating attention, logit softcap.

46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000
[arXiv:2408.00118; hf]

head_dim=128 per the published model (q/k/v project to 32*128=4096, not
d_model).  Local layers use a 4096-token sliding window; attention logit
softcap 50.0, final logit softcap 30.0, gemma post-norms and sqrt(d)
embedding scaling.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256000,
    window_pattern=(4096, 0),  # local, global alternating
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norm=True,
    embed_scale=True,
    rope_theta=10000.0,
    tie_embeddings=True,
    subquadratic=False,  # global layers are full attention -> long_500k skipped
    source="arXiv:2408.00118",
)
