"""Assigned-architecture configs (one module per arch) + registry."""

from repro.configs.base import ArchConfig, ShapeSpec, lm_shapes  # noqa: F401
from repro.configs.registry import (  # noqa: F401
    ARCH_NAMES,
    all_configs,
    cache_specs,
    get_config,
    input_specs,
)
