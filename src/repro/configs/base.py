"""Architecture/shape configuration schema.

Every assigned architecture provides an `ArchConfig` via
`repro.configs.registry.get_config(name)`; the same dataclass drives
model construction, parameter sharding, the dry-run input specs and the
smoke tests (through `reduced()`).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (input-shape) cell of the dry-run grid."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


def lm_shapes() -> Dict[str, ShapeSpec]:
    """The four assigned LM shapes (identical for all ten archs)."""
    return {
        "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
        "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
        "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
        "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
    }


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // n_heads

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # dispatch locality: >1 computes expert positions per token block
    # (GShard per-device capacity; blocks align with the data shards)
    dispatch_blocks: int = 1
    # serving-path capacity factor; 0.0 -> drop-free (= n_experts)
    serve_capacity_factor: float = 0.0
    # "scatter" (baseline) | "einsum" (GShard one-hot matmul dispatch)
    dispatch_mode: str = "scatter"
    dispatch_group: int = 2048

    # attention pattern: per-layer sliding window, cycled over layers;
    # 0 = global attention. () = all-global.
    window_pattern: Tuple[int, ...] = ()
    attn_softcap: float = 0.0
    final_softcap: float = 0.0
    post_norm: bool = False  # gemma2-style post-block norms

    # SSM / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    d_inner_mult: int = 2
    attn_every: int = 0  # zamba2: shared attn after every N mamba layers

    # RWKV
    rwkv_head_dim: int = 64

    rope_theta: float = 10000.0
    embed_scale: bool = False  # gemma-style sqrt(d) embedding scale
    tie_embeddings: bool = True
    frontend: str = "none"  # "vision_stub" | "audio_stub" (input = embeddings)
    subquadratic: bool = False  # supports long_500k
    norm_eps: float = 1e-6

    # documentation fields
    source: str = ""
    notes: str = ""

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.d_inner_mult * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def param_count(self) -> int:
        """Analytic parameter count (drives the roofline MODEL_FLOPS)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + (
            self.n_heads * hd
        ) * d
        total = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            per_layer = attn
            if self.n_experts:
                per_layer += d * self.n_experts + self.n_experts * 3 * d * ff
            else:
                per_layer += 3 * d * ff
            total += self.n_layers * per_layer
        elif self.family == "hybrid":
            H = self.ssm_heads
            mamba = (
                self.d_model * (2 * self.d_inner + 2 * self.ssm_state + H)
                + self.d_inner * self.d_model
            )
            total += self.n_layers * mamba
            total += (self.n_layers // max(self.attn_every, 1)) * 0 + attn  # shared
        elif self.family == "ssm":
            total += self.n_layers * (6 * d * d + 2 * d * ff)  # rwkv approx
        total += V * d if self.tie_embeddings else 2 * V * d
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE: only top_k experts count)."""
        if not self.n_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        inactive = (self.n_experts - self.top_k) * 3 * d * ff * self.n_layers
        return self.param_count() - inactive

    def supported_shapes(self) -> Dict[str, ShapeSpec]:
        shapes = dict(lm_shapes())
        if not self.subquadratic:
            # long_500k needs sub-quadratic attention (docs/design.md §5).
            shapes.pop("long_500k")
        return shapes

    def reduced(self) -> "ArchConfig":
        """Smoke-test configuration of the same family (CPU-runnable)."""
        pattern_len = max(len(self.window_pattern), 1)
        n_layers = max(2, self.attn_every or 0, pattern_len)
        if self.attn_every:
            n_layers = 2 * self.attn_every
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=2 if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            n_experts=min(self.n_experts, 4),
            ssm_head_dim=16 if self.ssm_state else self.ssm_head_dim,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            rwkv_head_dim=16,
            window_pattern=tuple(
                min(w, 8) if w else 0 for w in self.window_pattern
            ),
        )
