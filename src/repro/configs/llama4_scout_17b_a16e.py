"""llama4-scout-17b-a16e [moe] — MoE, early fusion.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 16e top-1
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202048,
    n_experts=16,
    top_k=1,
    rope_theta=500000.0,
    tie_embeddings=False,
    subquadratic=False,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (unverified)",
    notes="All layers MoE top-1 per the assigned config; early-fusion "
    "multimodality enters as token embeddings (text path modelled).",
)
