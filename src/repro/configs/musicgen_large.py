"""musicgen-large [audio] — decoder-only over EnCodec tokens; backbone only.

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048
[arXiv:2306.05284; hf]

The EnCodec frontend is a STUB per the assignment: `input_specs()`
provides precomputed frame embeddings (the codebook-sum embedding of the
delay-interleaved streams); decode embeds generated audio tokens through
the code embedding table (vocab 2048).
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    rope_theta=10000.0,
    tie_embeddings=False,
    frontend="audio_stub",
    subquadratic=False,
    source="arXiv:2306.05284",
)
