"""llava-next-34b [vlm] — anyres tiling; backbone only.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The vision frontend (anyres patch tiler + projector) is a STUB per the
assignment: `input_specs()` provides precomputed patch embeddings
[B, S, d_model]; training/prefill consume them directly, decode embeds
generated text tokens through the LM table.
"""

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=10000.0,
    tie_embeddings=False,
    frontend="vision_stub",
    subquadratic=False,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (unverified)",
)
