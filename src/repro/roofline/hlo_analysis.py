"""Loop-aware roofline extraction from compiled HLO.

XLA's built-in `compiled.cost_analysis()` visits while-loop bodies ONCE
(verified empirically: a 10-iteration scanned matmul reports the flops
of one matmul), which would undercount every scanned layer stack by
n_layers x.  This module re-derives the three roofline terms by walking
the compiled HLO text itself:

  * while ops carry `backend_config={"known_trip_count":{"n": ...}}` —
    bodies/conditions are multiplied by their trip counts (nested loops
    compose recursively: layers-scan x chunk-scan works);
  * dot flops = 2 x elems(result) x contraction size (from
    lhs_contracting_dims + the operand's shape);
  * HBM-bytes model is fusion-aware: a fusion counts its operand+result
    bytes once (its internals live in registers/VMEM) — the standard
    roofline traffic model;
  * collective bytes = sum of operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute ops (per-device
    module => per-device bytes), accumulated per collective type.

All numbers are per device (the module is the SPMD-partitioned
per-device program).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue  # token/opaque
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        total += elems * _DTYPE_BYTES[dtype]
    return total


def shape_elems(type_str: str) -> int:
    elems_total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                elems *= int(d)
        elems_total += elems
    return elems_total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    operands: List[str]
    attrs: str
    is_root: bool = False


def _split_op_line(line: str) -> Optional[Op]:
    line = line.strip()
    if not line.startswith("%") and not line.startswith("ROOT %"):
        return None
    is_root = line.startswith("ROOT ")
    if is_root:
        line = line[len("ROOT "):]
    if " = " not in line:
        return None
    name, rhs = line.split(" = ", 1)
    name = name.strip().lstrip("%")
    rhs = rhs.strip()
    # result type: balanced parens for tuples, else up to first space
    if rhs.startswith("("):
        depth = 0
        for i, c in enumerate(rhs):
            if c == "(":
                depth += 1
            elif c == ")":
                depth -= 1
                if depth == 0:
                    break
        rtype = rhs[: i + 1]
        rest = rhs[i + 1 :].strip()
    else:
        sp = rhs.index(" ")
        rtype = rhs[:sp]
        rest = rhs[sp + 1 :].strip()
    par = rest.find("(")
    if par < 0:
        return None
    opcode = rest[:par].strip()
    depth = 0
    for i in range(par, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                break
    operand_str = rest[par + 1 : i]
    attrs = rest[i + 1 :]
    operands = re.findall(r"%([\w.\-]+)", operand_str)
    return Op(name, rtype, opcode, operands, attrs, is_root)


def parse_module(text: str) -> Dict[str, List[Op]]:
    comps: Dict[str, List[Op]] = {}
    current: Optional[str] = None
    for line in text.splitlines():
        stripped = line.strip()
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", stripped)
        if header and not line.startswith(" "):
            current = header.group(1)
            comps[current] = []
            continue
        if stripped == "}":
            current = None
            continue
        if current is not None:
            op = _split_op_line(line)
            if op is not None:
                comps[current].append(op)
    return comps


def find_entry(text: str, comps: Dict[str, List[Op]]) -> str:
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.M)
    if m:
        return m.group(1)
    return next(iter(comps))


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    # dtype-convert / transpose / copy traffic: CPU-backend lowering
    # artifacts around bf16 dots that a TPU (native-bf16 MXU) would not
    # execute; reported separately and excluded from the memory term.
    layout_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        self.layout_bytes += other.layout_bytes * mult
        for k, v in other.per_collective.items():
            self.per_collective[k] = self.per_collective.get(k, 0.0) + v * mult


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_FREE_OPS = {
    "parameter", "get-tuple-element", "tuple", "constant", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


def _dot_flops(op: Op, shapes: Dict[str, str]) -> float:
    out_elems = shape_elems(op.result_type)
    m = _LHS_C_RE.search(op.attrs)
    contraction = 1
    if m and op.operands:
        lhs_type = shapes.get(op.operands[0], "")
        dims = _shape_dims(lhs_type)
        if m.group(1):
            for d in m.group(1).split(","):
                di = int(d)
                if di < len(dims):
                    contraction *= dims[di]
    return 2.0 * out_elems * contraction


class Analyzer:
    def __init__(self, text: str):
        self.comps = parse_module(text)
        self.entry = find_entry(text, self.comps)
        self._memo: Dict[str, Cost] = {}
        self.warnings: List[str] = []

    def _operand_bytes(self, op: Op, shapes: Dict[str, str]) -> float:
        return float(
            sum(shape_bytes(shapes.get(o, "")) for o in op.operands)
        )

    _LAYOUT_OPS = {"copy", "transpose", "convert", "bitcast", "reshape"}

    def _origin_dtype_bytes_per_elem(
        self, name: str, defs: Dict[str, "Op"], depth: int = 0
    ) -> Optional[float]:
        """Walk back through layout-only ops to the original buffer's
        dtype; None if unknown (loop parameters etc.)."""
        if depth > 8 or name not in defs:
            return None
        op = defs[name]
        if op.opcode in self._LAYOUT_OPS and op.operands:
            return self._origin_dtype_bytes_per_elem(
                op.operands[0], defs, depth + 1
            )
        m = _SHAPE_RE.search(op.result_type)
        if m and m.group(1) in _DTYPE_BYTES:
            return float(_DTYPE_BYTES[m.group(1)])
        return None

    # Ops through which a big buffer flows without forcing full
    # materialization inside a fusion (computed lazily per element).
    _UNARY_LAZY = {
        "convert", "copy", "bitcast", "transpose", "reshape", "negate",
        "multiply", "add", "subtract", "divide", "tanh", "exponential",
        "select",
    }

    def _is_layout_only(self, comp_name: str) -> bool:
        ops = self.comps.get(comp_name, [])
        real = [
            o for o in ops
            if o.opcode not in _FREE_OPS and o.opcode not in self._LAYOUT_OPS
            and o.opcode not in ("broadcast", "slice", "pad")
        ]
        return bool(ops) and not real

    def _fusion_traffic(self, comp_name: str, op_shapes_outer, fusion_op) -> float:
        """HBM traffic of one fusion execution (fusion-semantics-aware).

        Inputs: each fusion parameter is read once in full — unless its
        only (transitive, through lazily-computed elementwise ops) uses
        are dynamic-slice/gather, in which case only the sliced regions
        are read (fusion internals are computed lazily: a convert of a
        whole KV cache feeding a slice materializes just the slice).
        Output: the root's result is written once; a dynamic-update-
        slice root writes (and read-modifies) only its update region.
        """
        ops = self.comps.get(comp_name)
        if ops is None:
            # no called comp: fall back to boundary
            return self._operand_bytes(fusion_op, op_shapes_outer) + shape_bytes(
                fusion_op.result_type
            )
        shapes = {op.name: op.result_type for op in ops}

        # In-place-update pattern: XLA-CPU rewrites multi-dynamic-index
        # dynamic-update-slice on a loop carry into a select-over-iota
        # fusion whose result shape equals the carried buffer's shape.
        # On TPU (with buffer aliasing) this is an in-place write of the
        # small update region: charge only the small operands.
        heavy = {"dot", "convolution", "reduce", "scatter", "gather",
                 "reduce-window", "sort", "rng"}
        if not any(o.opcode in heavy for o in ops):
            root_t = next(
                (o.result_type for o in ops if o.is_root), ops[-1].result_type
            )
            params = [o for o in ops if o.opcode == "parameter"]
            big = [
                p for p in params
                if _shape_dims(p.result_type) == _shape_dims(root_t)
                and shape_bytes(p.result_type) > (1 << 22)
            ]
            others = [p for p in params if p not in big]
            # Only an in-place update if every non-destination input is
            # small (the update region + indices); a loop fusion mixing
            # several large tensors is NOT this pattern.
            if big and all(
                shape_bytes(p.result_type) < (1 << 22) for p in others
            ):
                small = sum(shape_bytes(p.result_type) for p in others)
                return 2.0 * small  # read small inputs + write the region

        users: Dict[str, List[Op]] = {}
        for op in ops:
            for o in op.operands:
                users.setdefault(o, []).append(op)

        def sliced_read_bytes(
            name: str, per_elem: float
        ) -> Optional[float]:
            """Bytes read from buffer `name` if all its transitive uses
            (through lazily-computed elementwise ops) are slice-like;
            None if it is materialized in full (any non-lazy consumer or
            a path to the fusion root).  BFS with dedup — diamond
            dataflow must not multiply the charge."""
            seen = set()
            frontier = [name]
            slice_ops: Dict[str, float] = {}
            while frontier:
                nm = frontier.pop()
                if nm in seen:
                    continue
                seen.add(nm)
                us = users.get(nm, [])
                if not us:
                    return None  # reaches the root: materialized in full
                for u in us:
                    if u.opcode in ("dynamic-slice", "gather"):
                        slice_ops[u.name] = (
                            shape_elems(u.result_type) * per_elem
                        )
                    elif (
                        u.opcode == "dynamic-update-slice"
                        and u.operands[0] == nm
                    ):
                        slice_ops[u.name] = float(
                            shape_bytes(shapes.get(u.operands[1], ""))
                        )
                    elif u.opcode in self._UNARY_LAZY:
                        frontier.append(u.name)
                    else:
                        return None
            return sum(slice_ops.values())

        traffic = 0.0
        for op in ops:
            if op.opcode != "parameter":
                continue
            full = shape_bytes(op.result_type)
            if full < (1 << 20):  # small inputs: charge full, skip analysis
                traffic += full
                continue
            m2 = _SHAPE_RE.search(op.result_type)
            per_elem = float(_DTYPE_BYTES.get(m2.group(1), 4)) if m2 else 4.0
            sliced = sliced_read_bytes(op.name, per_elem)
            traffic += full if sliced is None else min(sliced, full)
        # output side
        root = next((o for o in ops if o.is_root), ops[-1])
        roots = [root]
        if root.opcode == "tuple":
            roots = [
                next((o for o in ops if o.name == n), None)
                for n in root.operands
            ]
        defs = {o.name: o for o in ops}

        def layout_chain_from_slice(name: str, depth: int = 0) -> bool:
            # root value that is a pure layout transform of a slice: a
            # TPU consumer reads the slice directly; the materialized
            # transposed/converted copy is a CPU-lowering artifact
            if depth > 10 or name not in defs:
                return False
            o = defs[name]
            if o.opcode in ("dynamic-slice",):
                return True
            if o.opcode in self._LAYOUT_OPS and o.operands:
                return layout_chain_from_slice(o.operands[0], depth + 1)
            return False

        for r in roots:
            if r is None:
                continue
            if r.opcode == "dynamic-update-slice":
                traffic += shape_bytes(shapes.get(r.operands[1], ""))
            elif layout_chain_from_slice(r.name):
                pass  # artifact write, excluded (slice read already charged)
            else:
                # intermediate materialization: charged once here (the
                # write); consumer fusions charge the read as a param
                traffic += shape_bytes(r.result_type)
        return traffic

    def analyze_comp(self, name: str, in_fusion: bool = False) -> Cost:
        memo_key = f"{name}@{int(in_fusion)}"
        if memo_key in self._memo:
            return self._memo[memo_key]
        cost = Cost()
        ops = self.comps.get(name, [])
        shapes = {op.name: op.result_type for op in ops}
        for op in ops:
            oc = op.opcode
            if oc in _FREE_OPS:
                continue
            if oc == "while":
                trip = 1
                m = _TRIP_RE.search(op.attrs)
                if m:
                    trip = int(m.group(1))
                else:
                    self.warnings.append(f"while without trip count in {name}")
                body = _BODY_RE.search(op.attrs)
                cond = _COND_RE.search(op.attrs)
                if body:
                    cost.add(self.analyze_comp(body.group(1)), trip)
                if cond:
                    cost.add(self.analyze_comp(cond.group(1)), trip + 1)
                continue
            if oc in ("fusion", "call", "async-start"):
                m = _CALLS_RE.search(op.attrs) or re.search(
                    r"to_apply=%?([\w.\-]+)", op.attrs
                )
                if m:
                    sub = self.analyze_comp(m.group(1), in_fusion=True)
                    # flops recurse; traffic via fusion-semantics model
                    cost.flops += sub.flops
                    cost.collective_bytes += sub.collective_bytes
                    for k, v in sub.per_collective.items():
                        cost.per_collective[k] = (
                            cost.per_collective.get(k, 0.0) + v
                        )
                    traffic = self._fusion_traffic(m.group(1), shapes, op)
                    if self._is_layout_only(m.group(1)):
                        cost.layout_bytes += traffic
                    else:
                        cost.bytes += traffic
                else:
                    cost.bytes += self._operand_bytes(op, shapes) + shape_bytes(
                        op.result_type
                    )
                continue
            if oc == "conditional":
                branches = re.findall(r"branch_computations=\{([^}]*)\}", op.attrs)
                names = (
                    re.findall(r"%?([\w.\-]+)", branches[0]) if branches else []
                )
                if not names:
                    tc = re.search(r"true_computation=%?([\w.\-]+)", op.attrs)
                    fc = re.search(r"false_computation=%?([\w.\-]+)", op.attrs)
                    names = [m.group(1) for m in (tc, fc) if m]
                sub_costs = [self.analyze_comp(n) for n in names]
                if sub_costs:
                    worst = max(sub_costs, key=lambda c: c.flops)
                    cost.add(worst)
                continue
            if any(oc.startswith(c) for c in COLLECTIVES):
                b = self._operand_bytes(op, shapes)
                cost.collective_bytes += b
                key = next(c for c in COLLECTIVES if oc.startswith(c))
                cost.per_collective[key] = cost.per_collective.get(key, 0.0) + b
                cost.bytes += b + shape_bytes(op.result_type)
                continue
            if oc in ("dot", "dot-general"):
                cost.flops += _dot_flops(op, shapes)
                defs = {o.name: o for o in ops}
                ob = 0.0
                for o in op.operands:
                    d = defs.get(o)
                    if d is not None and d.opcode in ("fusion", "call"):
                        # the buffer behind this operand was already
                        # charged when the producing fusion wrote it
                        continue
                    t = shapes.get(o, "")
                    per = self._origin_dtype_bytes_per_elem(o, defs)
                    if per is None:
                        ob += shape_bytes(t)
                    else:
                        ob += shape_elems(t) * per
                cost.bytes += ob + shape_bytes(op.result_type)
                continue
            if oc == "convolution":
                # rare in this codebase; approximate via result elems x
                # kernel elems / output-features
                cost.flops += 2.0 * shape_elems(op.result_type) * max(
                    shape_elems(shapes.get(op.operands[1], "")) // max(
                        _shape_dims(op.result_type)[-1], 1
                    ),
                    1,
                )
                cost.bytes += self._operand_bytes(op, shapes) + shape_bytes(
                    op.result_type
                )
                continue
            if oc == "custom-call":
                m = _CALLS_RE.search(op.attrs)
                if m:
                    cost.add(self.analyze_comp(m.group(1)))
                cost.bytes += self._operand_bytes(op, shapes) + shape_bytes(
                    op.result_type
                )
                continue
            if oc in self._LAYOUT_OPS:
                cost.layout_bytes += self._operand_bytes(op, shapes) + \
                    shape_bytes(op.result_type)
                continue
            if oc == "dynamic-update-slice":
                upd = shape_bytes(shapes.get(op.operands[1], "")) if len(
                    op.operands
                ) > 1 else 0
                cost.bytes += 2.0 * upd  # in-place: update read + write
                continue
            if oc == "dynamic-slice":
                cost.bytes += 2.0 * shape_bytes(op.result_type)
                continue
            if oc in ("gather", "scatter"):
                # random-access rows: traffic = touched region, not the
                # whole table (embedding lookups, MoE dispatch)
                touched = shape_bytes(op.result_type)
                if oc == "scatter" and len(op.operands) > 2:
                    touched = shape_bytes(shapes.get(op.operands[2], ""))
                cost.bytes += 2.0 * touched
                continue
            # generic elementwise / data movement: 1 flop per output elem
            # (skipped inside fusions: internals are computed lazily and
            # a whole-buffer convert feeding a slice costs ~nothing),
            # traffic at op boundary (outside fusions only)
            if not in_fusion:
                cost.flops += shape_elems(op.result_type)
                cost.bytes += self._operand_bytes(op, shapes) + shape_bytes(
                    op.result_type
                )
        self._memo[memo_key] = cost
        return cost

    def analyze(self) -> Cost:
        return self.analyze_comp(self.entry)


def analyze_hlo(text: str) -> dict:
    a = Analyzer(text)
    c = a.analyze()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "layout_bytes": c.layout_bytes,
        "collective_bytes": c.collective_bytes,
        "per_collective": dict(c.per_collective),
        "warnings": a.warnings[:20],
    }


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e)
# ---------------------------------------------------------------------------

HW_V5E = {
    "peak_flops_bf16": 197e12,  # per chip
    "hbm_bw": 819e9,            # B/s per chip
    "ici_bw": 50e9,             # B/s per link
    "hbm_bytes": 16e9,          # capacity per chip
}


def roofline_terms(per_device: dict, hw: dict = HW_V5E) -> dict:
    compute_s = per_device["flops"] / hw["peak_flops_bf16"]
    memory_s = per_device["bytes"] / hw["hbm_bw"]
    collective_s = per_device["collective_bytes"] / hw["ici_bw"]
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
    }
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    total = sum(terms.values())
    return {
        **terms,
        "dominant": dom,
        "bound_s": bound,
        # roofline fraction: how much of the step the dominant term is —
        # 1.0 means perfectly limited by one resource (no wasted overlap
        # potential); we also report the useful-compute fraction
        # separately (vs MODEL_FLOPS) in the tables.
        "overlap_fraction": bound / total if total else 0.0,
    }
