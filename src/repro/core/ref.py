"""Paper-faithful sequential oracle of the non-blocking buddy system.

This is a line-by-line transcription of Algorithms 1-4 of the paper
(NBALLOC / TRYALLOC / NBFREE / FREENODE / UNMARK) into pure Python, with
the CAS primitive factored out so that word-update ("RMW") counts can be
instrumented exactly as the paper reasons about them (§III-D: the number
of RMW instructions on the critical path is the optimization target).

It serves three roles:

  1. The *correctness oracle* for every other implementation in this
     repository (jitted JAX single-op, wavefront batch, packed bunches,
     Pallas kernel) — property tests replay identical request traces and
     require identical allocation outcomes.
  2. The *host-side allocator* of the serving engine: the continuous
     batching scheduler runs on the host and allocates KV-cache pages
     from this allocator (numpy-backed tree, O(levels) per op).
  3. The faithful single-thread baseline of the paper's benchmarks.

Two pseudo-code typos in the paper are corrected here (both are obvious
from the surrounding prose and from the published C implementation at
github.com/HPDCS/NBBS):

  * Alg. 1 lines A9-A10 scan ``[2^(level-1), 2^level - 1]`` which is the
    range of ``level-1``; §III-A's text gives the correct range
    ``n ∈ [2^level, 2^(level+1) - 1]`` — we use the latter.
  * Alg. 3 line F5 computes the branch selector from ``current`` (the
    parent); the bit being set is the coalescing bit *of the branch that
    contains `runner`* (the child), so the selector must be
    ``mod2(runner)``.  Line F16 ``runner <- actual`` reads
    ``runner <- current``.  Line F20 compares an index against a level;
    the guard is ``level(n) != upper_bound``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core import bits
from repro.core.bits import (
    BUSY,
    COAL_LEFT,
    OCC,
    clean_coal,
    is_coal,
    is_coal_buddy,
    is_free,
    is_occ_buddy,
    level_of,
    mark,
    mod2,
    unmark,
)


def _ilog2(x: int) -> int:
    """floor(log2(x)) for positive ints."""
    return x.bit_length() - 1


@dataclasses.dataclass
class NBBSStats:
    """Instrumentation mirroring the paper's cost model."""

    cas_attempts: int = 0       # every RMW issued (incl. failed retries)
    cas_failures: int = 0       # RMWs that observed a changed word
    plain_writes: int = 0       # non-RMW writes (F19: tree[n] <- 0)
    allocs_ok: int = 0
    allocs_failed: int = 0      # NBALLOC returned NULL
    frees: int = 0
    level_scan_steps: int = 0   # nodes inspected during level scans

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)


class NBBSRef:
    """Sequential reference implementation of the non-blocking buddy system.

    Parameters mirror the paper's notation: the allocator manages
    ``total_memory`` bytes starting at ``base_address``; leaves are
    allocation units of ``min_size`` bytes; no single request may exceed
    ``max_size`` (the level of which is ``max_level``).
    """

    def __init__(
        self,
        total_memory: int,
        min_size: int,
        max_size: Optional[int] = None,
        base_address: int = 0,
    ) -> None:
        if max_size is None:
            max_size = total_memory
        if total_memory & (total_memory - 1):
            raise ValueError("total_memory must be a power of two")
        if min_size & (min_size - 1) or min_size > total_memory:
            raise ValueError("min_size must be a power of two <= total_memory")
        if max_size & (max_size - 1) or max_size > total_memory:
            raise ValueError("max_size must be a power of two <= total_memory")
        self.total_memory = total_memory
        self.min_size = min_size
        self.max_size = max_size
        self.base_address = base_address
        self.depth = _ilog2(total_memory // min_size)
        self.max_level = _ilog2(total_memory // max_size)
        # tree[0] unused; root at index 1 (paper Fig. 2).
        self.tree: List[int] = [0] * (1 << (self.depth + 1))
        # index[] maps allocation-unit offset -> node index of the serving
        # allocation (paper §III-A).
        self.index: List[int] = [0] * (total_memory // min_size)
        self.stats = NBBSStats()
        # Scattered scan hint (paper: "not necessarily such a search has to
        # start from the first node at that level").
        self._scan_hint: dict[int, int] = {}

    # ------------------------------------------------------------------
    # CAS primitive.  Sequential => always succeeds when expected matches.
    # Factored out so subclasses / harnesses can instrument or perturb it.
    # ------------------------------------------------------------------
    def _cas(self, idx: int, expected: int, new: int) -> bool:
        self.stats.cas_attempts += 1
        if self.tree[idx] != expected:
            self.stats.cas_failures += 1
            return False
        self.tree[idx] = new
        return True

    # -- helpers --------------------------------------------------------
    def level_for_size(self, size: int) -> int:
        """Paper rule 1 / line A5: floor(log2(total/size)), clamped to depth.

        floor on the *ratio* rounds non-power-of-two sizes up to the next
        block size (e.g. size=3 in a 1024-byte tree lands at the 4-byte
        level, not the 2-byte level).
        """
        level = _ilog2(self.total_memory // size) if size else self.depth
        return min(level, self.depth)

    def size_of_level(self, level: int) -> int:
        return self.total_memory >> level

    def starting_address(self, n: int) -> int:
        """Paper eq. (3)."""
        level = level_of(n)
        size = self.size_of_level(level)
        return self.base_address + (n - (1 << level)) * size

    def node_for_address(self, addr: int) -> int:
        return self.index[(addr - self.base_address) // self.min_size]

    # ------------------------------------------------------------------
    # Algorithm 1 — NBALLOC
    # ------------------------------------------------------------------
    def nb_alloc(self, size: int, scattered: bool = False) -> Optional[int]:
        if size > self.max_size or size < 0:
            self.stats.allocs_failed += 1
            return None
        if size == 0:
            size = 1
        level = self.level_for_size(size)
        base = 1 << level
        n_nodes = 1 << level
        start = self._scan_hint.get(level, 0) if scattered else 0
        # Scan the level (wrapping once when scattered) looking for a free
        # node; skip whole sub-trees on TRYALLOC failure (lines A18-A19).
        scanned = 0
        i = base + start
        end = base + n_nodes
        wrapped = not scattered
        while True:
            if i >= end:
                if wrapped:
                    break
                wrapped = True
                i = base
                end = base + start
                if i >= end:
                    break
            self.stats.level_scan_steps += 1
            scanned += 1
            if is_free(self.tree[i]):
                failed_at = self._try_alloc(i)
                if not failed_at:
                    addr = self.starting_address(i)
                    self.index[(addr - self.base_address) // self.min_size] = i
                    self.stats.allocs_ok += 1
                    if scattered:
                        self._scan_hint[level] = (i + 1 - base) % n_nodes
                    return addr
                # Skip the whole sub-tree of the ancestor that failed us.
                d = 1 << (level - level_of(failed_at))
                i = (failed_at + 1) * d
                continue
            i += 1
        self.stats.allocs_failed += 1
        return None

    # ------------------------------------------------------------------
    # Algorithm 2 — TRYALLOC
    # ------------------------------------------------------------------
    def _try_alloc(self, n: int) -> int:
        """Returns 0 on success, else the node index that failed us."""
        if not self._cas(n, 0, BUSY):
            return n
        current = n
        while level_of(current) > self.max_level:
            child = current
            current >>= 1
            while True:
                curr_val = self.tree[current]
                if curr_val & OCC:
                    # An ancestor is fully reserved: roll back our marks.
                    self._free_node(n, level_of(child))
                    return current
                new_val = clean_coal(curr_val, child)
                new_val = mark(new_val, child)
                if self._cas(current, curr_val, new_val):
                    break
        return 0

    # ------------------------------------------------------------------
    # Algorithm 3 — NBFREE / FREENODE
    # ------------------------------------------------------------------
    def nb_free(self, addr: int) -> None:
        n = self.index[(addr - self.base_address) // self.min_size]
        self._free_node(n, self.max_level)
        self.stats.frees += 1

    def nb_free_many(self, addrs) -> None:
        """Release a burst of allocations in one call (the release-side
        batch API; this host oracle linearizes, device allocators process
        the whole burst in one merged `free_round` pass)."""
        for addr in addrs:
            self.nb_free(addr)

    def _free_node(self, n: int, upper_bound: int) -> None:
        # -- phase 1: mark the path as coalescing, bottom-up ------------
        current = n >> 1
        runner = n
        while level_of(runner) > upper_bound:
            or_val = COAL_LEFT >> mod2(runner)
            while True:
                cur_val = self.tree[current]
                new_val = cur_val | or_val
                if self._cas(current, cur_val, new_val):
                    old_val = cur_val
                    break
            if is_occ_buddy(old_val, runner) and not is_coal_buddy(old_val, runner):
                # The buddy sub-tree holds live allocations: the climb can
                # stop, chunks above cannot coalesce (paper Fig. 4).
                break
            runner = current
            current >>= 1
        # -- phase 2: release the node itself (plain write, line F19) ---
        self.tree[n] = 0
        self.stats.plain_writes += 1
        # -- phase 3: propagate the release towards the upper bound -----
        if level_of(n) != upper_bound:
            self._unmark(n, upper_bound)

    # ------------------------------------------------------------------
    # Algorithm 4 — UNMARK
    # ------------------------------------------------------------------
    def _unmark(self, n: int, upper_bound: int) -> None:
        current = n
        while True:
            child = current
            current >>= 1
            while True:
                curr_val = self.tree[current]
                if not is_coal(curr_val, child):
                    # A concurrent operation re-used / re-released the
                    # branch: our responsibility ends here.
                    return
                new_val = unmark(curr_val, child)
                if self._cas(current, curr_val, new_val):
                    break
            if not (
                level_of(current) > upper_bound
                and not is_occ_buddy(new_val, child)
            ):
                return

    # ------------------------------------------------------------------
    # Introspection helpers (used by tests / the serving engine)
    # ------------------------------------------------------------------
    def allocated_ranges(self) -> List[range]:
        """All currently reserved [start, end) address ranges (OCC nodes)."""
        out = []
        for n in range(1, len(self.tree)):
            if self.tree[n] & OCC:
                start = self.starting_address(n)
                out.append(range(start, start + self.size_of_level(level_of(n))))
        return out

    def free_bytes(self) -> int:
        occupied = sum(
            self.size_of_level(level_of(n))
            for n in range(1, len(self.tree))
            if self.tree[n] & OCC
        )
        return self.total_memory - occupied

    def check_invariants(self) -> None:
        """Structural sanity: status bits consistent with sub-tree state.

        In quiescent state (no in-flight ops) the paper's derivation rules
        (Fig. 6) must hold: a node's left/right occupancy bit is set iff
        its corresponding child sub-tree contains a reserved node, and no
        coalescing bits remain.
        """
        for n in range(1, 1 << self.depth):
            val = self.tree[n]
            left, right = 2 * n, 2 * n + 1
            left_busy = (self.tree[left] & BUSY) != 0
            right_busy = (self.tree[right] & BUSY) != 0
            if val & OCC:
                continue  # fully reserved: children state is not reflected
            has_left = (val & bits.OCC_LEFT) != 0
            has_right = (val & bits.OCC_RIGHT) != 0
            if has_left != left_busy or has_right != right_busy:
                raise AssertionError(
                    f"node {n}: bits {val:#x} inconsistent with children "
                    f"{self.tree[left]:#x}/{self.tree[right]:#x}"
                )
            if val & (bits.COAL_LEFT | bits.COAL_RIGHT):
                raise AssertionError(f"node {n}: stale coalescing bits {val:#x}")
