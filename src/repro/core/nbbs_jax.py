"""Single-operation jitted allocator — in-graph NBBS for serving steps.

A wavefront of width 1 is *exactly* the sequential specification: the
rank-0 assignment picks the first level node whose word is zero and whose
ancestors carry no OCC bit — the same node the paper's NBALLOC level scan
(with sub-tree skipping) lands on.  We therefore express the single-op
API as K=1 wavefronts rather than duplicating the algorithm.

`AllocState` carries the paper's two arrays (tree[] and index[]) as JAX
arrays so allocation/release can live inside a jitted serving step
(e.g. allocating KV-cache pages for newly admitted sequences without
host round-trips).  `PoolAllocState` is the sharded analogue: S
replicated (tree[], index[]) pairs stacked on a leading axis, routed by
`core/pool.py`'s home-shard hash with overflow probing.

The tree[] words follow the `TreeConfig.layout` (docs/design.md §3):
`Unpacked` int32-per-node by default, or the §III-D `BunchPacked`
uint32 bunch words (`TreeConfig(..., layout=BUNCH_PACKED)`).  Handles
are node indices / (shard, unit_offset) pairs in both cases — the
layout never leaks through this API, it only changes the persistent
word format (and shrinks it ~7x when packed).

Invariants (deep-linked from docs/architecture.md):

  * node numbering: root is index 1, children of n are 2n/2n+1, level
    of n is floor(log2 n); a level-l node's chunk starts at unit offset
    (n - 2^l) * 2^(depth-l) (`_node_to_unit_offset`, paper eq. 3);
  * occupancy encoding: tree[] words carry the 5-bit status mask of
    `core/bits.py` (OCC = this node reserved, OCC_LEFT/RIGHT = branch
    occupancy, COAL_* = release in flight) — per node under `Unpacked`,
    on bunch leaves with derived interiors under `BunchPacked`; a chunk
    is allocatable iff its (derived) state is bit-free and no strict
    ancestor carries (derived) OCC;
  * index[] maps a unit offset to the node that served it and keeps
    stale entries after release, exactly like the paper's NBFREE:
    double-free arbitration happens in `free_round`'s validity mask —
    a released word without OCC identifies the free as stale and it is
    dropped instead of corrupting ancestor marks;
  * pool handles are (shard, unit_offset) pairs; each shard's index[]
    is private, so a stale handle can never free another shard's node;
  * *leaf-only pools* (the jit-resident serving engine, docs/design.md
    §8) need no index[] at all: every allocation is a single unit, so
    the serving node of offset o is always the leaf 2^depth + o.  The
    `nb_pool_alloc_pages` / `nb_pool_free_pages` pair below works on the
    bare `trees` array — that is what lets the engine pytree carry just
    the `[S, n_state_words]` tree state across steps, with handles
    living in its page tables.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.concurrent import (
    TreeConfig,
    free_round,
    levels_from_sizes,
    wavefront_alloc,
)
from repro.core.magazine import MagazineState
from repro.core.pool import (
    PoolConfig,
    pool_free_round,
    pool_free_round_mag,
    pool_wavefront_alloc,
    pool_wavefront_alloc_mag,
)

Array = jax.Array


class AllocState(NamedTuple):
    tree: Array   # cfg.layout state words (int32[2^(depth+1)] unpacked)
    index: Array  # int32[units] node that served each unit offset


def init_state(cfg: TreeConfig) -> AllocState:
    return AllocState(
        tree=cfg.empty_tree(),
        index=jnp.zeros(1 << cfg.depth, dtype=jnp.int32),
    )


def _node_to_unit_offset(cfg: TreeConfig, node: Array) -> Array:
    """Unit offset of a node's chunk: (n - 2^level) * 2^(depth-level)."""
    level = 31 - jax.lax.clz(jnp.maximum(node, 1))
    return (node - (1 << level)) << (cfg.depth - level)


def nb_alloc(
    cfg: TreeConfig, state: AllocState, level: Array
) -> Tuple[AllocState, Array, Array]:
    """Allocate one chunk at `level`. Returns (state, unit_offset, ok)."""
    levels = jnp.reshape(level, (1,)).astype(jnp.int32)
    tree, nodes, ok, _ = wavefront_alloc(
        cfg, state.tree, levels, jnp.ones((1,), bool)
    )
    node = nodes[0]
    off = _node_to_unit_offset(cfg, node)
    index = jnp.where(
        ok[0], state.index.at[off].set(node), state.index
    )
    return AllocState(tree, index), off, ok[0]


def nb_free(cfg: TreeConfig, state: AllocState, unit_offset: Array) -> AllocState:
    """Release the chunk previously allocated at `unit_offset`."""
    state, _ = nb_free_batch(
        cfg, state, jnp.reshape(unit_offset, (1,)), jnp.ones((1,), bool)
    )
    return state


def nb_free_batch(
    cfg: TreeConfig,
    state: AllocState,
    unit_offsets: Array,
    active: Array,
) -> Tuple[AllocState, Array]:
    """Release a burst of chunks in one merged O(depth) pass — the
    in-graph serving release path (a decode step retires many sequences'
    pages at once; the whole burst costs one `free_round`, not a
    per-chunk scan).  Returns (state, freed bool[K]); double frees and
    junk offsets are dropped by the round's validity mask."""
    unit_offsets = unit_offsets.astype(jnp.int32)
    # out-of-range offsets are invalid handles, not aliases of unit 0
    in_range = (unit_offsets >= 0) & (unit_offsets < (1 << cfg.depth))
    offs = jnp.where(in_range, unit_offsets, 0)
    nodes = state.index[offs]
    tree, _, _, freed = free_round(cfg, state.tree, nodes, active & in_range)
    # index[] keeps its stale entries, exactly like the paper's NBFREE —
    # a re-free through a stale entry lands on a word without OCC and is
    # dropped by free_round's validity mask.
    return AllocState(tree, state.index), freed


def nb_alloc_size(
    cfg: TreeConfig, state: AllocState, total_memory: int, size: Array
) -> Tuple[AllocState, Array, Array]:
    """Size-based convenience (paper NBALLOC API, rule A5 in-graph)."""
    level = levels_from_sizes(cfg, total_memory, jnp.reshape(size, (1,)))[0]
    return nb_alloc(cfg, state, level)


# ---------------------------------------------------------------------------
# Sharded pool API (S replicated trees; routing in core/pool.py)
# ---------------------------------------------------------------------------


class PoolAllocState(NamedTuple):
    trees: Array  # [S, n_state_words] stacked layout state words
    index: Array  # int32[S, units] per-shard unit offset -> serving node


def init_pool_state(pcfg: PoolConfig) -> PoolAllocState:
    return PoolAllocState(
        trees=pcfg.empty_trees(),
        index=jnp.zeros(
            (pcfg.n_shards, 1 << pcfg.tree.depth), dtype=jnp.int32
        ),
    )


def nb_pool_alloc(
    pcfg: PoolConfig,
    state: PoolAllocState,
    level: Array,
    lane_id: Array | int = 0,
) -> Tuple[PoolAllocState, Array, Array, Array]:
    """Allocate one chunk at `level` from the pool (in-graph, jit-able).

    `lane_id` is the requester identity fed to the home-shard hash (use
    e.g. the sequence id so a requester's allocations cluster on its
    home shard).  Returns (state, shard, unit_offset, ok) — the pool
    handle is the (shard, unit_offset) pair."""
    levels = jnp.reshape(level, (1,)).astype(jnp.int32)
    lane_ids = jnp.reshape(jnp.asarray(lane_id), (1,)).astype(jnp.int32)
    trees, nodes, shard, ok, _ = pool_wavefront_alloc(
        pcfg, state.trees, levels, jnp.ones((1,), bool), 64, lane_ids
    )
    node, s = nodes[0], shard[0]
    off = _node_to_unit_offset(pcfg.tree, node)
    index = jnp.where(
        ok[0], state.index.at[s, off].set(node), state.index
    )
    return PoolAllocState(trees, index), s, off, ok[0]


def nb_pool_free_batch(
    pcfg: PoolConfig,
    state: PoolAllocState,
    shards: Array,
    unit_offsets: Array,
    active: Array,
) -> Tuple[PoolAllocState, Array]:
    """Release a burst of pool handles in one vmapped merged pass (one
    `free_round` per shard).  Returns (state, freed bool[K]); stale or
    junk handles are dropped by each shard's validity mask."""
    shards = shards.astype(jnp.int32)
    unit_offsets = unit_offsets.astype(jnp.int32)
    in_range = (
        (unit_offsets >= 0)
        & (unit_offsets < (1 << pcfg.tree.depth))
        & (shards >= 0)
        & (shards < pcfg.n_shards)
    )
    offs = jnp.where(in_range, unit_offsets, 0)
    sh = jnp.where(in_range, shards, 0)
    nodes = state.index[sh, offs]
    trees, _, _, freed = pool_free_round(
        pcfg, state.trees, nodes, sh, active & in_range
    )
    # per-shard index[] keeps stale entries (see module invariants)
    return PoolAllocState(trees, state.index), freed


# ---------------------------------------------------------------------------
# Leaf-only pool API (index[]-free; the jit-resident serving engine)
# ---------------------------------------------------------------------------


def nb_pool_alloc_pages(
    pcfg: PoolConfig,
    trees: Array,
    active: Array,
    lane_ids: Array,
    max_rounds: int = 64,
) -> Tuple[Array, Array, Array, Array, dict]:
    """Allocate one *leaf unit* (one KV page) per active lane, in-graph.

    The burst-allocation primitive of the jitted engine step: every
    request targets the leaf level, routed by the Fibonacci home-shard
    hash of `lane_ids` (the sequence ids, so a sequence's pages cluster
    on its home shard) with the pool's cyclic overflow probing.

    Returns (trees, shard int32[K], unit_offset int32[K], ok bool[K],
    stats).  The (shard, offset) pair is the page handle; no index[] is
    needed because a leaf's node is always 2^depth + offset.

    With `pcfg.fastpath` set, each lane's probe first tries the O(1)
    slab claim on its current shard and only spills into the buddy
    climb when the slab is exhausted (core/fastpath.py); handles are
    path-agnostic — a slab page's node is the same leaf node — and
    stats carry 'fastpath_hits'/'fastpath_spills'."""
    K = active.shape[0]
    levels = jnp.full((K,), pcfg.tree.depth, dtype=jnp.int32)
    trees, nodes, shard, ok, stats = pool_wavefront_alloc(
        pcfg, trees, levels, active, max_rounds,
        lane_ids.astype(jnp.int32),
    )
    off = jnp.where(ok, nodes - (1 << pcfg.tree.depth), -1)
    return trees, shard, off, ok, stats


def nb_pool_free_pages(
    pcfg: PoolConfig,
    trees: Array,
    shards: Array,
    unit_offsets: Array,
    active: Array,
) -> Tuple[Array, Array, Array]:
    """Release a burst of leaf-unit page handles in one vmapped merged
    pass (one `free_round` per shard) — the in-graph retirement path of
    the jitted engine.

    Junk handles are dropped, never aliased: offsets or shards outside
    the pool geometry are masked here, and a stale in-range handle
    whose leaf lacks OCC is dropped by `free_round`'s validity mask —
    identical semantics to `nb_pool_free_batch`, minus the index[]
    lookup that leaf-only pools don't need.

    With `pcfg.fastpath` set, frees route by address range inside
    `pool_free_round`: offsets under the slab release through its
    bitmap, the rest through the merged buddy pass — callers never
    track which path served a page.

    Returns (trees, freed bool[K], stats)."""
    shards = shards.astype(jnp.int32)
    unit_offsets = unit_offsets.astype(jnp.int32)
    in_range = (
        (unit_offsets >= 0)
        & (unit_offsets < (1 << pcfg.tree.depth))
        & (shards >= 0)
        & (shards < pcfg.n_shards)
    )
    nodes = jnp.where(in_range, (1 << pcfg.tree.depth) + unit_offsets, 0)
    sh = jnp.where(in_range, shards, 0)
    trees, merged, logical, freed = pool_free_round(
        pcfg, trees, nodes, sh, active & in_range
    )
    stats = {"free_merged_writes": merged, "free_logical_rmws": logical}
    return trees, freed, stats


# ---------------------------------------------------------------------------
# Magazine-fused leaf-only pool API (core/magazine.py, docs/design.md §10)
# ---------------------------------------------------------------------------


def nb_pool_alloc_pages_mag(
    pcfg: PoolConfig,
    trees: Array,
    mags: MagazineState,
    active: Array,
    lane_ids: Array,
    max_rounds: int = 64,
    mag_lane: Array | None = None,
    mag_rank: Array | None = None,
) -> Tuple[Array, MagazineState, Array, Array, Array, dict]:
    """`nb_pool_alloc_pages` with the per-lane magazines fused in: each
    active lane first pops its own magazine (`mag_lane`, -1 = no
    magazine; zero shared-state RMWs) and only the misses drop through
    into the same wavefront's slab/tree rounds.  Exhaustion triggers one
    merged spill-back plus a retry, so failure semantics match the
    magazines-off pool (core/pool.py `pool_wavefront_alloc_mag`).
    `mag_rank` optionally skips the claim's group-rank sort — pass all
    zeros when every lane has its own magazine (`mag_claim`).

    Returns (trees, mags, shard, unit_offset, ok, stats); stats adds
    'magazine_hits'/'magazine_spills'/'magazine_refills'."""
    K = active.shape[0]
    levels = jnp.full((K,), pcfg.tree.depth, dtype=jnp.int32)
    trees, mags, nodes, shard, ok, stats = pool_wavefront_alloc_mag(
        pcfg, trees, mags, levels, active, max_rounds,
        lane_ids.astype(jnp.int32),
        None if mag_lane is None else mag_lane.astype(jnp.int32),
        mag_rank,
    )
    off = jnp.where(ok, nodes - (1 << pcfg.tree.depth), -1)
    return trees, mags, shard, off, ok, stats


def nb_pool_free_pages_mag(
    pcfg: PoolConfig,
    trees: Array,
    mags: MagazineState,
    shards: Array,
    unit_offsets: Array,
    active: Array,
    mag_lane: Array | None = None,
    mag_rank: Array | None = None,
    assume_owned: bool = False,
) -> Tuple[Array, MagazineState, Array, dict]:
    """`nb_pool_free_pages` with the magazine stash fused in: each
    valid leaf handle whose lane has a magazine is recycled lane-
    locally (pages the pool still marks allocated stay marked — the
    magazine owns them until a claim or spill), and drop-throughs take
    the same burst's merged slab/tree release.  `mag_rank` and the
    static `assume_owned` are the stash fast paths for callers whose
    handles are known distinct/owned (core/pool.py `_mag_stash_phase`).

    Returns (trees, mags, freed bool[K], stats) with the free-side
    'magazine_spills' (stash drop-throughs on full magazines)."""
    shards = shards.astype(jnp.int32)
    unit_offsets = unit_offsets.astype(jnp.int32)
    in_range = (
        (unit_offsets >= 0)
        & (unit_offsets < (1 << pcfg.tree.depth))
        & (shards >= 0)
        & (shards < pcfg.n_shards)
    )
    nodes = jnp.where(in_range, (1 << pcfg.tree.depth) + unit_offsets, 0)
    sh = jnp.where(in_range, shards, 0)
    if mag_lane is None:
        mag_lane = jnp.full(nodes.shape[0], -1, jnp.int32)
    trees, mags, merged, logical, freed, _, spills = pool_free_round_mag(
        pcfg, trees, mags, nodes, sh, active & in_range,
        mag_lane.astype(jnp.int32),
        mag_rank=mag_rank, assume_owned=assume_owned,
    )
    stats = {
        "free_merged_writes": merged,
        "free_logical_rmws": logical,
        "magazine_spills": spills,
    }
    return trees, mags, freed, stats
