"""Single-operation jitted allocator — in-graph NBBS for serving steps.

A wavefront of width 1 is *exactly* the sequential specification: the
rank-0 assignment picks the first level node whose word is zero and whose
ancestors carry no OCC bit — the same node the paper's NBALLOC level scan
(with sub-tree skipping) lands on.  We therefore express the single-op
API as K=1 wavefronts rather than duplicating the algorithm.

`AllocState` carries the paper's two arrays (tree[] and index[]) as JAX
arrays so allocation/release can live inside a jitted serving step
(e.g. allocating KV-cache pages for newly admitted sequences without
host round-trips).
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.concurrent import (
    TreeConfig,
    free_batch,
    levels_from_sizes,
    wavefront_alloc,
)

Array = jax.Array


class AllocState(NamedTuple):
    tree: Array   # int32[2^(depth+1)] status-bit tree
    index: Array  # int32[units] node that served each unit offset


def init_state(cfg: TreeConfig) -> AllocState:
    return AllocState(
        tree=cfg.empty_tree(),
        index=jnp.zeros(1 << cfg.depth, dtype=jnp.int32),
    )


def _node_to_unit_offset(cfg: TreeConfig, node: Array) -> Array:
    """Unit offset of a node's chunk: (n - 2^level) * 2^(depth-level)."""
    level = 31 - jax.lax.clz(jnp.maximum(node, 1))
    return (node - (1 << level)) << (cfg.depth - level)


def nb_alloc(
    cfg: TreeConfig, state: AllocState, level: Array
) -> Tuple[AllocState, Array, Array]:
    """Allocate one chunk at `level`. Returns (state, unit_offset, ok)."""
    levels = jnp.reshape(level, (1,)).astype(jnp.int32)
    tree, nodes, ok, _ = wavefront_alloc(
        cfg, state.tree, levels, jnp.ones((1,), bool)
    )
    node = nodes[0]
    off = _node_to_unit_offset(cfg, node)
    index = jnp.where(
        ok[0], state.index.at[off].set(node), state.index
    )
    return AllocState(tree, index), off, ok[0]


def nb_free(cfg: TreeConfig, state: AllocState, unit_offset: Array) -> AllocState:
    """Release the chunk previously allocated at `unit_offset`."""
    node = state.index[unit_offset]
    tree, _ = free_batch(
        cfg,
        state.tree,
        jnp.reshape(node, (1,)),
        jnp.ones((1,), bool),
    )
    return AllocState(tree, state.index)


def nb_alloc_size(
    cfg: TreeConfig, state: AllocState, total_memory: int, size: Array
) -> Tuple[AllocState, Array, Array]:
    """Size-based convenience (paper NBALLOC API, rule A5 in-graph)."""
    level = levels_from_sizes(cfg, total_memory, jnp.reshape(size, (1,)))[0]
    return nb_alloc(cfg, state, level)
