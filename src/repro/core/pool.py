"""Sharded allocator pool: S replicated wavefront trees behind one API.

The literature scales allocators past a single core structure by
*replicating* the core allocator and routing requests across the
replicas (scalloc's backend spans, SpeedMalloc's per-thread pools); the
paper positions the non-blocking buddy system as exactly such a core
allocator.  This module is that replication layer for the wavefront
substrate: a pool of S independent status-bit trees, stacked as the
leading axis of one `[S, n_state_words]` array (of the tree layout's
state dtype — `int32[S, n_words]` for the default `Unpacked`, packed
`uint32[S, n_words/7ish]` for `BunchPacked`; see `core/layout.py`) so
every per-tree pass of `core/concurrent.py` lifts to the pool with a
single `jax.vmap`.  Routing and handles live in node-index space, which
is layout-independent, so the pool layer is oblivious to the packing.

Routing (all in-graph, shape-static):

  * every requester lane has a deterministic *home shard* — a Fibonacci
    multiplicative hash of its lane id (`home_shard`), so an unchanged
    workload always maps to the same shard and the pool state is
    reproducible run-to-run;
  * each arbitration round, every pending lane participates in exactly
    one shard's `alloc_round`; the S per-shard rounds run batched under
    `vmap` (level slices are static, so XLA sees the same fused vector
    ops as the single tree, with an extra leading axis);
  * *overflow*: a lane whose round exhausts its current shard (no free
    node at its level — the definitive per-tree failure, not a
    transient arbitration loss) is re-routed to the next shard in the
    fixed probe order home, home+1, …, home+S-1 (mod S) for the
    following round.  A lane fails definitively only after exhausting
    all S shards, so a burst that would fail on one tree succeeds
    across the pool within at most S-1 extra probe rounds per lane;
  * releases carry their serving shard (recorded at allocation time):
    `pool_free_round` applies one merged `free_round` per shard — a
    whole multi-shard burst costs one vmapped O(depth) sweep.

Invariants (deep-linked from docs/architecture.md):

  * shard trees are fully independent — no tree word is shared, so the
    single-tree safety theorems (S1/S2) apply per shard and a
    cross-shard double allocation is structurally impossible: a lane is
    pending on exactly one shard per round (`shard[k]` is scalar);
  * with `n_shards == 1` every pool entry point is bit-identical to its
    single-tree counterpart (the vmap over one shard is the identity
    and the probe order is the single tree) — enforced by differential
    tests in tests/test_pool.py;
  * node numbering inside a shard is unchanged (root = 1, children
    2n/2n+1); a pool handle is the pair (shard, node) and unit offsets
    are per-shard, exactly like a replicated allocator's (arena, addr).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import fastpath as fpmod
from repro.core import magazine as magmod
from repro.core.bits import FIB_HASH
from repro.core.concurrent import TreeConfig, alloc_round, free_round
from repro.core.fastpath import FastPathConfig
from repro.core.magazine import MagazineConfig, MagazineState
from repro.obs.schema import POOL_STEP_SLOTS, spec as metric_spec

Array = jax.Array


def _named(stats: dict) -> dict:
    """Every stats key must be a registered metric (obs/schema.py)."""
    for name in stats:
        metric_spec(name)  # raises on unregistered names
    return stats


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Static geometry of the sharded pool: S replicas of one tree.

    `fastpath`, when set, carves the leftmost `slab_level` subtree out
    of every shard's tree for a bitmap slab of fast-octave blocks
    (core/fastpath.py, docs/design.md §9); the slab's bitmap words are
    appended to each shard's state row so the pool remains one stacked
    `[S, n_state_words]` array.

    `magazines`, when set, enables the per-lane recycling layer
    (core/magazine.py, docs/design.md §10): callers thread a
    `MagazineState` through the `*_mag` pool entry points and freed
    leaf pages are recycled lane-locally with zero shared-state RMWs.
    The magazine state is *per requester population*, not per shard, so
    it lives alongside — not inside — the `[S, n_state_words]` array
    (create it with `pool_init_magazines`)."""

    tree: TreeConfig
    n_shards: int = 1
    fastpath: FastPathConfig | None = None
    magazines: MagazineConfig | None = None

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        if self.fastpath is not None:
            self.fastpath.validate(self.tree)
        if self.magazines is not None:
            self.magazines.validate()

    @property
    def n_words(self) -> int:
        """Per-shard node-index space (layout-independent)."""
        return self.tree.n_words

    @property
    def fp_state_words(self) -> int:
        """Slab bitmap words per shard (0 without a fastpath)."""
        if self.fastpath is None:
            return 0
        return fpmod.fp_state_words(self.tree, self.fastpath)

    @property
    def n_state_words(self) -> int:
        """Per-shard persistent state words of the configured layout,
        plus the appended fastpath slab bitmap words when enabled."""
        return self.tree.n_state_words + self.fp_state_words

    @property
    def total_units(self) -> int:
        return self.n_shards << self.tree.depth

    def empty_trees(self) -> Array:
        if self.fastpath is None:
            return jnp.zeros(
                (self.n_shards, self.n_state_words),
                dtype=self.tree.state_dtype,
            )
        # the carve is committed through the layout's own alloc pass, so
        # `allocatable` excludes the slab's subtree with zero new code
        tree = fpmod.carved_empty_tree(self.tree, self.fastpath)
        row = jnp.concatenate(
            [tree, jnp.zeros(self.fp_state_words, tree.dtype)]
        )
        return jnp.tile(row[None, :], (self.n_shards, 1))


def home_shard(pcfg: PoolConfig, lane_ids: Array) -> Array:
    """Deterministic home shard of each requester lane (Fibonacci hash)."""
    h = lane_ids.astype(jnp.uint32) * jnp.uint32(FIB_HASH)
    return (h % jnp.uint32(pcfg.n_shards)).astype(jnp.int32)


def probe_shard(pcfg: PoolConfig, home: Array, attempt: Array) -> Array:
    """Shard probed on the given overflow attempt (fixed cyclic order)."""
    return (home + attempt) % pcfg.n_shards


# ---------------------------------------------------------------------------
# Pool rounds: one vmapped per-shard pass + overflow re-routing
# ---------------------------------------------------------------------------


def pool_alloc_round(
    pcfg: PoolConfig,
    trees: Array,
    levels: Array,
    pending: Array,
    shard: Array,
    attempt: Array,
    nodes: Array,
):
    """One pool arbitration round.

    Runs `alloc_round` on every shard (vmapped; each lane participates
    in the shard it is currently routed to), then re-routes lanes whose
    shard is exhausted at their level to the next shard in the probe
    order.  Lanes that merely lost arbitration stay on their shard and
    retry, exactly like the single tree.

    With a fastpath configured, lanes requesting the fast octave first
    probe their current shard's slab bitmap (single-RMW claim, no
    arbitration); only the spill — slab exhausted or a different
    octave — enters the buddy round.  Probing the *current* shard each
    round (not just the home shard) keeps overflow semantics identical
    to a slab-free pool: a re-routed lane sees the probed shard's slab
    exactly as it would see its leftmost free blocks.

    Returns (trees, nodes, pending, shard, attempt, merged, logical,
    won, fp_hits).
    """
    S = pcfg.n_shards
    K = levels.shape[0]
    fp = pcfg.fastpath
    sh_ids = jnp.arange(S, dtype=jnp.int32)
    lane_mask = shard[None, :] == sh_ids[:, None]        # [S, K]

    fp_hits = jnp.int32(0)
    fp_merged = jnp.int32(0)
    got_fp = jnp.zeros(K, bool)
    TW = pcfg.tree.n_state_words
    if fp is not None:
        tree_part, slab_part = trees[:, :TW], trees[:, TW:]
        eligible = pending & (levels == fpmod.fp_level(pcfg.tree, fp))
        want_s = eligible[None, :] & lane_mask
        claim = jax.vmap(
            functools.partial(fpmod.slab_claim, pcfg.tree, fp),
            in_axes=(0, 0),
        )
        slab_part, nodes_fp_s, got_s, merged_fp_s, hits_s = claim(
            slab_part, want_s
        )
        got_fp = got_s.any(axis=0)
        nodes = jnp.where(got_fp, (nodes_fp_s * got_s).sum(axis=0), nodes)
        pending = pending & ~got_fp
        fp_hits = hits_s.sum(dtype=jnp.int32)
        fp_merged = merged_fp_s.sum(dtype=jnp.int32)
    else:
        tree_part, slab_part = trees, trees[:, TW:]
    sh_pending = pending[None, :] & lane_mask

    rnd = jax.vmap(
        functools.partial(alloc_round, pcfg.tree),
        in_axes=(0, None, 0, None),
    )
    tree_part, nodes_s, pending_s, merged_s, logical_s, won_s = rnd(
        tree_part, levels, sh_pending, jnp.zeros((K,), jnp.int32)
    )
    trees = (
        jnp.concatenate([tree_part, slab_part], axis=1)
        if fp is not None
        else tree_part
    )

    won = won_s.any(axis=0)          # a lane is pending on exactly one shard
    won_node = (nodes_s * won_s).sum(axis=0)
    nodes = jnp.where(won, won_node, nodes)
    # a lane still pending after its shard's round lost arbitration;
    # pending lanes that vanished without winning exhausted the shard
    pend_after = pending_s.any(axis=0)
    exhausted = pending & ~won & ~pend_after

    attempt = attempt + exhausted.astype(jnp.int32)
    give_up = exhausted & (attempt >= S)   # probed every shard: fail
    shard = jnp.where(exhausted & ~give_up, (shard + 1) % S, shard)
    pending = pending & ~won & ~give_up
    return (
        trees,
        nodes,
        pending,
        shard,
        attempt,
        merged_s.sum(dtype=jnp.int32) + fp_merged,
        logical_s.sum(dtype=jnp.int32) + fp_hits,
        won | got_fp,
        fp_hits,
    )


@functools.partial(jax.jit, static_argnums=(0, 4))
def pool_wavefront_alloc(
    pcfg: PoolConfig,
    trees: Array,
    levels: Array,
    active: Array,
    max_rounds: int = 64,
    lane_ids: Array | None = None,
) -> Tuple[Array, Array, Array, Array, dict]:
    """Allocate a wavefront of requests across the pool.

    Args:
      pcfg: static pool geometry.
      trees: [S, n_state_words] stacked layout state words
        (`pcfg.tree.state_dtype`; int32[S, n_words] for `Unpacked`).
      levels: int32[K] target level per request (per-shard-tree levels).
      active: bool[K] request-present mask.
      max_rounds: static bound on pool rounds (progress: every round each
        contended shard commits or exhausts >= 1 lane, and a lane probes
        at most S shards, so K + S rounds always suffice).
      lane_ids: int32[K] requester identities for home-shard hashing
        (defaults to arange(K)).

    Returns:
      (trees, nodes, shard, ok, stats) — nodes int32[K] (0 where
      failed/inactive), shard int32[K] the serving shard of each lane
      (its handle is the pair), ok bool[K]; stats adds 'overflows' (lanes
      served off their home shard) plus 'fastpath_hits'/'fastpath_spills'
      (fast-octave lanes served by the slab vs not; both zero without a
      fastpath) to the single-tree counters.
    """
    K = levels.shape[0]
    if lane_ids is None:
        lane_ids = jnp.arange(K, dtype=jnp.int32)
    home = home_shard(pcfg, lane_ids)

    def round_body(carry):
        (trees, nodes, pending, shard, attempt,
         rounds, merged, logical, hits) = carry
        trees, nodes, pending, shard, attempt, m, l, _, h = pool_alloc_round(
            pcfg, trees, levels, pending, shard, attempt, nodes
        )
        return (
            trees, nodes, pending, shard, attempt,
            rounds + 1, merged + m, logical + l, hits + h,
        )

    def cond(carry):
        _, _, pending, _, _, rounds, _, _, _ = carry
        return pending.any() & (rounds < max_rounds)

    init = (
        trees,
        jnp.zeros(K, dtype=jnp.int32),
        active,
        home,
        jnp.zeros(K, dtype=jnp.int32),
        jnp.int32(0),
        jnp.int32(0),
        jnp.int32(0),
        jnp.int32(0),
    )
    trees, nodes, _, shard, _, rounds, merged, logical, hits = lax.while_loop(
        cond, round_body, init
    )
    ok = nodes > 0
    if pcfg.fastpath is None:
        fast_total = jnp.int32(0)
    else:
        fast = levels == fpmod.fp_level(pcfg.tree, pcfg.fastpath)
        fast_total = (active & fast).sum(dtype=jnp.int32)
    stats = _named({
        "rounds": rounds,
        "merged_writes": merged,
        "logical_rmws": logical,
        "overflows": (ok & (shard != home)).sum(dtype=jnp.int32),
        "fastpath_hits": hits,
        "fastpath_spills": fast_total - hits,
    })
    return trees, nodes, shard, ok, stats


def pool_free_round(
    pcfg: PoolConfig,
    trees: Array,
    nodes: Array,
    shard: Array,
    active: Array,
) -> Tuple[Array, Array, Array, Array]:
    """Release a multi-shard burst: one merged `free_round` per shard,
    all S applied in a single vmapped O(depth) sweep.  Each lane's node
    is released on the shard recorded in its handle; double frees and
    junk handles are dropped per shard exactly like the single tree.

    With a fastpath configured, handles route purely by node range:
    slab slots release through the bitmap (`slab_release`, single
    merged RMW per shard), any other node inside or on the path to the
    carved subtree is junk (neither allocator can have issued it —
    freeing it tree-side could merge the carve away) and is dropped,
    and everything else takes the ordinary merged buddy release.

    Returns (trees, merged_writes, logical_rmws, freed)."""
    S = pcfg.n_shards
    fp = pcfg.fastpath
    sh_ids = jnp.arange(S, dtype=jnp.int32)
    lane_mask = shard[None, :] == sh_ids[:, None]
    if fp is None:
        tree_part, slab_part = trees, None
        tree_active = active
    else:
        TW = pcfg.tree.n_state_words
        tree_part, slab_part = trees[:, :TW], trees[:, TW:]
        slab_leaf = fpmod.in_slab_leaf(pcfg.tree, fp, nodes)
        junk = fpmod.in_carved_junk(pcfg.tree, fp, nodes)
        tree_active = active & ~slab_leaf & ~junk
        rel = jax.vmap(
            functools.partial(fpmod.slab_release, pcfg.tree, fp),
            in_axes=(0, None, 0),
        )
        slab_part, sl_freed_s, sl_merged_s, sl_logical_s = rel(
            slab_part, nodes, (active & slab_leaf)[None, :] & lane_mask
        )
    sh_active = tree_active[None, :] & lane_mask
    rnd = jax.vmap(
        functools.partial(free_round, pcfg.tree), in_axes=(0, None, 0)
    )
    tree_part, merged_s, logical_s, freed_s = rnd(
        tree_part, nodes, sh_active
    )
    merged = merged_s.sum(dtype=jnp.int32)
    logical = logical_s.sum(dtype=jnp.int32)
    freed = freed_s.any(axis=0)
    if fp is None:
        return tree_part, merged, logical, freed
    return (
        jnp.concatenate([tree_part, slab_part], axis=1),
        merged + sl_merged_s.sum(dtype=jnp.int32),
        logical + sl_logical_s.sum(dtype=jnp.int32),
        freed | sl_freed_s.any(axis=0),
    )


# ---------------------------------------------------------------------------
# In-graph occupancy introspection (serving observability)
# ---------------------------------------------------------------------------


def pool_free_units(pcfg: PoolConfig, trees: Array) -> Array:
    """Free allocation units per shard, int32[S] — computed in-graph.

    A leaf is free iff it is allocatable under the tree's layout (word
    bit-free and no reserved ancestor), so the per-shard sum over the
    leaf slice is exactly `NBBSRef.free_bytes() / min_size` of the host
    mirror.  O(n_words) vector work; cheap enough to ride along in the
    jitted engine step's stats (docs/design.md §8).  With a fastpath,
    free slab slots count at their octave's unit width, so totals match
    an uncarved pool of the same capacity."""
    cfg = pcfg.tree
    lo = 1 << cfg.depth
    TW = cfg.n_state_words

    def one(row):
        alloc = cfg.layout.allocatable(cfg, row[:TW])
        n = alloc[lo : 2 * lo].sum(dtype=jnp.int32)
        if pcfg.fastpath is not None:
            n = n + fpmod.slab_free_units(cfg, pcfg.fastpath, row[TW:])
        return n

    return jax.vmap(one)(trees)


def pool_largest_run(pcfg: PoolConfig, trees: Array) -> Array:
    """Largest allocatable run (in units) across all shards, int32
    scalar — the in-graph mirror of `PagedKVManager.fragmentation()`'s
    `largest_run` (fragmentation observability without a host sync)."""
    cfg = pcfg.tree
    TW = cfg.n_state_words

    def one(row):
        alloc = cfg.layout.allocatable(cfg, row[:TW])
        best = jnp.int32(0)
        # static unrolled loop, deepest level first so larger runs win
        for lev in range(cfg.depth, cfg.max_level - 1, -1):
            lo, hi = 1 << lev, 1 << (lev + 1)
            has = alloc[lo:hi].any()
            best = jnp.where(has, jnp.int32(1 << (cfg.depth - lev)), best)
        if pcfg.fastpath is not None:
            # a free slab slot is a run of the fast octave's width
            has = fpmod.slab_free_slots(cfg, pcfg.fastpath, row[TW:]) > 0
            run = jnp.where(
                has,
                jnp.int32(fpmod.fp_units_per_slot(cfg, pcfg.fastpath)),
                0,
            )
            best = jnp.maximum(best, run)
        return best

    return jax.vmap(one)(trees).max()


@functools.partial(jax.jit, static_argnums=(0,))
def pool_wavefront_free(
    pcfg: PoolConfig,
    trees: Array,
    nodes: Array,
    shard: Array,
    active: Array,
) -> Tuple[Array, Array, dict]:
    """Jitted pool release. Returns (trees, freed, stats)."""
    trees, merged, logical, freed = pool_free_round(
        pcfg, trees, nodes, shard, active
    )
    return trees, freed, _named(
        {"merged_writes": merged, "logical_rmws": logical}
    )


@functools.partial(jax.jit, static_argnums=(0, 7))
def pool_wavefront_step(
    pcfg: PoolConfig,
    trees: Array,
    free_nodes: Array,
    free_shard: Array,
    free_active: Array,
    alloc_levels: Array,
    alloc_active: Array,
    max_rounds: int = 64,
    lane_ids: Array | None = None,
):
    """One pool scheduler round: the per-shard merged release pass
    first, then the pool allocation wavefront with overflow probing
    (one legal linearization of a mixed multi-shard batch).

    Returns (trees, nodes, shard, ok, stats)."""
    trees, free_merged, free_logical, freed = pool_free_round(
        pcfg, trees, free_nodes, free_shard, free_active
    )
    trees, nodes, shard, ok, stats = pool_wavefront_alloc(
        pcfg, trees, alloc_levels, alloc_active, max_rounds, lane_ids
    )
    stats = dict(stats)
    stats["free_writes"] = free_merged
    stats["free_merged_writes"] = free_merged
    stats["free_logical_rmws"] = free_logical
    stats["freed"] = freed.sum(dtype=jnp.int32)
    stats["magazine_hits"] = jnp.int32(0)
    stats["magazine_spills"] = jnp.int32(0)
    stats["magazine_refills"] = jnp.int32(0)
    # the reference path must expose at least the Pallas kernel's slots,
    # so every impl of nbbs_pool_wavefront_step names the same metrics
    missing = set(POOL_STEP_SLOTS) - set(stats)
    if missing:  # pragma: no cover - drift guard
        raise KeyError(f"pool step stats missing schema slots {missing}")
    return trees, nodes, shard, ok, _named(stats)


# ---------------------------------------------------------------------------
# Magazine fusion: lane-local recycling in front of the slab/tree rounds
# (core/magazine.py, docs/design.md §10)
# ---------------------------------------------------------------------------


def pool_init_magazines(pcfg: PoolConfig, n_lanes: int) -> MagazineState:
    """Empty magazines for a pool with a `MagazineConfig` attached."""
    if pcfg.magazines is None:
        raise ValueError("pool has no MagazineConfig attached")
    return magmod.init_magazines(pcfg.magazines, n_lanes)


def _gid_of(pcfg: PoolConfig, shard: Array, nodes: Array) -> Array:
    """Global leaf page id of a (shard, leaf-node) handle."""
    lo = 1 << pcfg.tree.depth
    return shard.astype(jnp.int32) * lo + (nodes.astype(jnp.int32) - lo)


def _gid_parts(pcfg: PoolConfig, gid: Array) -> Tuple[Array, Array]:
    """(shard, leaf node) of a global page id (clamped for gid < 0)."""
    lo = 1 << pcfg.tree.depth
    g = jnp.maximum(gid.astype(jnp.int32), 0)
    return g // lo, lo + g % lo


def pool_mag_free_per_shard(pcfg: PoolConfig, mags: MagazineState) -> Array:
    """int32[S]: stashed pages per shard (stashed pages stay marked
    allocated in their shard's tree, so occupancy gauges add this to
    `pool_free_units`)."""
    return magmod.mag_free_per_shard(
        mags, pcfg.n_shards, 1 << pcfg.tree.depth
    )


def pool_alloc_round_mag(
    pcfg: PoolConfig,
    trees: Array,
    mags: MagazineState,
    levels: Array,
    pending: Array,
    shard: Array,
    attempt: Array,
    nodes: Array,
    mag_lane: Array,
    mag_rank: Array | None = None,
):
    """One pool arbitration round with the magazine claim fused in
    front: leaf-octave lanes first pop their own magazine (zero
    shared-state RMWs; the serving shard becomes the popped page's
    recorded shard), and only the misses fall through into this SAME
    round's fastpath-then-tree wavefront (`pool_alloc_round`).

    `mag_rank` optionally skips the claim's group-rank sort when the
    caller's lane structure makes the rank trivial (`mag_claim`).

    Returns (trees, mags, nodes, pending, shard, attempt, merged,
    logical, won, fp_hits, mag_got) — mag_got bool[K] marks the lanes
    a magazine pop served this round."""
    cfg = pcfg.tree
    want = pending & (levels == cfg.depth)
    mags, gids, got, _ = magmod.mag_claim(
        pcfg.magazines, mags, want, mag_lane, rank=mag_rank
    )
    g_shard, g_node = _gid_parts(pcfg, gids)
    nodes = jnp.where(got, g_node, nodes)
    shard = jnp.where(got, g_shard, shard)
    pending = pending & ~got
    (trees, nodes, pending, shard, attempt,
     merged, logical, won, fp_hits) = pool_alloc_round(
        pcfg, trees, levels, pending, shard, attempt, nodes
    )
    return (
        trees, mags, nodes, pending, shard, attempt,
        merged, logical, won | got, fp_hits, got,
    )


def _mag_stash_phase(
    pcfg: PoolConfig,
    trees: Array,
    mags: MagazineState,
    nodes: Array,
    shard: Array,
    active: Array,
    mag_lane: Array,
    mag_rank: Array | None = None,
    assume_owned: bool = False,
):
    """The stash pre-pass of a magazine-fused release burst.

    A handle may stash only if (a) it is a leaf node, (b) its lane has
    a magazine, (c) the pool currently marks it allocated — the exact
    ownership predicates the release paths themselves use
    (`layout.node_occ_at` for tree leaves, the slab bit for slab-range
    leaves, never carved junk) — and (d) it is the min-lane instance of
    its page in the burst (the same dedup rule as `free_round`, lifted
    to the global page space so a stash and a tree-free of one page
    cannot both happen).  Every other instance of a *stashed* page is
    dropped from the burst; everything that did not stash falls through
    unchanged to the ordinary merged release.

    `assume_owned=True` (static) skips predicates (c) and (d): the
    caller asserts every active handle is a distinct page the pool
    currently marks allocated.  The jit engine qualifies — its block
    tables hold exactly the pages its lanes allocated — and the skip
    removes an [S, K] occupancy derivation plus a page-space scatter
    from every step.  `mag_rank` optionally skips the group-rank sort
    (`mag_stash`); with `assume_owned` the candidate set is exactly
    `active & leaf & (mag_lane >= 0)`, so the caller can rank it.

    Returns (mags, active_out, stashed, spills)."""
    cfg = pcfg.tree
    S = pcfg.n_shards
    K = nodes.shape[0]
    TW = cfg.n_state_words
    lo = 1 << cfg.depth
    nodes = nodes.astype(jnp.int32)
    in_leaf = active & (nodes >= lo) & (nodes < 2 * lo)
    safe_nodes = jnp.where(in_leaf, nodes, lo)
    safe_shard = jnp.clip(shard.astype(jnp.int32), 0, S - 1)

    if assume_owned:
        gid = _gid_of(pcfg, safe_shard, safe_nodes)
        stash_cand = in_leaf & (mag_lane >= 0)
        mags, stashed = magmod.mag_stash(
            pcfg.magazines, mags, gid, stash_cand, mag_lane,
            rank=mag_rank,
        )
        spills = (stash_cand & ~stashed).sum(dtype=jnp.int32)
        return mags, active & ~stashed, stashed, spills

    fp = pcfg.fastpath
    if fp is not None and fpmod.fp_level(cfg, fp) == cfg.depth:
        slab_mask = in_leaf & fpmod.in_slab_leaf(cfg, fp, safe_nodes)
        occ_s = jax.vmap(functools.partial(fpmod._slab_occ, cfg, fp))(
            trees[:, TW:]
        )  # [S, n_slots]
        base = fpmod.fp_node_base(cfg, fp)
        slot = jnp.clip(
            safe_nodes - base, 0, fpmod.fp_n_slots(cfg, fp) - 1
        )
        occ_fp = occ_s[safe_shard, slot]
    else:
        slab_mask = jnp.zeros(K, bool)
        occ_fp = jnp.zeros(K, bool)
    junk = (
        fpmod.in_carved_junk(cfg, fp, safe_nodes)
        if fp is not None
        else jnp.zeros(K, bool)
    )
    occ_tree_s = jax.vmap(
        lambda row: cfg.layout.node_occ_at(cfg, row[:TW], safe_nodes)
    )(trees)  # [S, K]
    occ_tree = occ_tree_s[safe_shard, jnp.arange(K, dtype=jnp.int32)]
    owned = jnp.where(slab_mask, occ_fp, occ_tree & ~junk)

    # burst-wide min-lane dedup over the global page space: only one
    # instance of a page may stash, and a stashed page's duplicates
    # must not fall through to a tree-side free
    ids = jnp.arange(K, dtype=jnp.int32)
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    key = jnp.where(in_leaf, _gid_of(pcfg, safe_shard, safe_nodes), 0)
    own = jnp.full(S * lo, big, jnp.int32).at[key].min(
        jnp.where(in_leaf, ids, big)
    )
    winner = in_leaf & (own[key] == ids)

    stash_cand = winner & (mag_lane >= 0) & owned
    mags, stashed = magmod.mag_stash(
        pcfg.magazines, mags, key, stash_cand, mag_lane
    )
    spills = (stash_cand & ~stashed).sum(dtype=jnp.int32)
    stash_mark = jnp.zeros(S * lo, bool).at[key].max(stashed)
    active_out = active & ~(in_leaf & stash_mark[key])
    return mags, active_out, stashed, spills


def pool_free_round_mag(
    pcfg: PoolConfig,
    trees: Array,
    mags: MagazineState,
    nodes: Array,
    shard: Array,
    active: Array,
    mag_lane: Array,
    mag_rank: Array | None = None,
    assume_owned: bool = False,
):
    """Magazine-fused release burst: the stash pre-pass recycles leaf
    handles lane-locally (zero shared-state RMWs), then everything that
    dropped through — full magazines, non-leaf handles, magazine-less
    lanes — takes the SAME round's ordinary merged slab/tree release
    (`pool_free_round`).  `mag_rank`/`assume_owned` are the stash
    pre-pass fast paths (`_mag_stash_phase`).

    Returns (trees, mags, merged, logical, freed, stashes, spills)."""
    mags, active2, stashed, spills = _mag_stash_phase(
        pcfg, trees, mags, nodes, shard, active, mag_lane,
        mag_rank=mag_rank, assume_owned=assume_owned,
    )
    trees, merged, logical, freed = pool_free_round(
        pcfg, trees, nodes, shard, active2
    )
    return (
        trees, mags, merged, logical, freed | stashed,
        stashed.sum(dtype=jnp.int32), spills,
    )


def _mag_spill_all(pcfg: PoolConfig, trees: Array, mags: MagazineState):
    """Release every stashed page back to its shard's slab/tree in one
    merged burst.  Returns (trees, mags, merged, logical, n_spilled)."""
    gids, live = magmod.mag_contents(mags)
    sh, nd = _gid_parts(pcfg, gids)
    trees, merged, logical, _ = pool_free_round(pcfg, trees, nd, sh, live)
    return (
        trees,
        magmod.mag_clear(mags, jnp.bool_(True)),
        merged,
        logical,
        live.sum(dtype=jnp.int32),
    )


@functools.partial(jax.jit, static_argnums=(0, 5))
def pool_wavefront_alloc_mag(
    pcfg: PoolConfig,
    trees: Array,
    mags: MagazineState,
    levels: Array,
    active: Array,
    max_rounds: int = 64,
    lane_ids: Array | None = None,
    mag_lane: Array | None = None,
    mag_rank: Array | None = None,
):
    """Allocate a wavefront of requests with magazines fused in.

    Three fused phases, all in-graph:

      1. the ordinary pool wavefront with the magazine claim in front
         of every round (`pool_alloc_round_mag`; claims can only land
         in the first round since nothing restocks mid-wavefront, but
         misses fall through into the same round's slab/tree pass);
      2. if any lane failed outright while magazines still hold pages,
         ONE merged spill-back releases every stashed page to its tree
         (`magazine_spills`) — magazines never strand capacity;
      3. the failed lanes rerun the wavefront from their home shard
         against the replenished trees.

    Phase 2+3 make a magazines-on pool capacity-equivalent to
    magazines-off: an allocation fails only if the pool as a whole
    cannot serve it.  `mag_rank` optionally skips the claim's
    group-rank sort (`mag_claim`); a fixed rank stays valid across
    rounds because nothing restocks mid-wavefront — every round-2+
    claim misses under any ranking.  Returns (trees, mags, nodes,
    shard, ok, stats); stats adds 'magazine_hits'/'magazine_spills'/
    'magazine_refills' to the `pool_wavefront_alloc` counters."""
    if pcfg.magazines is None:
        raise ValueError("pool_wavefront_alloc_mag needs pcfg.magazines")
    K = levels.shape[0]
    if lane_ids is None:
        lane_ids = jnp.arange(K, dtype=jnp.int32)
    if mag_lane is None:
        mag_lane = jnp.full(K, -1, jnp.int32)
    home = home_shard(pcfg, lane_ids)

    def round_body(carry):
        (trees, mags, nodes, pending, shard, attempt, magged,
         rounds, merged, logical, fph) = carry
        (trees, mags, nodes, pending, shard, attempt,
         m, l, _, fh, got) = pool_alloc_round_mag(
            pcfg, trees, mags, levels, pending, shard, attempt, nodes,
            mag_lane, mag_rank=mag_rank,
        )
        return (
            trees, mags, nodes, pending, shard, attempt, magged | got,
            rounds + 1, merged + m, logical + l, fph + fh,
        )

    def cond(carry):
        pending, rounds = carry[3], carry[7]
        return pending.any() & (rounds < max_rounds)

    init = (
        trees, mags,
        jnp.zeros(K, jnp.int32), active, home,
        jnp.zeros(K, jnp.int32), jnp.zeros(K, bool),
        jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.int32(0),
    )
    (trees, mags, nodes, _, shard, _, magged,
     rounds, merged, logical, fph) = lax.while_loop(
        cond, round_body, init
    )
    magh = magged.sum(dtype=jnp.int32)
    ok1 = nodes > 0
    failed = active & ~ok1

    # phase 2: exhaustion spill-back (one merged burst, at most once)
    do_spill = failed.any() & (magmod.mag_total(mags) > 0)

    def spill(args):
        trees, mags = args
        return _mag_spill_all(pcfg, trees, mags)

    def no_spill(args):
        trees, mags = args
        z = jnp.int32(0)
        return trees, mags, z, z, z

    trees, mags, sp_merged, sp_logical, n_spill = lax.cond(
        do_spill, spill, no_spill, (trees, mags)
    )

    # phase 3: failed lanes retry from home against replenished trees
    retry = failed & do_spill

    def round_body2(carry):
        (trees, nodes, pending, shard, attempt,
         rounds, merged, logical, fph) = carry
        (trees, nodes, pending, shard, attempt,
         m, l, _, fh) = pool_alloc_round(
            pcfg, trees, levels, pending, shard, attempt, nodes
        )
        return (
            trees, nodes, pending, shard, attempt,
            rounds + 1, merged + m, logical + l, fph + fh,
        )

    def cond2(carry):
        pending, rounds = carry[2], carry[5]
        return pending.any() & (rounds < max_rounds)

    shard = jnp.where(retry, home, shard)
    init2 = (
        trees, nodes, retry, shard, jnp.zeros(K, jnp.int32),
        jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.int32(0),
    )
    (trees, nodes, _, shard, _,
     rounds2, merged2, logical2, fph2) = lax.while_loop(
        cond2, round_body2, init2
    )
    ok = nodes > 0

    if pcfg.fastpath is None:
        fast_total = jnp.int32(0)
    else:
        fast = levels == fpmod.fp_level(pcfg.tree, pcfg.fastpath)
        fast_total = (active & fast).sum(dtype=jnp.int32)
        if fpmod.fp_level(pcfg.tree, pcfg.fastpath) == pcfg.tree.depth:
            # magazine-served lanes never reached the slab
            fast_total = fast_total - magh
    hits = fph + fph2
    stats = _named({
        "rounds": rounds + rounds2,
        "merged_writes": merged + merged2 + sp_merged,
        "logical_rmws": logical + logical2 + sp_logical,
        # a magazine pop serves a lane off the popped page's recorded
        # shard — that is recycling, not an overflow probe
        "overflows": (ok & ~magged & (shard != home)).sum(dtype=jnp.int32),
        "fastpath_hits": hits,
        "fastpath_spills": fast_total - hits,
        "magazine_hits": magh,
        "magazine_spills": n_spill,
        "magazine_refills": jnp.int32(0),
    })
    return trees, mags, nodes, shard, ok, stats


@functools.partial(jax.jit, static_argnums=(0, 8))
def pool_wavefront_free_mag(
    pcfg: PoolConfig,
    trees: Array,
    mags: MagazineState,
    nodes: Array,
    shard: Array,
    active: Array,
    mag_lane: Array | None = None,
    mag_rank: Array | None = None,
    assume_owned: bool = False,
):
    """Jitted magazine-fused pool release.  `mag_rank`/`assume_owned`
    are the stash pre-pass fast paths (`_mag_stash_phase`).
    Returns (trees, mags, freed, stats)."""
    if pcfg.magazines is None:
        raise ValueError("pool_wavefront_free_mag needs pcfg.magazines")
    if mag_lane is None:
        mag_lane = jnp.full(nodes.shape[0], -1, jnp.int32)
    trees, mags, merged, logical, freed, stashes, spills = (
        pool_free_round_mag(
            pcfg, trees, mags, nodes, shard, active, mag_lane,
            mag_rank=mag_rank, assume_owned=assume_owned,
        )
    )
    return trees, mags, freed, _named({
        "merged_writes": merged,
        "logical_rmws": logical,
        "magazine_spills": spills,
    })


@functools.partial(jax.jit, static_argnums=(0,))
def pool_magazine_drain(
    pcfg: PoolConfig, trees: Array, mags: MagazineState
):
    """Release every stashed page back to the pool (one merged burst
    per shard) and empty the magazines.  Draining restores the exact
    occupancy a magazines-off pool would have — the differential
    baseline (tests/test_magazine.py, tests/test_properties.py).

    Returns (trees, mags, stats)."""
    if pcfg.magazines is None:
        raise ValueError("pool_magazine_drain needs pcfg.magazines")
    trees, mags, merged, logical, n = _mag_spill_all(pcfg, trees, mags)
    return trees, mags, _named({
        "free_merged_writes": merged,
        "free_logical_rmws": logical,
        "magazine_spills": n,
    })


@functools.partial(jax.jit, static_argnums=(0,))
def pool_magazine_refill(
    pcfg: PoolConfig,
    trees: Array,
    mags: MagazineState,
    want_lanes: Array,
):
    """Batched magazine refill: pre-claim up to `refill_batch` leaf
    pages for every selected lane through ONE merged pool wavefront
    (the PR 1/2 burst machinery — one `pool_wavefront_alloc` per
    refill, never per page) and stash them.

    Returns (trees, mags, stats) with 'magazine_refills' counting the
    pages that landed in magazines."""
    mcfg = pcfg.magazines
    if mcfg is None or mcfg.refill_batch < 1:
        raise ValueError(
            "pool_magazine_refill needs pcfg.magazines.refill_batch >= 1"
        )
    B = mcfg.refill_batch
    L, C = mags.pages.shape
    cfg = pcfg.tree
    room = jnp.clip(C - mags.depth, 0, B)
    r_ids = jnp.arange(B, dtype=jnp.int32)
    req = want_lanes[:, None] & (r_ids[None, :] < room[:, None])
    lane_ids = jnp.repeat(jnp.arange(L, dtype=jnp.int32), B)
    levels = jnp.full(L * B, cfg.depth, jnp.int32)
    trees, nodes, shard, ok, astats = pool_wavefront_alloc(
        pcfg, trees, levels, req.reshape(-1), 64, lane_ids
    )
    gids = _gid_of(pcfg, shard, nodes)
    mags, stashed = magmod.mag_stash(mcfg, mags, gids, ok, lane_ids)
    # room was reserved per lane, so every claim stashes; the release
    # below is pure insurance against a leak if that ever changes
    leak = ok & ~stashed
    trees, _, _, _ = pool_free_round(pcfg, trees, nodes, shard, leak)
    stats = dict(astats)
    stats["magazine_refills"] = stashed.sum(dtype=jnp.int32)
    return trees, mags, _named(stats)


@functools.partial(jax.jit, static_argnums=(0, 8, 14))
def pool_wavefront_step_mag(
    pcfg: PoolConfig,
    trees: Array,
    mags: MagazineState,
    free_nodes: Array,
    free_shard: Array,
    free_active: Array,
    alloc_levels: Array,
    alloc_active: Array,
    max_rounds: int = 64,
    lane_ids: Array | None = None,
    free_mag_lane: Array | None = None,
    alloc_mag_lane: Array | None = None,
    free_mag_rank: Array | None = None,
    alloc_mag_rank: Array | None = None,
    assume_owned_frees: bool = False,
):
    """Magazine-fused pool scheduler round: the stash-then-release pass
    first, then the claim-then-wavefront allocation.  Same stats slots
    as `pool_wavefront_step` with the magazine counters live.  The
    `*_mag_rank`/`assume_owned_frees` fast paths are `_mag_stash_phase`
    and `mag_claim`'s caller-computed-rank contracts.

    Returns (trees, mags, nodes, shard, ok, stats)."""
    if pcfg.magazines is None:
        raise ValueError("pool_wavefront_step_mag needs pcfg.magazines")
    if free_mag_lane is None:
        free_mag_lane = jnp.full(free_nodes.shape[0], -1, jnp.int32)
    trees, mags, f_merged, f_logical, freed, _, f_spills = (
        pool_free_round_mag(
            pcfg, trees, mags, free_nodes, free_shard, free_active,
            free_mag_lane,
            mag_rank=free_mag_rank, assume_owned=assume_owned_frees,
        )
    )
    trees, mags, nodes, shard, ok, stats = pool_wavefront_alloc_mag(
        pcfg, trees, mags, alloc_levels, alloc_active, max_rounds,
        lane_ids, alloc_mag_lane, alloc_mag_rank,
    )
    stats = dict(stats)
    stats["free_writes"] = f_merged
    stats["free_merged_writes"] = f_merged
    stats["free_logical_rmws"] = f_logical
    stats["freed"] = freed.sum(dtype=jnp.int32)
    stats["magazine_spills"] = stats["magazine_spills"] + f_spills
    missing = set(POOL_STEP_SLOTS) - set(stats)
    if missing:  # pragma: no cover - drift guard
        raise KeyError(f"pool step stats missing schema slots {missing}")
    return trees, mags, nodes, shard, ok, _named(stats)
