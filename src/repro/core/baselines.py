"""Baseline allocators the paper compares against (§IV).

1. ``SpinlockTreeBuddy`` — the ``1lvl-sl`` configuration of the paper:
   the *same* tree data structure as the non-blocking buddy system, but
   with every operation executed under one global lock.  On this
   substrate (no preemptive threads inside a JAX program) a global lock
   is modelled by its defining property: concurrent requests are admitted
   strictly one at a time.  The wavefront benchmarks therefore charge it
   ``K`` serialized rounds for a batch of ``K`` requests, against the
   handful of arbitration rounds of the non-blocking version — exactly
   the scalability axis of the paper's Figures 8-11.  Lock acquire/release
   costs are additionally instrumented so wall-clock comparisons on the
   host include them.

2. ``FreeListBuddy`` — the Linux-kernel-style buddy allocator (Fig. 12
   comparison): per-order free lists, split-on-alloc, buddy-merge-on-free,
   single lock.  We cannot load a kernel module in this container, so the
   algorithm (as described in Gorman, "Understanding the Linux Virtual
   Memory Manager", ch. 6) is reimplemented in user space.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set

from repro.core.ref import NBBSRef, _ilog2


class SpinlockTreeBuddy(NBBSRef):
    """Same tree as NBBS, global-lock discipline (paper's 1lvl-sl)."""

    def __init__(self, *args, **kwargs) -> None:
        super().__init__(*args, **kwargs)
        self.lock_acquisitions = 0

    def nb_alloc(self, size: int, scattered: bool = False) -> Optional[int]:
        self.lock_acquisitions += 1  # lock()
        out = super().nb_alloc(size, scattered=scattered)
        return out  # unlock()

    def nb_free(self, addr: int) -> None:
        self.lock_acquisitions += 1  # lock()
        super().nb_free(addr)  # unlock()


@dataclasses.dataclass
class FreeListStats:
    allocs_ok: int = 0
    allocs_failed: int = 0
    frees: int = 0
    splits: int = 0
    merges: int = 0
    lock_acquisitions: int = 0


class FreeListBuddy:
    """Linux-style multi-list buddy allocator (single global lock).

    State: for every order ``o`` (block of ``min_size * 2**o`` bytes) a
    set of free block start-offsets.  Allocation pops from the smallest
    sufficient order, splitting larger blocks as needed; free re-inserts
    and greedily merges with the buddy while it is also free.
    """

    def __init__(
        self,
        total_memory: int,
        min_size: int,
        max_size: Optional[int] = None,
        base_address: int = 0,
    ) -> None:
        if max_size is None:
            max_size = total_memory
        self.total_memory = total_memory
        self.min_size = min_size
        self.max_size = max_size
        self.base_address = base_address
        self.max_order = _ilog2(total_memory // min_size)
        self.max_alloc_order = _ilog2(max_size // min_size)
        # free_lists[order] = set of unit-offsets of free blocks
        self.free_lists: List[Set[int]] = [set() for _ in range(self.max_order + 1)]
        self.free_lists[self.max_order].add(0)
        self.alloc_order: Dict[int, int] = {}  # unit-offset -> order
        self.stats = FreeListStats()

    def _order_for_size(self, size: int) -> int:
        if size <= self.min_size:
            return 0
        units = (size + self.min_size - 1) // self.min_size
        order = _ilog2(units)
        if (1 << order) < units:
            order += 1
        return order

    def nb_alloc(self, size: int) -> Optional[int]:
        self.stats.lock_acquisitions += 1
        if size > self.max_size:
            self.stats.allocs_failed += 1
            return None
        order = self._order_for_size(max(size, 1))
        # Find the smallest order with a free block.
        o = order
        while o <= self.max_order and not self.free_lists[o]:
            o += 1
        if o > self.max_order:
            self.stats.allocs_failed += 1
            return None
        off = min(self.free_lists[o])  # deterministic pop
        self.free_lists[o].discard(off)
        # Split down to the requested order.
        while o > order:
            o -= 1
            self.free_lists[o].add(off + (1 << o))
            self.stats.splits += 1
        self.alloc_order[off] = order
        self.stats.allocs_ok += 1
        return self.base_address + off * self.min_size

    def nb_free(self, addr: int) -> None:
        self.stats.lock_acquisitions += 1
        off = (addr - self.base_address) // self.min_size
        order = self.alloc_order.pop(off)
        # Merge with the buddy while possible.
        while order < self.max_order:
            buddy = off ^ (1 << order)
            if buddy not in self.free_lists[order]:
                break
            self.free_lists[order].discard(buddy)
            off = min(off, buddy)
            order += 1
            self.stats.merges += 1
        self.free_lists[order].add(off)
        self.stats.frees += 1

    def free_bytes(self) -> int:
        return sum(
            len(s) * (self.min_size << o) for o, s in enumerate(self.free_lists)
        )

    def allocated_ranges(self) -> List[range]:
        out = []
        for off, order in self.alloc_order.items():
            start = self.base_address + off * self.min_size
            out.append(range(start, start + (self.min_size << order)))
        return out
