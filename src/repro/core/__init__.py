"""Core contribution: the non-blocking buddy system (paper Algorithms 1-4)
and its TPU-native wavefront adaptation.

Modules:
  bits        — status-bit algebra (5-bit node masks)
  ref         — paper-faithful sequential oracle (host allocator)
  baselines   — spin-lock tree buddy + Linux-style free-list buddy
  concurrent  — batched wavefront allocator (jnp, jittable; kernel oracle)
  nbbs_jax    — single-op in-graph API on top of the wavefront
  pool        — sharded multi-tree pool (replicated trees + overflow routing)
  fastpath    — fixed-size bitmap-slab front end carved out of the tree
  bunch       — packed-word multi-level variant (paper §III-D, host)
  layout      — device tree-state layouts: Unpacked / BunchPacked (§III-D)
"""

from repro.core.bits import BUSY, OCC, STATUS_BITS  # noqa: F401
from repro.core.bunch import BunchBuddy  # noqa: F401
from repro.core.concurrent import (  # noqa: F401
    BUNCH_PACKED,
    BunchPacked,
    TreeConfig,
    TreeLayout,
    UNPACKED,
    Unpacked,
    free_batch,
    free_batch_sequential,
    free_round,
    levels_from_sizes,
    wavefront_alloc,
    wavefront_free,
    wavefront_step,
)
from repro.core.fastpath import FastPathConfig  # noqa: F401
from repro.core.nbbs_jax import (  # noqa: F401
    AllocState,
    PoolAllocState,
    init_pool_state,
    init_state,
    nb_alloc,
    nb_free,
    nb_free_batch,
    nb_pool_alloc,
    nb_pool_free_batch,
)
from repro.core.pool import (  # noqa: F401
    PoolConfig,
    home_shard,
    pool_free_round,
    pool_wavefront_alloc,
    pool_wavefront_free,
    pool_wavefront_step,
    probe_shard,
)
from repro.core.ref import NBBSRef, NBBSStats  # noqa: F401
from repro.core.baselines import FreeListBuddy, SpinlockTreeBuddy  # noqa: F401
