"""Per-lane magazines: a zero-RMW recycling cache over the pool.

scalloc (arXiv 1503.09006) and SpeedMalloc (arXiv 2508.20253) both make
the same observation about multicore allocators: the big wins come from
a cheap local front end that absorbs alloc/free churn before it reaches
the shared structure.  The source paper positions NBBS as exactly the
kind of core allocator such layered services sit on top of.  This
module is that layer for the wavefront pool (docs/design.md §10): a
small fixed-capacity LIFO *magazine* of recently freed page handles per
requester lane (a decode lane / sequence group), so constant-occupancy
churn of the fast octave recycles pages lane-locally with **zero**
shared-state RMWs — no slab bit, no tree climb.

Representation (static shapes, jit/vmap/donation friendly):

  * `MagazineState.pages`: `int32[n_lanes, mag_cap]`, global leaf page
    ids (`shard * 2^depth + offset`), `-1` in empty slots;
  * `MagazineState.depth`: `int32[n_lanes]`, live entries per lane;
    slots `0..depth-1` are full, in push order (slot `depth-1` is the
    LIFO top).

Protocol (all burst ops, mirroring the pool's merged-round style):

  * `mag_claim`: each wanting lane pops from its own magazine.  Lanes
    sharing a magazine are ranked in lane order (the same stable order
    `alloc_round`'s rank assignment uses) and rank r pops slot
    `depth-1-r`, so concurrent claimants of one magazine take distinct
    slots top-down with no arbitration.  Misses simply stay pending —
    the caller's round falls through to the slab/tree wavefront.
  * `mag_stash`: each candidate lane pushes into its own magazine; rank
    r lands in slot `depth+r` and ranks beyond capacity *drop through*
    (stashed=False) to the caller's ordinary merged release.

A magazine only ever holds handles the pool still marks allocated —
stashing happens *instead of* releasing, never after — so a magazine
pop hands out a page the tree/slab side structurally cannot: the
single-tree safety argument (S1) is untouched, exactly like the
fastpath carve.  Capacity is conserved as
`pool_free_units + mag_total + live == total_units`
(tests/test_properties.py).

The ops here are pool-agnostic integer machinery; the fusion into pool
rounds (claim-then-wavefront, stash-then-release, exhaustion
spill-back, batched refill) lives in `core/pool.py`.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

_I32_MAX = jnp.iinfo(jnp.int32).max


@dataclasses.dataclass(frozen=True)
class MagazineConfig:
    """Static magazine geometry.

    `mag_cap` is the per-lane LIFO capacity (pages).  `refill_batch`,
    when nonzero, is how many pages one `pool_magazine_refill` burst
    pre-claims per selected lane — routed through ONE pool wavefront
    for the whole batch, never per page."""

    mag_cap: int = 4
    refill_batch: int = 0

    def validate(self) -> None:
        if self.mag_cap < 1:
            raise ValueError(
                f"magazine mag_cap must be >= 1, got {self.mag_cap}"
            )
        if self.refill_batch < 0:
            raise ValueError(
                f"magazine refill_batch must be >= 0, got "
                f"{self.refill_batch}"
            )


class MagazineState(NamedTuple):
    """Per-lane magazine contents (a leaf of the pool state pytree)."""

    pages: Array  # int32[n_lanes, mag_cap]; global page ids, -1 empty
    depth: Array  # int32[n_lanes]; slots 0..depth-1 are live


def init_magazines(mcfg: MagazineConfig, n_lanes: int) -> MagazineState:
    """All-empty magazines for `n_lanes` requester lanes."""
    mcfg.validate()
    return MagazineState(
        pages=jnp.full((n_lanes, mcfg.mag_cap), -1, jnp.int32),
        depth=jnp.zeros((n_lanes,), jnp.int32),
    )


def mag_total(mags: MagazineState) -> Array:
    """int32 scalar: pages currently stashed across all magazines."""
    return mags.depth.sum(dtype=jnp.int32)


def mag_contents(mags: MagazineState) -> Tuple[Array, Array]:
    """Flattened view for batched spill-back: (pages int32[L*C],
    live bool[L*C]) — live marks slots below each lane's depth."""
    L, C = mags.pages.shape
    live = jnp.arange(C, dtype=jnp.int32)[None, :] < mags.depth[:, None]
    return mags.pages.reshape(-1), live.reshape(-1)


def mag_clear(mags: MagazineState, enable: Array) -> MagazineState:
    """Empty every magazine when `enable` (bool scalar) is set."""
    return MagazineState(
        pages=jnp.where(enable, jnp.int32(-1), mags.pages),
        depth=jnp.where(enable, jnp.int32(0), mags.depth),
    )


def mag_free_per_shard(
    mags: MagazineState, n_shards: int, pages_per_shard: int
) -> Array:
    """int32[S]: stashed pages per owning shard (a stashed page stays
    marked allocated in its shard's tree, so occupancy gauges add this
    to `pool_free_units` to see through the magazines)."""
    pages, live = mag_contents(mags)
    sh = jnp.clip(
        jnp.maximum(pages, 0) // pages_per_shard, 0, n_shards - 1
    )
    return jnp.zeros(n_shards, jnp.int32).at[sh].add(
        live.astype(jnp.int32)
    )


def group_rank(keys: Array, cand: Array, n_groups: int) -> Array:
    """Rank of each candidate among candidates sharing its key, in
    index (lane) order — 0 for non-candidates.

    The grouped analogue of `slab_claim`'s cumsum rank: a stable sort
    over `O(K log K)` instead of a `K x n_groups` one-hot matrix, so
    it stays cheap on the engine's `B * max_lane_pages`-wide free
    bursts."""
    K = keys.shape[0]
    key = jnp.where(cand, keys, n_groups).astype(jnp.int32)
    order = jnp.argsort(key, stable=True)
    skey = key[order]
    first = jnp.searchsorted(skey, skey, side="left").astype(jnp.int32)
    rank_sorted = jnp.arange(K, dtype=jnp.int32) - first
    rank = jnp.zeros(K, jnp.int32).at[order].set(rank_sorted)
    return jnp.where(cand, rank, 0)


def mag_claim(
    mcfg: MagazineConfig,
    mags: MagazineState,
    want: Array,
    mag_lane: Array,
    rank: Array | None = None,
) -> Tuple[MagazineState, Array, Array, Array]:
    """Pop one page per wanting lane from its own magazine.

    Lanes whose `mag_lane` is out of range (< 0 or >= n_lanes) never
    claim.  Claimants of one magazine take distinct slots top-down in
    lane order; lanes ranked past the magazine's depth miss and stay
    with the caller (drop-through to the shared wavefront).

    `rank` optionally replaces the `group_rank` stable sort with a
    caller-computed rank (int32[K]).  It must be what `group_rank`
    would return — 0..n-1 dense per magazine over the candidates, in
    lane order.  Callers whose structure makes it trivial pass it to
    skip the O(K log K) sort: all-distinct `mag_lane` => all zeros
    (the jit engine's decode claim).

    Returns (mags, pages, got, hits) — pages int32[K] global page ids
    (-1 on miss), got bool[K], hits int32 scalar.  Zero shared-state
    RMWs: only the magazines mutate."""
    L, C = mags.pages.shape
    lane = mag_lane.astype(jnp.int32)
    cand = want & (lane >= 0) & (lane < L)
    safe_lane = jnp.where(cand, lane, 0)
    if rank is None:
        rank = group_rank(safe_lane, cand, L)
    else:
        rank = jnp.where(cand, rank.astype(jnp.int32), 0)
    depth_k = mags.depth[safe_lane]
    got = cand & (rank < depth_k)
    slot = jnp.where(got, depth_k - 1 - rank, 0)
    pages = jnp.where(got, mags.pages[safe_lane, slot], -1)
    # distinct (lane, slot) per winner, so one scatter empties them all
    drop = (
        jnp.zeros((L, C), bool).at[safe_lane, slot].max(got)
    )
    new_pages = jnp.where(drop, jnp.int32(-1), mags.pages)
    pops = jnp.zeros(L, jnp.int32).at[safe_lane].add(
        got.astype(jnp.int32)
    )
    return (
        MagazineState(pages=new_pages, depth=mags.depth - pops),
        pages,
        got,
        got.sum(dtype=jnp.int32),
    )


def mag_stash(
    mcfg: MagazineConfig,
    mags: MagazineState,
    pages: Array,
    want: Array,
    mag_lane: Array,
    rank: Array | None = None,
) -> Tuple[MagazineState, Array]:
    """Push one page per candidate lane into its own magazine.

    Stashers of one magazine land in distinct slots bottom-up in lane
    order; ranks past capacity drop through (stashed=False) so the
    caller releases them on the ordinary merged path.

    `rank` optionally replaces the `group_rank` stable sort, exactly
    as in `mag_claim`: it must be dense 0..n-1 per magazine over the
    candidates in lane order (a sparse rank would leave holes below
    the depth counter).  The jit engine's retire burst is a lane-major
    `[B, max_lane_pages]` block table whose rows fill prefix-wise, so
    its rank is just the column index.

    Returns (mags, stashed bool[K])."""
    L, C = mags.pages.shape
    lane = mag_lane.astype(jnp.int32)
    cand = want & (lane >= 0) & (lane < L)
    safe_lane = jnp.where(cand, lane, 0)
    if rank is None:
        rank = group_rank(safe_lane, cand, L)
    else:
        rank = jnp.where(cand, rank.astype(jnp.int32), 0)
    depth_k = mags.depth[safe_lane]
    slot = depth_k + rank
    stashed = cand & (slot < C)
    slot = jnp.where(stashed, slot, 0)
    # distinct (lane, slot) per stasher; scatter-max over a -1 base so
    # the single collision point (0, 0) resolves to the real page
    upd = jnp.full((L, C), -1, jnp.int32).at[safe_lane, slot].max(
        jnp.where(stashed, pages.astype(jnp.int32), -1)
    )
    new_pages = jnp.where(upd >= 0, upd, mags.pages)
    adds = jnp.zeros(L, jnp.int32).at[safe_lane].add(
        stashed.astype(jnp.int32)
    )
    return (
        MagazineState(pages=new_pages, depth=mags.depth + adds),
        stashed,
    )
