"""Packed-word ("bunch") buddy system — paper §III-D, generalized.

The paper packs a 4-level sub-tree (a *bunch*: 15 nodes, of which only
the 8 leaf nodes are materialized, 8 x 5 = 40 bits) into one 64-bit word
so that one RMW updates four tree levels at once.  The enabling insight
(paper Fig. 6) is that an interior node's state is *derivable* from its
descendants within the word:

    occ(n)       = AND over n's bunch-leaf range of OCC
    occ_left(n)  = OR  over the left-half range of (OCC|OCC_L|OCC_R)
    coal_left(n) = OR  over the left-half range of (COAL_L|COAL_R)

so only bunch leaves carry explicit bits; within-word state transitions
are atomic by construction (the whole word is CAS'd), and the climb only
touches the one bunch-leaf that is the parent of the lower bunch's root
(one RMW per B levels instead of per level).

Hardware adaptation (docs/design.md §2): the TPU VPU has 32-bit lanes (int64
is emulated), so the device-side packing is **B=3 levels per uint32**
(4 leaves x 5 bits = 20 bits).  The host-side allocator keeps the
paper's **B=4 per uint64**.  Both are provided by this one
implementation, parameterized by (B, word dtype); both are validated to
produce *identical allocation addresses* to the unpacked oracle
(`core/ref.py`) on arbitrary traces, while issuing ~B x fewer word RMWs
on climbs — the paper's central §III-D claim.

Bunch layout: bunch layers cover tree levels [kB, (k+1)B); the bottom
layer may be partial.  A bunch is identified by its root node index r
(level ≡ 0 mod B); its word stores its deepest-materialized level.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.bits import (
    BUSY,
    COAL_LEFT,
    COAL_RIGHT,
    OCC,
    OCC_LEFT,
    OCC_RIGHT,
    STATUS_BITS,
    level_of,
)
from repro.core.ref import _ilog2


@dataclasses.dataclass
class BunchStats:
    word_rmws: int = 0          # CAS-class word updates (the §III-D metric)
    word_rmw_failures: int = 0
    plain_writes: int = 0
    allocs_ok: int = 0
    allocs_failed: int = 0
    frees: int = 0

    def reset(self) -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, 0)


class BunchBuddy:
    """Buddy system over packed bunch words (paper §III-D).

    B=4 with 64-bit words reproduces the paper exactly; B=3 with 32-bit
    words is the TPU-native variant.
    """

    def __init__(
        self,
        total_memory: int,
        min_size: int,
        max_size: Optional[int] = None,
        base_address: int = 0,
        bunch_levels: int = 4,
        word_bits: int = 64,
    ) -> None:
        if max_size is None:
            max_size = total_memory
        leaves = 1 << (bunch_levels - 1)
        if leaves * STATUS_BITS > word_bits:
            raise ValueError(
                f"bunch of {bunch_levels} levels needs {leaves * STATUS_BITS}"
                f" bits > word size {word_bits}"
            )
        self.total_memory = total_memory
        self.min_size = min_size
        self.max_size = max_size
        self.base_address = base_address
        self.B = bunch_levels
        self.word_bits = word_bits
        self.depth = _ilog2(total_memory // min_size)
        self.max_level = _ilog2(total_memory // max_size)
        # words keyed by bunch-root node index (levels ≡ 0 mod B).
        self.words: Dict[int, int] = {}
        self.index: List[int] = [0] * (total_memory // min_size)
        self.stats = BunchStats()
        self._scan_hint: dict[int, int] = {}

    # ------------------------------------------------------------------
    # Geometry helpers
    # ------------------------------------------------------------------
    def _bunch_root(self, n: int) -> int:
        """Root node index of the bunch containing n."""
        return n >> (level_of(n) % self.B)

    def _stored_level(self, root: int) -> int:
        """Deepest tree level materialized in this bunch's word."""
        rl = level_of(root)
        return min(rl + self.B - 1, self.depth)

    def _leaf_range(self, n: int) -> range:
        """Within-word leaf slot range whose OR/AND derives node n."""
        root = self._bunch_root(n)
        lb = level_of(n) - level_of(root)          # within-bunch level of n
        sb = self._stored_level(root) - level_of(root)  # leaf within-level
        offset = n - (root << lb)
        lo = offset << (sb - lb)
        return range(lo, lo + (1 << (sb - lb)))

    def _word(self, root: int) -> int:
        return self.words.get(root, 0)

    def _leaf_bits(self, word: int, slot: int) -> int:
        return (word >> (slot * STATUS_BITS)) & 0x1F

    def _cas_word(self, root: int, expected: int, new: int) -> bool:
        self.stats.word_rmws += 1
        if self._word(root) != expected:
            self.stats.word_rmw_failures += 1
            return False
        if new:
            self.words[root] = new
        else:
            self.words.pop(root, None)
        return True

    # -- derived node state (paper Fig. 6) --------------------------------
    def node_state(self, n: int) -> int:
        """Reconstruct the 5-bit status of any tree node (for tests/debug)."""
        root = self._bunch_root(n)
        word = self._word(root)
        r = self._leaf_range(n)
        if len(r) == 1:
            return self._leaf_bits(word, r[0])
        half = len(r) // 2
        occ = all(self._leaf_bits(word, s) & OCC for s in r)
        lbusy = any(
            self._leaf_bits(word, s) & (OCC | OCC_LEFT | OCC_RIGHT)
            for s in r[:half]
        )
        rbusy = any(
            self._leaf_bits(word, s) & (OCC | OCC_LEFT | OCC_RIGHT)
            for s in r[half:]
        )
        lcoal = any(
            self._leaf_bits(word, s) & (COAL_LEFT | COAL_RIGHT) for s in r[:half]
        )
        rcoal = any(
            self._leaf_bits(word, s) & (COAL_LEFT | COAL_RIGHT) for s in r[half:]
        )
        return (
            (OCC if occ else 0)
            | (OCC_LEFT if lbusy else 0)
            | (OCC_RIGHT if rbusy else 0)
            | (COAL_LEFT if lcoal else 0)
            | (COAL_RIGHT if rcoal else 0)
        )

    def _is_free(self, n: int) -> bool:
        """Derived is_free: every leaf slot in n's range has no busy bit."""
        word = self._word(self._bunch_root(n))
        busy = OCC | OCC_LEFT | OCC_RIGHT
        return all((self._leaf_bits(word, s) & busy) == 0 for s in self._leaf_range(n))

    # ------------------------------------------------------------------
    # Size/level/address rules (identical to the unpacked allocator)
    # ------------------------------------------------------------------
    def level_for_size(self, size: int) -> int:
        level = _ilog2(self.total_memory // size) if size else self.depth
        return min(level, self.depth)

    def size_of_level(self, level: int) -> int:
        return self.total_memory >> level

    def starting_address(self, n: int) -> int:
        level = level_of(n)
        return self.base_address + (n - (1 << level)) * self.size_of_level(level)

    # ------------------------------------------------------------------
    # NBALLOC (Alg. 1) over bunches
    # ------------------------------------------------------------------
    def nb_alloc(self, size: int, scattered: bool = False) -> Optional[int]:
        if size > self.max_size or size < 0:
            self.stats.allocs_failed += 1
            return None
        level = self.level_for_size(max(size, 1))
        base = 1 << level
        n_nodes = 1 << level
        start = self._scan_hint.get(level, 0) if scattered else 0
        i = base + start
        end = base + n_nodes
        wrapped = not scattered
        while True:
            if i >= end:
                if wrapped:
                    break
                wrapped = True
                i = base
                end = base + start
                if i >= end:
                    break
            if self._is_free(i):
                failed_at = self._try_alloc(i)
                if not failed_at:
                    addr = self.starting_address(i)
                    self.index[(addr - self.base_address) // self.min_size] = i
                    self.stats.allocs_ok += 1
                    if scattered:
                        self._scan_hint[level] = (i + 1 - base) % n_nodes
                    return addr
                d = 1 << (level - level_of(failed_at))
                i = (failed_at + 1) * d
                continue
            i += 1
        self.stats.allocs_failed += 1
        return None

    # ------------------------------------------------------------------
    # TRYALLOC (Alg. 2): one RMW per bunch instead of one per level
    # ------------------------------------------------------------------
    def _busy_range_mask(self, n: int) -> int:
        mask = 0
        for s in self._leaf_range(n):
            mask |= BUSY << (s * STATUS_BITS)
        return mask

    def _range_nonzero_mask(self, n: int) -> int:
        mask = 0
        for s in self._leaf_range(n):
            mask |= 0x1F << (s * STATUS_BITS)
        return mask

    def _try_alloc(self, n: int) -> int:
        root = self._bunch_root(n)
        word = self._word(root)
        # CAS(range == 0 -> range |= BUSY): the bunch equivalent of T2.
        if word & self._range_nonzero_mask(n):
            self.stats.word_rmws += 1  # the failed CAS attempt
            self.stats.word_rmw_failures += 1
            return n
        if not self._cas_word(root, word, word | self._busy_range_mask(n)):
            return n  # pragma: no cover - sequential: cannot happen
        # Climb across bunches: mark the cross leaf (the parent of this
        # bunch's root) in each ancestor bunch — one RMW per bunch.
        cross = root >> 1
        while cross >= 1 and level_of(root) > self.max_level:
            proot = self._bunch_root(cross)
            slot = self._leaf_range(cross)[0]
            pword = self._word(proot)
            leaf = self._leaf_bits(pword, slot)
            if leaf & OCC:
                # Occupied ancestor discovered (T11): roll back.
                self._free_node(n, level_of(cross) + 1)
                return cross
            new_leaf = leaf & ~(COAL_LEFT >> (root & 1))   # clean_coal
            new_leaf = new_leaf | (OCC_LEFT >> (root & 1))  # mark
            nw = (pword & ~(0x1F << (slot * STATUS_BITS))) | (
                new_leaf << (slot * STATUS_BITS)
            )
            self._cas_word(proot, pword, nw)
            root = proot
            cross = root >> 1
        return 0

    # ------------------------------------------------------------------
    # NBFREE / FREENODE / UNMARK over bunches
    # ------------------------------------------------------------------
    def nb_free(self, addr: int) -> None:
        n = self.index[(addr - self.base_address) // self.min_size]
        self._free_node(n, self.max_level)
        self.stats.frees += 1

    def _derived_busy(self, m: int) -> bool:
        """Derived (OCC|OCC_L|OCC_R) != 0 for node m (paper Fig. 6 OR rule)."""
        word = self._word(self._bunch_root(m))
        busy = OCC | OCC_LEFT | OCC_RIGHT
        return any(
            (self._leaf_bits(word, s) & busy) != 0 for s in self._leaf_range(m)
        )

    def _derived_coal(self, m: int) -> bool:
        """Derived 'a release is in flight somewhere in m's subtree'."""
        word = self._word(self._bunch_root(m))
        return any(
            (self._leaf_bits(word, s) & (COAL_LEFT | COAL_RIGHT)) != 0
            for s in self._leaf_range(m)
        )

    def _is_cross(self, child: int) -> bool:
        """True iff `child` is a bunch root, i.e. its parent is an explicit
        RMW point (a bunch-leaf slot of the parent bunch)."""
        return level_of(child) % self.B == 0

    def _rmw_leaf(self, node: int, transform) -> int:
        """CAS-update the explicit leaf slot of `node`; returns the OLD
        5-bit leaf value (sequential: single attempt suffices)."""
        proot = self._bunch_root(node)
        slot = self._leaf_range(node)[0]
        pword = self._word(proot)
        leaf = self._leaf_bits(pword, slot)
        nw = (pword & ~(0x1F << (slot * STATUS_BITS))) | (
            transform(leaf) << (slot * STATUS_BITS)
        )
        self._cas_word(proot, pword, nw)
        return leaf

    def _free_node(self, n: int, upper_bound: int) -> None:
        """FREENODE over bunches: walk *every* level of the climb exactly
        as Alg. 3 does — buddy occupancy / coalescing decisions are taken
        at each level — but issue word RMWs only at explicit cross-bunch
        leaves; within-bunch levels are derived (Fig. 6) and their state
        transition happens atomically with phase 2's single word update.
        """
        # -- phase 1: coalescing marks bottom-up (lines F2-F18) -----------
        runner = n
        current = n >> 1
        while level_of(runner) > upper_bound:
            if self._is_cross(runner):
                leaf = self._rmw_leaf(
                    current, lambda v: v | (COAL_LEFT >> (runner & 1))
                )
                occ_buddy = (leaf & (OCC_RIGHT << (runner & 1))) != 0
                coal_buddy = (leaf & (COAL_RIGHT << (runner & 1))) != 0
            else:
                buddy = runner ^ 1
                occ_buddy = self._derived_busy(buddy)
                coal_buddy = self._derived_coal(buddy)
            if occ_buddy and not coal_buddy:
                break
            runner = current
            current >>= 1
        # -- phase 2: zero the node's leaf range (one atomic word op, F19) -
        root = self._bunch_root(n)
        word = self._word(root)
        self._cas_word(root, word, word & ~self._range_nonzero_mask(n))
        # -- phase 3: UNMARK upward (Alg. 4), same per-level walk ----------
        if level_of(n) == upper_bound:
            return
        current = n
        while True:
            child = current
            current >>= 1
            if self._is_cross(child):
                proot = self._bunch_root(current)
                slot = self._leaf_range(current)[0]
                leaf = self._leaf_bits(self._word(proot), slot)
                if not (leaf & (COAL_LEFT >> (child & 1))):
                    return  # branch re-used/re-released concurrently (U8)
                new_leaf = leaf & ~((OCC_LEFT | COAL_LEFT) >> (child & 1))
                self._rmw_leaf(current, lambda v: new_leaf)
                occ_buddy = (new_leaf & (OCC_RIGHT << (child & 1))) != 0
            else:
                occ_buddy = self._derived_busy(child ^ 1)
            if not (level_of(current) > upper_bound and not occ_buddy):
                return

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    def allocated_ranges(self) -> List[range]:
        """Occupied address coverage at bunch-leaf granularity.

        A single allocation of an interior bunch node appears as its run
        of leaf-slot ranges (the bits cannot distinguish "parent
        occupied" from "both children occupied" — paper Fig. 6 makes
        them semantically identical), so this is an exact *coverage* set
        rather than a per-allocation list.
        """
        out = []
        for root, word in self.words.items():
            sb = self._stored_level(root)
            size = self.size_of_level(sb)
            n_slots = 1 << (sb - level_of(root))
            for s in range(n_slots):
                if self._leaf_bits(word, s) & OCC:
                    node = (root << (sb - level_of(root))) + s
                    addr = self.starting_address(node)
                    out.append(range(addr, addr + size))
        return out

    def free_bytes(self) -> int:
        return self.total_memory - sum(len(r) for r in self.allocated_ranges())
