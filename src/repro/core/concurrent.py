"""Wavefront non-blocking buddy system — the TPU-native adaptation.

The paper's threads contend on tree words with CAS; losers retry.  A TPU
has no threads or CAS, so the same optimistic-concurrency insight is
re-thought for a data-parallel machine (docs/design.md §2):

  * a *wavefront* of K allocation requests is processed per round,
    entirely with vectorized bitwise/scan primitives (VPU-friendly);
  * each pending request is tentatively assigned a distinct free node of
    its target level via a rank/prefix-sum match (the vector analogue of
    the paper's scattered level scan);
  * cross-level conflicts (one request's node inside another's sub-tree)
    are detected with min-id propagation over the tree — the
    deterministic arbitration that replaces CAS serialization.  Losers
    retry next round, exactly like a failed CAS;
  * winners' climbs (paper TRYALLOC lines T6-T18) are *merged*: branch
    occupancy marks are monotone ORs, so all winners' paths are applied
    in one bottom-up pass per round.  This is the key TPU win: what costs
    each thread `level - max_level` RMWs on x86 costs the whole wavefront
    one vector pass — the same motivation as the paper's 4-level bunch
    optimization (§III-D), taken to its vector-width limit.

Progress property (the lock-freedom analogue, property-tested): every
round with pending requests either commits at least one request or fails
requests whose level is exhausted — the minimum-id winner always
survives arbitration, mirroring Lemma A.3.

Releases get the same treatment (`free_round` / `wavefront_free`): the
paper's FREENODE coalescing climb and UNMARK climb are not commutative
word-by-word — two frees whose climbs meet at a shared ancestor, or a
free racing an occupied buddy, produce different intermediate words
depending on order.  But for a *batch* applied to a quiescent tree the
order-dependence is confined to which climb clears the shared ancestor
segment; the final state of every legal linearization is identical (the
derived occupancy of paper Fig. 6).  The merged pass therefore (1)
clears all released node words at once (F19, vectorized), then (2)
resolves every meeting-point conflict in one bottom-up O(depth) sweep
that re-derives the branch occupancy bits along touched paths — the OR
over surviving sub-tree occupancy is exactly the fixed point all
sequential climb orders converge to.  Frees the pass cannot prove valid
(released word without OCC: double frees / junk handles) are dropped
rather than allowed to corrupt ancestor marks like a replayed
sequential climb would.  The faithful per-node scan survives as
`free_batch_sequential`, the differential oracle for the merged pass.
Rounds interleave frees-then-allocs, which is one legal linearization.

Everything here is shape-static and jittable; the Pallas kernel
(`kernels/nbbs_alloc.py`) implements the same per-round algorithm with
the tree resident in VMEM and this module is its oracle.  `core/pool.py`
replicates this tree S times and routes lanes across the replicas.

The persistent tree *state* is pluggable (docs/design.md §3): every
round operates through the `TreeConfig.layout` — `Unpacked` (one int32
word per node, the historical format and the differential oracle) or
`BunchPacked` (the paper's §III-D packing: B=3 levels / 4 leaf slots x
5 bits per uint32 word, interior bits derived per Fig. 6 within the
word, climbs crossing words only at bunch roots).  The rounds
themselves are layout-agnostic: they scan the layout's derived
*allocatable* predicate, arbitrate in node-index space, and hand winner
/ freed node masks back to the layout's merged commit passes.  Both
layouts produce identical allocation outcomes on identical traces
(differentially tested); only the word-traffic stats differ — which is
the point: packed `merged_writes` counts uint32 bunch words, ~B x fewer
per climb.

Invariants (deep-linked from docs/architecture.md):

  * node numbering: tree[0] is unused; the root is index 1, the
    children of node n are 2n and 2n+1, and level(n) = floor(log2 n)
    (`_level_of`) — every level-sliced pass below indexes the half-open
    slice [2^lev, 2^(lev+1)) (paper Fig. 2).  Node indices are
    layout-independent: handles and arbitration scratch always live in
    this space, whatever the state words look like;
  * occupancy encoding: `Unpacked` carries the 5-bit mask of
    `core/bits.py` per node; `BunchPacked` materializes it on bunch
    leaves only and derives interior state (Fig. 6).  In both, a node
    is allocatable iff its (derived) state is bit-free AND no strict
    ancestor has (derived) OCC (paper T2 + T11); branch occupancy of a
    quiescent tree is *derived*: a non-OCC node's OCC_LEFT/OCC_RIGHT
    equal the OR over the corresponding child sub-tree's reserved
    nodes, and no COAL bits remain (paper Fig. 6, checked by
    `NBBSRef.check_invariants`);
  * double-free arbitration: `free_round` drops any free whose node
    lacks (derived) OCC (stale/junk handle), and when one batch carries
    duplicate handles the minimum lane id wins — the same
    deterministic min-id arbitration the alloc side uses for
    overlapping tentative assignments.  (Layout caveat: `BunchPacked`
    cannot distinguish "n allocated" from "both children allocated", so
    that one *junk*-handle case is layout-specific — see
    `core/layout.py`.)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.bits import (
    BUSY,
    COAL_LEFT,
    COAL_RIGHT,
    OCC,
    OCC_LEFT,
    OCC_RIGHT,
)
from repro.core.layout import (  # noqa: F401  (re-exported API)
    BUNCH_PACKED,
    BunchPacked,
    TreeLayout,
    UNPACKED,
    Unpacked,
    _level_of,
)

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TreeConfig:
    """Static geometry of the allocator tree (+ its state layout)."""

    depth: int          # leaves are at this level; units = 2**depth
    max_level: int = 0  # largest allocatable block lives at this level
    layout: TreeLayout = UNPACKED  # persistent-word format (core/layout.py)

    @property
    def n_words(self) -> int:
        """Node-index space (layout-independent): 2^(depth+1)."""
        return 1 << (self.depth + 1)

    @property
    def n_state_words(self) -> int:
        """Persistent state words of the configured layout."""
        return self.layout.n_state_words(self)

    @property
    def state_dtype(self):
        return self.layout.state_dtype

    def empty_tree(self) -> Array:
        return self.layout.empty_tree(self)


# ---------------------------------------------------------------------------
# Vectorized tree passes (static unrolled loops over levels)
# ---------------------------------------------------------------------------


def _min_id_fields(cfg: TreeConfig, own: Array) -> Tuple[Array, Array]:
    """(desc_min, anc_min): min request-id over strict descendants /
    strict ancestors of every node, given per-node tentative owner ids."""
    inf = own.dtype.type(jnp.iinfo(own.dtype).max)
    desc = jnp.full(cfg.n_words, inf, dtype=own.dtype)
    for lev in range(cfg.depth - 1, -1, -1):
        lo, hi = 1 << lev, 1 << (lev + 1)
        child_own = own[2 * lo : 2 * hi]
        child_desc = desc[2 * lo : 2 * hi]
        m = jnp.minimum(child_own, child_desc).reshape(-1, 2).min(axis=1)
        desc = desc.at[lo:hi].set(m)
    ancm = jnp.full(cfg.n_words, inf, dtype=own.dtype)
    for lev in range(1, cfg.depth + 1):
        lo, hi = 1 << lev, 1 << (lev + 1)
        p = jnp.minimum(ancm[lo // 2 : hi // 2], own[lo // 2 : hi // 2])
        ancm = ancm.at[lo:hi].set(jnp.repeat(p, 2))
    return desc, ancm


# ---------------------------------------------------------------------------
# Wavefront allocation
# ---------------------------------------------------------------------------


def alloc_round(
    cfg: TreeConfig,
    tree: Array,
    levels: Array,
    pending: Array,
    nodes: Array,
):
    """One arbitration round of the wavefront (shared verbatim by the
    jnp driver below and the Pallas kernel's loop body; layout-agnostic
    — state reads/writes go through `cfg.layout`).

    Returns (tree, nodes, pending, merged_writes, logical_rmws, won).
    """
    layout = cfg.layout
    K = levels.shape[0]
    ids = jnp.arange(K, dtype=jnp.int32)
    inf = jnp.iinfo(jnp.int32).max

    # CAS(0 -> BUSY) needs the node's (derived) state to be bit-free
    # (paper T2) and no fully-occupied ancestor may exist (paper T11).
    allocatable = layout.allocatable(cfg, tree)

    target = jnp.zeros(K, dtype=jnp.int32)
    got = jnp.zeros(K, dtype=bool)
    exhausted = jnp.zeros(K, dtype=bool)
    for lev in range(cfg.max_level, cfg.depth + 1):
        lo, hi = 1 << lev, 1 << (lev + 1)
        avail = allocatable[lo:hi]
        cnt = avail.sum()
        req = pending & (levels == lev)
        rank = jnp.cumsum(req) - 1  # rank among this level's requests
        csum = jnp.cumsum(avail.astype(jnp.int32))
        node_of_rank = (
            jnp.searchsorted(csum, rank.astype(jnp.int32) + 1, side="left")
            .astype(jnp.int32)
            + lo
        )
        sel = req & (rank < cnt)
        target = jnp.where(sel, node_of_rank, target)
        got = got | sel
        exhausted = exhausted | (req & (cnt == 0))

    # --- arbitration: min request id wins on overlap ----------------
    own = jnp.full(cfg.n_words, inf, dtype=jnp.int32)
    own = own.at[jnp.where(got, target, 0)].min(jnp.where(got, ids, inf))
    desc, ancm = _min_id_fields(cfg, own)
    win = got & (ids < desc[target]) & (ids < ancm[target])

    # --- commit winners + merged climb (paper T2 + T6-T18, all
    # winners at once) through the layout's packed/unpacked pass ------
    win_nodes = jnp.where(win, target, 0)
    win_mask = jnp.zeros(cfg.n_words, dtype=bool).at[win_nodes].set(win)
    tree, merged = layout.commit_allocs(cfg, tree, win_mask)

    nodes = jnp.where(win, target, nodes)
    logical = layout.alloc_logical_rmws(cfg, win, levels)
    pending = pending & ~win & ~exhausted
    return tree, nodes, pending, merged, logical, win


@functools.partial(jax.jit, static_argnums=(0, 4))
def wavefront_alloc(
    cfg: TreeConfig,
    tree: Array,
    levels: Array,
    active: Array,
    max_rounds: int = 64,
) -> Tuple[Array, Array, Array, dict]:
    """Allocate a wavefront of requests.

    Args:
      cfg: static tree geometry (its `layout` fixes the state format).
      tree: `cfg.layout` state words (`cfg.n_state_words` of
        `cfg.state_dtype`; int32[n_words] for the default `Unpacked`).
      levels: int32[K] target level per request (from `level_for_size`).
      active: bool[K] request-present mask.
      max_rounds: static bound on arbitration rounds (progress guarantees
        termination long before this in practice; K+1 rounds always
        suffice because >=1 request commits or fails per round).

    Returns:
      (tree, nodes, ok, stats) — nodes int32[K] (0 where failed/inactive),
      ok bool[K]; stats dict with 'rounds', 'merged_writes',
      'logical_rmws' (per-request climb RMW count, the paper's metric).
    """
    K = levels.shape[0]

    def round_body(carry):
        tree, nodes, pending, rounds, merged_writes, logical_rmws = carry
        tree, nodes, pending, merged, logical, _ = alloc_round(
            cfg, tree, levels, pending, nodes
        )
        return (
            tree,
            nodes,
            pending,
            rounds + 1,
            merged_writes + merged,
            logical_rmws + logical,
        )

    def cond(carry):
        _, _, pending, rounds, _, _ = carry
        return pending.any() & (rounds < max_rounds)

    init = (
        tree,
        jnp.zeros(K, dtype=jnp.int32),
        active,
        jnp.int32(0),
        jnp.int32(0),
        jnp.int32(0),
    )
    tree, nodes, _, rounds, merged_writes, logical_rmws = lax.while_loop(
        cond, round_body, init
    )
    ok = nodes > 0
    stats = {
        "rounds": rounds,
        "merged_writes": merged_writes,
        "logical_rmws": logical_rmws,
    }
    return tree, nodes, ok, stats


# ---------------------------------------------------------------------------
# Faithful in-graph release (FREENODE + UNMARK with lax.while_loop)
# ---------------------------------------------------------------------------


def _free_one(cfg: TreeConfig, tree: Array, n: Array) -> Tuple[Array, Array]:
    """Release node `n` (paper Algorithms 3-4). Returns (tree, writes)."""
    ub = jnp.int32(cfg.max_level)
    n = n.astype(jnp.int32)

    # -- phase 1: coalescing marks bottom-up --------------------------------
    def ph1_cond(c):
        _, _, runner, brk, _ = c
        return (_level_of(runner) > ub) & ~brk

    def ph1_body(c):
        tree, current, runner, _, w = c
        or_val = COAL_LEFT >> (runner & 1)
        old = tree[current]
        tree = tree.at[current].set(old | or_val)
        occ_buddy = (old & (OCC_RIGHT << (runner & 1))) != 0
        coal_buddy = (old & (COAL_RIGHT << (runner & 1))) != 0
        brk = occ_buddy & ~coal_buddy
        return tree, current >> 1, current, brk, w + 1

    tree, _, _, _, writes = lax.while_loop(
        ph1_cond, ph1_body, (tree, n >> 1, n, jnp.bool_(False), jnp.int32(0))
    )

    # -- phase 2: plain write, release the node (F19) ------------------------
    tree = tree.at[n].set(0)
    writes = writes + 1

    # -- phase 3: UNMARK (do-while) ------------------------------------------
    def un_cond(c):
        _, _, stop, _ = c
        return ~stop

    def un_body(c):
        tree, current, _, w = c
        child = current
        current = current >> 1
        cv = tree[current]
        coal = (cv & (COAL_LEFT >> (child & 1))) != 0
        nv = cv & ~((OCC_LEFT | COAL_LEFT) >> (child & 1))
        tree = jnp.where(coal, tree.at[current].set(nv), tree)
        w = w + jnp.where(coal, 1, 0)
        occ_buddy = (nv & (OCC_RIGHT << (child & 1))) != 0
        stop = (~coal) | ~((_level_of(current) > ub) & ~occ_buddy)
        return tree, current, stop, w

    def run_unmark(args):
        tree, w = args
        tree, _, _, w2 = lax.while_loop(
            un_cond, un_body, (tree, n, jnp.bool_(False), jnp.int32(0))
        )
        return tree, w + w2

    tree, writes = lax.cond(
        _level_of(n) != ub, run_unmark, lambda a: a, (tree, writes)
    )
    return tree, writes


@functools.partial(jax.jit, static_argnums=(0,))
def free_batch_sequential(
    cfg: TreeConfig, tree: Array, nodes: Array, active: Array
) -> Tuple[Array, Array]:
    """Release a batch of nodes one at a time (faithful FREENODE/UNMARK
    scan; one legal linearization).  O(K·depth) serialized steps — kept
    as the differential oracle for `free_round`.  Returns (tree, writes).

    Unpacked-only: the scan replays the paper's per-word bit protocol,
    which has no meaning on packed state words."""
    if not isinstance(cfg.layout, Unpacked):
        raise ValueError(
            "free_batch_sequential requires the Unpacked layout; "
            f"got {cfg.layout!r} (use free_round / wavefront_free)"
        )

    def step(carry, x):
        tree, writes = carry
        node, act = x
        def do(tree):
            return _free_one(cfg, tree, node)
        tree, w = lax.cond(
            act & (node > 0), do, lambda t: (t, jnp.int32(0)), tree
        )
        return (tree, writes + w), None

    (tree, writes), _ = lax.scan(step, (tree, jnp.int32(0)), (nodes, active))
    return tree, writes


# ---------------------------------------------------------------------------
# Merged vectorized release (free-side wavefront)
# ---------------------------------------------------------------------------


def free_round(
    cfg: TreeConfig, tree: Array, nodes: Array, active: Array
) -> Tuple[Array, Array, Array, Array]:
    """One merged release pass: all of a batch's FREENODE/UNMARK climbs
    applied in O(depth) level-sliced vector ops (the release-side mirror
    of `alloc_round`; shared verbatim by the jnp drivers and the Pallas
    kernel).

    Phase 1 clears every released node word at once (F19).  Phase 2 is
    one bottom-up sweep: alongside a sub-tree-occupancy OR (does this
    sub-tree still contain a reserved node?), every non-OCC ancestor on a
    touched path gets its branch occupancy bits re-derived from that OR
    and its coalescing bits cleared.  Climbs that meet at a shared
    ancestor — the non-commutative case that forces retry loops on x86 —
    are resolved exactly: the OR is the fixed point every sequential
    climb order converges to, so no residue needs a serialized replay.
    Frees whose word lacks OCC (double free / junk handle) are dropped.

    Returns (tree, merged_writes, logical_rmws, freed) — freed is the
    bool[K] mask of frees actually applied; merged_writes counts state
    words the vector pass changed vs the paper's per-free logical_rmws
    (per-level CASes for `Unpacked`, per-bunch word RMWs for
    `BunchPacked`).
    """
    layout = cfg.layout
    K = nodes.shape[0]
    nodes = nodes.astype(jnp.int32)
    safe = jnp.clip(nodes, 0, cfg.n_words - 1)
    # out-of-range ids are junk handles, not aliases of the last leaf
    valid = (
        active
        & (nodes > 0)
        & (nodes < cfg.n_words)
        & layout.node_occ_at(cfg, tree, safe)
    )
    tgt = jnp.where(valid, safe, 0)
    # duplicate handles within one batch: min lane id wins (the same
    # arbitration the alloc side uses), later duplicates are dropped so
    # the freed mask and stats count each release exactly once
    ids = jnp.arange(K, dtype=jnp.int32)
    inf = jnp.iinfo(jnp.int32).max
    own = jnp.full(cfg.n_words, inf, dtype=jnp.int32).at[tgt].min(
        jnp.where(valid, ids, inf)
    )
    valid = valid & (own[tgt] == ids)
    tgt = jnp.where(valid, tgt, 0)

    logical = layout.free_logical_rmws(cfg, tree, tgt, valid)

    # -- phase 1 (F19, vectorized) + phase 2 (merged coalescing climb:
    # FREENODE marks + UNMARK as one fixed-point sweep), both through
    # the layout's release pass --------------------------------------
    freed = jnp.zeros(cfg.n_words, dtype=bool).at[tgt].set(valid)
    freed = freed.at[0].set(False)
    tree, merged = layout.apply_frees(cfg, tree, freed)
    return tree, merged, logical, valid


@functools.partial(jax.jit, static_argnums=(0,))
def wavefront_free(
    cfg: TreeConfig, tree: Array, nodes: Array, active: Array
) -> Tuple[Array, Array, dict]:
    """Release a wavefront of nodes in one merged O(depth) pass.

    Returns (tree, freed, stats) — freed bool[K]; stats mirrors
    `wavefront_alloc` ('merged_writes' vs 'logical_rmws', the release
    side of the paper's Fig. 7 metric)."""
    tree, merged, logical, freed = free_round(cfg, tree, nodes, active)
    return tree, freed, {"merged_writes": merged, "logical_rmws": logical}


@functools.partial(jax.jit, static_argnums=(0,))
def free_batch(
    cfg: TreeConfig, tree: Array, nodes: Array, active: Array
) -> Tuple[Array, Array]:
    """Release a batch of nodes via the merged vectorized pass.  Keeps the
    historical (tree, writes) signature; writes is now the merged word-
    update count.  Use `free_batch_sequential` for the faithful scan."""
    tree, merged, _, _ = free_round(cfg, tree, nodes, active)
    return tree, merged


@functools.partial(jax.jit, static_argnums=(0, 6))
def wavefront_step(
    cfg: TreeConfig,
    tree: Array,
    free_nodes: Array,
    free_active: Array,
    alloc_levels: Array,
    alloc_active: Array,
    max_rounds: int = 64,
):
    """One scheduler round: the merged release pass first, then the
    allocation wavefront (one legal linearization of a mixed concurrent
    batch)."""
    tree, free_merged, free_logical, freed = free_round(
        cfg, tree, free_nodes, free_active
    )
    tree, nodes, ok, stats = wavefront_alloc(
        cfg, tree, alloc_levels, alloc_active, max_rounds
    )
    stats = dict(stats)
    stats["free_writes"] = free_merged
    stats["free_merged_writes"] = free_merged
    stats["free_logical_rmws"] = free_logical
    stats["freed"] = freed.sum(dtype=jnp.int32)
    return tree, nodes, ok, stats


def levels_from_sizes(cfg: TreeConfig, total_memory: int, sizes: Array) -> Array:
    """Vectorized paper rule A5: level = floor(log2(total/size)), clamped."""
    sizes = jnp.maximum(sizes.astype(jnp.int32), 1)
    ratio = jnp.int32(total_memory) // sizes
    lev = 31 - lax.clz(jnp.maximum(ratio, 1))
    return jnp.clip(lev, 0, cfg.depth).astype(jnp.int32)
