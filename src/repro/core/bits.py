"""Status-bit algebra of the non-blocking buddy system (paper §III-A).

Every node of the allocator tree carries a 5-bit status mask:

    bit 0  OCC_RIGHT  — right sub-tree partially/fully occupied
    bit 1  OCC_LEFT   — left  sub-tree partially/fully occupied
    bit 2  COAL_RIGHT — a release is in flight in the right sub-tree
    bit 3  COAL_LEFT  — a release is in flight in the left  sub-tree
    bit 4  OCC        — this exact node has been reserved by an allocation

The helper functions below are direct transcriptions of the paper's
status-bit manipulation functions.  They are written against plain Python
integers / numpy arrays / jnp arrays interchangeably (only `&`, `|`, `~`,
`<<`, `>>` are used), so the same algebra backs the pure-Python oracle
(`core/ref.py`), the jitted allocator (`core/nbbs_jax.py`), the wavefront
allocator (`core/concurrent.py`) and the Pallas kernel
(`kernels/nbbs_alloc.py`).

`child` is always the *index* of a child node; `child & 1` discriminates
right (1) from left (0) children (left child of n is 2n, right is 2n+1).
"""

from __future__ import annotations

OCC_RIGHT = 0x1
OCC_LEFT = 0x2
COAL_RIGHT = 0x4
COAL_LEFT = 0x8
OCC = 0x10
BUSY = OCC | OCC_LEFT | OCC_RIGHT  # 0x13

# All five status bits — used to mask a node's full state out of packed words.
STATUS_MASK = OCC | OCC_LEFT | OCC_RIGHT | COAL_LEFT | COAL_RIGHT  # 0x1F
STATUS_BITS = 5

# Fibonacci multiplicative hashing constant (2^32 / golden ratio).  The
# single source of truth for home-shard routing: the device pool
# (`core/pool.home_shard`) and the host KV manager
# (`memory/kv_cache.PagedKVManager.home_shard`) both hash requester ids
# with it, so host and device always agree on "home".  Lives here (and
# not in core/pool.py) so jax-free host modules can import it.
FIB_HASH = 2654435761


def mod2(child):
    """1 for a right child (odd index), 0 for a left child (even index)."""
    return child & 1


def clean_coal(val, child):
    """Clear the coalescing bit of the branch that contains `child`."""
    return val & ~(COAL_LEFT >> mod2(child))


def mark(val, child):
    """Set the occupancy bit of the branch that contains `child`."""
    return val | (OCC_LEFT >> mod2(child))


def unmark(val, child):
    """Clear both coalescing and occupancy bits of `child`'s branch."""
    return val & ~((OCC_LEFT | COAL_LEFT) >> mod2(child))


def is_coal(val, child):
    """True iff the coalescing bit of `child`'s branch is set."""
    return (val & (COAL_LEFT >> mod2(child))) != 0


def is_occ_buddy(val, child):
    """True iff the occupancy bit of `child`'s *buddy* branch is set."""
    return (val & (OCC_RIGHT << mod2(child))) != 0


def is_coal_buddy(val, child):
    """True iff the coalescing bit of `child`'s *buddy* branch is set."""
    return (val & (COAL_RIGHT << mod2(child))) != 0


def is_free(val):
    """True iff the node is neither reserved nor partially occupied.

    Note coalescing bits do NOT make a node busy (paper §III-A): a node
    with only coalescing bits set is in a transient release state and is
    still rejected by the allocation CAS, which requires the word to be
    exactly zero.
    """
    return (val & BUSY) == 0


def level_of(n: int) -> int:
    """Tree level of node index `n` (root = index 1 = level 0)."""
    return n.bit_length() - 1


def level_first(level: int) -> int:
    """First node index of `level`."""
    return 1 << level


def level_nodes(level: int) -> int:
    """Number of nodes at `level`."""
    return 1 << level
