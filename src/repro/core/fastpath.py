"""Fixed-size fast path: a bitmap slab carved out of each buddy tree.

Decode-time appends are overwhelmingly single-page allocations of one
fixed octave, yet each one pays the paper's full O(depth/B) TRYALLOC
climb.  Blelloch & Wei (arXiv 2008.04296) show fixed-size concurrent
alloc/free is achievable in O(1) RMWs, and scalloc (arXiv 1503.09006)
demonstrates that a cheap size-class front end over a global structure
is where multicore allocators actually win — exactly the "combinable
with layered services" positioning of the source paper's abstract.

This module is that front end for the wavefront substrate
(docs/design.md §9):

  * at `PoolConfig` init one subtree — the *leftmost* node at
    `slab_level` — is carved out of each shard's buddy tree by
    committing it as allocated through the ordinary layout machinery
    (`layout.commit_allocs` on the empty tree).  The tree side can
    therefore never hand out a page under the carve: the mutual-
    exclusion argument is the tree's own S1 invariant, not new code;
  * the carved subtree's blocks at the *fast octave* (`level`,
    defaulting to the leaf level) are tracked by a bitmap slab — one
    bit per block, packed into words appended to the shard's tree row,
    so the pool state stays one `[S, n_state_words]` array and the
    Pallas kernel keeps the slab VMEM-resident next to the tree;
  * claim is a single RMW: rank the wanting lanes (cumsum), assign
    free slots in find-first-zero order (searchsorted over the free
    prefix sums — the same conflict-free tentative-assignment style as
    `alloc_round`, with no arbitration needed because distinct ranks
    map to distinct slots), OR the claimed bits in with one scatter;
  * release is a single RMW: validity = in-range AND bit currently
    set, duplicate handles in one burst deduplicated by min-lane-id
    exactly like `free_round`, then one AND-NOT scatter.

Handles stay path-agnostic: a slab block is addressed by its ordinary
buddy node index (the slab slots ARE the leftmost `level`-octave nodes
of the tree), so frees route purely by node range and
`free(alloc(x))` round-trips through whichever path served it.

Because the slab covers the leftmost blocks and claims assign slots in
index order — the same order `alloc_round`'s rank assignment walks
free nodes — a pure fast-octave workload is served *address-identical*
to an uncarved pool; mixed-octave workloads keep identical
capacity/failure semantics (tests/test_fastpath.py).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.concurrent import TreeConfig

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class FastPathConfig:
    """Static geometry of the fixed-size front end.

    `level` is the fast octave (tree level whose blocks the slab
    serves); None means the leaf level (single pages — the decode-
    append octave).  `slab_level` picks the carve: the leftmost node at
    that level is reserved for the slab, i.e. a 1/2^slab_level fraction
    of each shard's capacity."""

    level: int | None = None
    slab_level: int = 2

    def validate(self, cfg: TreeConfig) -> None:
        lv = self.resolved_level(cfg)
        if not (1 <= self.slab_level <= lv <= cfg.depth):
            raise ValueError(
                "fastpath needs 1 <= slab_level <= level <= depth, got "
                f"slab_level={self.slab_level} level={lv} depth={cfg.depth}"
            )
        if self.slab_level < cfg.max_level:
            raise ValueError(
                "fastpath slab_level must be >= tree max_level "
                f"({self.slab_level} < {cfg.max_level})"
            )

    def resolved_level(self, cfg: TreeConfig) -> int:
        return cfg.depth if self.level is None else self.level


# ---------------------------------------------------------------------------
# Static geometry helpers (python ints — safe inside Pallas kernels)
# ---------------------------------------------------------------------------


def fp_level(cfg: TreeConfig, fp: FastPathConfig) -> int:
    return fp.resolved_level(cfg)


def fp_carve_node(fp: FastPathConfig) -> int:
    """The reserved subtree root: leftmost node at slab_level."""
    return 1 << fp.slab_level


def fp_n_slots(cfg: TreeConfig, fp: FastPathConfig) -> int:
    """Fast-octave blocks under the carve (slab bitmap width)."""
    return 1 << (fp_level(cfg, fp) - fp.slab_level)


def fp_node_base(cfg: TreeConfig, fp: FastPathConfig) -> int:
    """Node index of slab slot 0 (slots are nodes base..base+n_slots)."""
    return 1 << fp_level(cfg, fp)


def fp_units_per_slot(cfg: TreeConfig, fp: FastPathConfig) -> int:
    return 1 << (cfg.depth - fp_level(cfg, fp))


def fp_state_words(cfg: TreeConfig, fp: FastPathConfig) -> int:
    """Slab bitmap words appended to each shard's tree-state row."""
    return (fp_n_slots(cfg, fp) + 31) // 32


def carved_empty_tree(cfg: TreeConfig, fp: FastPathConfig) -> Array:
    """Empty tree state with the slab's subtree pre-marked allocated.

    Written through `layout.commit_allocs` so the carve is the layout's
    own canonical "this node is allocated" state — `allocatable` then
    excludes every block under it for free, in both layouts."""
    win = jnp.zeros(cfg.n_words, bool).at[fp_carve_node(fp)].set(True)
    tree, _ = cfg.layout.commit_allocs(cfg, cfg.empty_tree(), win)
    return tree


# ---------------------------------------------------------------------------
# Node-range routing masks (frees route by address range)
# ---------------------------------------------------------------------------


def in_slab_leaf(cfg: TreeConfig, fp: FastPathConfig, nodes: Array) -> Array:
    """bool[K]: node is a slab slot (fast-octave block under the carve)."""
    base = fp_node_base(cfg, fp)
    return (nodes >= base) & (nodes < base + fp_n_slots(cfg, fp))


def in_carved_junk(cfg: TreeConfig, fp: FastPathConfig, nodes: Array) -> Array:
    """bool[K]: node is inside or on the path to the carved subtree but
    is NOT a slab slot.  Such handles can never have been returned by
    either allocator — a tree-side free of one could merge the slab's
    reservation away, so the pool drops them outright."""
    n = jnp.clip(nodes, 1, cfg.n_words - 1).astype(jnp.int32)
    lev = 31 - lax.clz(n)
    carve = fp_carve_node(fp)
    # inside the carved subtree: ancestor at slab_level == carve node
    inside = (lev >= fp.slab_level) & (
        (n >> jnp.maximum(lev - fp.slab_level, 0)) == carve
    )
    # on the root->carve path: the leftmost node of each shallower level
    on_path = (lev < fp.slab_level) & (n == (1 << lev).astype(jnp.int32))
    in_range = (nodes >= 1) & (nodes < cfg.n_words)
    return in_range & (inside | on_path) & ~in_slab_leaf(cfg, fp, nodes)


# ---------------------------------------------------------------------------
# Slab bitmap claim / release (single-RMW per op, whole burst merged)
# ---------------------------------------------------------------------------


def _slab_occ(cfg: TreeConfig, fp: FastPathConfig, slab: Array) -> Array:
    """bool[n_slots]: slot occupied (bit set)."""
    u = slab.astype(jnp.uint32)
    idx = jnp.arange(fp_n_slots(cfg, fp), dtype=jnp.int32)
    return ((u[idx >> 5] >> (idx & 31).astype(jnp.uint32)) & 1) != 0


def slab_claim(
    cfg: TreeConfig, fp: FastPathConfig, slab: Array, want: Array
) -> Tuple[Array, Array, Array, Array, Array]:
    """Claim one fast-octave block per wanting lane from the slab.

    Rank/prefix-sum tentative assignment in find-first-zero order —
    the bitmap analogue of `alloc_round`'s per-level pass, except no
    min-id arbitration is needed: distinct ranks map to distinct free
    slots, so every selected lane wins.  All claimed bits commit with
    ONE scatter into the slab words (the merged single-RMW claim).

    Returns (slab, nodes, got, merged_writes, hits)."""
    occ = _slab_occ(cfg, fp, slab)
    free = ~occ
    cnt = free.sum(dtype=jnp.int32)
    rank = jnp.cumsum(want.astype(jnp.int32)) - 1
    csum = jnp.cumsum(free.astype(jnp.int32))
    slot = jnp.searchsorted(csum, rank + 1, side="left").astype(jnp.int32)
    sel = want & (rank < cnt)
    slot = jnp.where(sel, slot, 0)
    u = slab.astype(jnp.uint32)
    contrib = jnp.where(
        sel, jnp.uint32(1) << (slot & 31).astype(jnp.uint32), jnp.uint32(0)
    )
    new = u.at[slot >> 5].add(contrib)  # distinct slots: add == OR
    merged = (new != u).sum(dtype=jnp.int32)
    nodes = jnp.where(sel, fp_node_base(cfg, fp) + slot, 0)
    return (
        new.astype(slab.dtype),
        nodes,
        sel,
        merged,
        sel.sum(dtype=jnp.int32),
    )


def slab_release(
    cfg: TreeConfig, fp: FastPathConfig, slab: Array, nodes: Array,
    active: Array,
) -> Tuple[Array, Array, Array, Array]:
    """Release a burst of slab handles: validity = in-range AND bit
    currently set; duplicate handles in the burst deduplicated by
    min-lane-id (same rule as `free_round`); all cleared bits commit
    with ONE AND-NOT scatter.

    Returns (slab, freed, merged_writes, logical_rmws)."""
    K = nodes.shape[0]
    base = fp_node_base(cfg, fp)
    n_slots = fp_n_slots(cfg, fp)
    nodes = nodes.astype(jnp.int32)
    in_r = active & (nodes >= base) & (nodes < base + n_slots)
    slot = jnp.where(in_r, nodes - base, 0)
    occ = _slab_occ(cfg, fp, slab)
    valid = in_r & occ[slot]
    ids = jnp.arange(K, dtype=jnp.int32)
    big = jnp.int32(jnp.iinfo(jnp.int32).max)
    own = jnp.full(n_slots, big, jnp.int32).at[slot].min(
        jnp.where(valid, ids, big)
    )
    valid = valid & (own[slot] == ids)
    u = slab.astype(jnp.uint32)
    contrib = jnp.where(
        valid, jnp.uint32(1) << (slot & 31).astype(jnp.uint32), jnp.uint32(0)
    )
    mask = jnp.zeros_like(u).at[slot >> 5].add(contrib)
    new = u & ~mask
    merged = (new != u).sum(dtype=jnp.int32)
    return (
        new.astype(slab.dtype),
        valid,
        merged,
        valid.sum(dtype=jnp.int32),
    )


# ---------------------------------------------------------------------------
# Occupancy introspection (rides along in the engine's in-graph stats)
# ---------------------------------------------------------------------------


def slab_free_slots(cfg: TreeConfig, fp: FastPathConfig, slab: Array) -> Array:
    """int32 scalar: free fast-octave blocks in the slab."""
    return (~_slab_occ(cfg, fp, slab)).sum(dtype=jnp.int32)


def slab_free_units(cfg: TreeConfig, fp: FastPathConfig, slab: Array) -> Array:
    return slab_free_slots(cfg, fp, slab) * jnp.int32(
        fp_units_per_slot(cfg, fp)
    )
