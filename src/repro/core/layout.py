"""Tree state layouts: one-word-per-node vs packed bunches (§III-D).

The wavefront rounds of `core/concurrent.py` are layout-agnostic: they
scan a per-node *allocatable* predicate, arbitrate winners in node-index
space, and then hand the winner/freed node masks back to the layout to
commit.  This module provides the two concrete layouts:

  * `Unpacked` — the historical device layout: `int32[2^(depth+1)]`,
    node n's 5-bit status word at index n.  One word RMW per level on a
    climb; the differential oracle for every other layout.
  * `BunchPacked` — the paper's §III-D packing adapted to 32-bit VPU
    lanes (docs/design.md §3): B=3 tree levels per bunch, the bunch's
    4 leaf nodes × 5 status bits packed into one uint32 word (20 bits).
    Only bunch leaves are materialized; interior-node state is *derived*
    within the word by the Fig. 6 rules (occ = AND over the leaf range's
    OCC bits, branch occupancy = OR over the half-range's busy bits), so
    a climb writes one word per B levels and the whole tree shrinks to
    ~1/7 of the unpacked word count.

Bunch layering is **bottom-aligned**: the deepest layer covers tree
levels [depth-B+1, depth] with full 4-leaf bunches and the partial layer
(if depth+1 is not a multiple of B) is the cheap one at the top — this
keeps the packed word count <= ~n_words/7 for every depth, unlike the
top-aligned layering of the host `core/bunch.py` whose partial *bottom*
layer would dominate.  Layer k's words are stored contiguously, indexed
by bunch-root node index minus the level base, top layer first.

Packed-word bit layout (one uint32, B=3, leaf slots s0..s3 left-to-right
in node order, 12 bits unused):

       31 .. 20   19 .. 15   14 .. 10    9 .. 5     4 .. 0
      [ unused ] [ slot 3 ] [ slot 2 ] [ slot 1 ] [ slot 0 ]
                  each slot: OCC | COAL_L | COAL_R | OCC_L | OCC_R

Canonical packed state (the quiescent-tree invariant all merged passes
preserve): a slot inside an allocated node's leaf range holds BUSY
(OCC|OCC_L|OCC_R — exactly what `core/bunch.py`'s range CAS writes), a
slot above live sub-bunches holds the OR of its child bunches' occupancy
as OCC_LEFT/OCC_RIGHT marks, every other slot is zero, and bunches below
an allocated node are all-zero words.  COAL bits are never set by the
device layouts: the merged release pass of `free_round` re-derives final
occupancy in one sweep, so the sequential protocol's in-flight
coalescing marks have no device-side counterpart.

Stale-handle caveat (shared with the paper's §III-D packing): the packed
bits cannot distinguish "node n allocated" from "both children of n
allocated separately", so a *junk* free of n in the latter state is
dropped by `Unpacked` (word lacks OCC) but releases both children under
`BunchPacked` (derived OCC holds) — the same semantics as
`core.bunch.BunchBuddy._free_node`.  On valid traces (every free matches
a live allocation) the layouts are outcome-identical; the differential
tests replay exactly those.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.bits import (
    BUSY,
    COAL_LEFT,
    COAL_RIGHT,
    OCC,
    OCC_LEFT,
    OCC_RIGHT,
    STATUS_BITS,
    STATUS_MASK,
)

Array = jax.Array


def _level_of(n: Array) -> Array:
    """Tree level of node index n>=1 (vectorized floor(log2(n)))."""
    return 31 - lax.clz(n.astype(jnp.int32))


@functools.lru_cache(maxsize=None)
def _bunch_layers(depth: int, bunch_levels: int) -> Tuple[Tuple[int, int, int], ...]:
    """Static bunch layering, bottom-aligned: tuple of
    (root_level, leaf_level, word_offset), top layer first.

    Layer k covers tree levels [root_level, leaf_level]; its words are
    keyed by bunch-root node index (one word per level-root_level node)
    and stored contiguously from word_offset."""
    spans = []
    leaf = depth
    while leaf >= 0:
        root = max(leaf - (bunch_levels - 1), 0)
        spans.append((root, leaf))
        leaf = root - 1
    spans.reverse()  # top-first
    layers = []
    off = 0
    for root, leaf in spans:
        layers.append((root, leaf, off))
        off += 1 << root
    return tuple(layers)


def _ancestor_occ_from(depth: int, occ: Array) -> Array:
    """anc[n] == True iff some strict ancestor of n is (derived) OCC.

    One top-down pass over per-node occupancy booleans — the layout-
    generic form of the paper's T11 occupancy discovery."""
    anc = jnp.zeros(occ.shape, dtype=bool)
    for lev in range(1, depth + 1):
        lo, hi = 1 << lev, 1 << (lev + 1)
        p = anc[lo // 2 : hi // 2] | occ[lo // 2 : hi // 2]
        anc = anc.at[lo:hi].set(jnp.repeat(p, 2))
    return anc


@dataclasses.dataclass(frozen=True)
class Unpacked:
    """One int32 status word per tree node (index = node index).

    The historical device layout and the differential oracle: every
    method reproduces the pre-layout `core/concurrent.py` passes
    word-for-word, so a `TreeConfig` without an explicit layout behaves
    bit-identically to the pre-refactor allocator."""

    name = "unpacked"

    def n_state_words(self, cfg) -> int:
        return 1 << (cfg.depth + 1)

    @property
    def state_dtype(self):
        return jnp.int32

    def empty_tree(self, cfg) -> Array:
        return jnp.zeros(self.n_state_words(cfg), dtype=self.state_dtype)

    # -- derived views -------------------------------------------------
    def allocatable(self, cfg, tree: Array) -> Array:
        """CAS(0 -> BUSY) needs the word to be exactly zero (paper T2)
        and no fully-occupied ancestor may exist (paper T11)."""
        occ = (tree & OCC) != 0
        anc = _ancestor_occ_from(cfg.depth, occ)
        return (tree == 0) & ~anc

    def node_occ_at(self, cfg, tree: Array, nodes: Array) -> Array:
        return (tree[nodes] & OCC) != 0

    # -- merged alloc commit (paper T2 + T6-T18, all winners at once) --
    def commit_allocs(self, cfg, tree: Array, win_mask: Array):
        """Write BUSY into every winner's word, then one merged
        bottom-up climb: branch-occupancy ORs of all winners' paths
        applied level by level.  Returns (tree, merged_writes)."""
        tree = jnp.where(win_mask, BUSY, tree)
        marked = win_mask
        merged = jnp.int32(0)
        for lev in range(cfg.depth, cfg.max_level, -1):
            lo, hi = 1 << lev, 1 << (lev + 1)
            pair = marked[lo:hi].reshape(-1, 2)
            left_m, right_m = pair[:, 0], pair[:, 1]
            or_mask = jnp.where(left_m, OCC_LEFT, 0) | jnp.where(
                right_m, OCC_RIGHT, 0
            )
            clear_mask = jnp.where(left_m, COAL_LEFT, 0) | jnp.where(
                right_m, COAL_RIGHT, 0
            )
            plo, phi = lo // 2, hi // 2
            pv = tree[plo:phi]
            tree = tree.at[plo:phi].set((pv | or_mask) & ~clear_mask)
            touched = left_m | right_m
            marked = marked.at[plo:phi].set(marked[plo:phi] | touched)
            merged = merged + touched.sum(dtype=jnp.int32)
        merged = merged + win_mask.sum(dtype=jnp.int32)
        return tree, merged

    # -- merged release (batch FREENODE + UNMARK) ----------------------
    def apply_frees(self, cfg, tree: Array, freed_mask: Array):
        """Phase 1 clears every released node word at once (F19); phase
        2 is one bottom-up sweep re-deriving branch occupancy along
        touched paths (the fixed point of every sequential climb order).
        Returns (tree, merged_writes)."""
        merged = freed_mask.sum(dtype=jnp.int32)
        tree = jnp.where(freed_mask, 0, tree)

        sub_occ = (tree & OCC) != 0   # bottom-up: sub-tree still reserved?
        touched = freed_mask          # bottom-up: some climb passes through
        for lev in range(cfg.depth - 1, cfg.max_level - 1, -1):
            lo, hi = 1 << lev, 1 << (lev + 1)
            c_occ = sub_occ[2 * lo : 2 * hi].reshape(-1, 2)
            c_tch = touched[2 * lo : 2 * hi].reshape(-1, 2)
            any_tch = c_tch[:, 0] | c_tch[:, 1]
            pv = tree[lo:hi]
            derived = jnp.where(c_occ[:, 0], OCC_LEFT, 0) | jnp.where(
                c_occ[:, 1], OCC_RIGHT, 0
            )
            own_occ = (pv & OCC) != 0
            nv = jnp.where(any_tch & ~own_occ, derived, pv)
            tree = tree.at[lo:hi].set(nv)
            merged = merged + (nv != pv).sum(dtype=jnp.int32)
            sub_occ = sub_occ.at[lo:hi].set(own_occ | c_occ[:, 0] | c_occ[:, 1])
            # OR, not overwrite: an interior freed node has untouched
            # children but must still propagate its release upward.
            touched = touched.at[lo:hi].set(touched[lo:hi] | any_tch)
        return tree, merged

    # -- the paper's per-operation RMW cost model (Fig. 7) -------------
    def alloc_logical_rmws(self, cfg, win: Array, levels: Array) -> Array:
        """Run-alone sequential cost: one CAS for the node word plus one
        per climbed level (T6-T18)."""
        return win.sum(dtype=jnp.int32) + jnp.where(
            win, levels - cfg.max_level, 0
        ).sum(dtype=jnp.int32)

    def free_logical_rmws(
        self, cfg, tree: Array, tgt: Array, valid: Array
    ) -> Array:
        """Per-free run-alone RMW count of the sequential release: the
        FREENODE climb CASes one word per level until the first ancestor
        whose buddy branch is occupied, UNMARK re-CASes the same
        segment, plus the one plain write of F19 — i.e. 2*climb + 1 per
        free, evaluated against the pre-round tree."""
        ub = cfg.max_level
        cur = jnp.where(valid, tgt, 1)
        climb = jnp.zeros(tgt.shape, jnp.int32)
        stopped = ~valid
        for _ in range(cfg.depth - ub):
            in_climb = ~stopped & (_level_of(cur) > ub)
            parent = cur >> 1
            pv = tree[parent]
            climb = climb + jnp.where(in_climb, 1, 0)
            buddy_occ = (pv & (OCC_RIGHT << (cur & 1))) != 0
            stopped = stopped | ~in_climb | buddy_occ
            cur = parent
        return jnp.where(valid, 2 * climb + 1, 0).sum(dtype=jnp.int32)


@dataclasses.dataclass(frozen=True)
class BunchPacked:
    """Packed-bunch device layout (§III-D, 32-bit variant): B tree
    levels per uint32 word, only bunch leaves materialized (5 bits per
    leaf slot), interior state derived per Fig. 6 within the word.

    All derived views are per-node boolean *scratch* arrays in the
    unpacked node-index space — cheap VPU work recomputed per round;
    only the packed words are persistent state (and the only thing the
    merged-write counters charge for)."""

    bunch_levels: int = 3
    word_bits: int = 32

    name = "bunch-packed"

    def __post_init__(self):
        leaves = 1 << (self.bunch_levels - 1)
        if leaves * STATUS_BITS > self.word_bits:
            raise ValueError(
                f"bunch of {self.bunch_levels} levels needs "
                f"{leaves * STATUS_BITS} bits > word size {self.word_bits}"
            )

    def layers(self, cfg) -> Tuple[Tuple[int, int, int], ...]:
        return _bunch_layers(cfg.depth, self.bunch_levels)

    def n_state_words(self, cfg) -> int:
        root, _, off = self.layers(cfg)[-1]
        return off + (1 << root)

    @property
    def state_dtype(self):
        return jnp.uint32

    def empty_tree(self, cfg) -> Array:
        return jnp.zeros(self.n_state_words(cfg), dtype=self.state_dtype)

    # ------------------------------------------------------------------
    # Derived per-node views (Fig. 6 within each word)
    # ------------------------------------------------------------------
    def _slot_status(self, cfg, state: Array, layer) -> Array:
        """int32[2^leaf_level] leaf-slot statuses of one layer, node
        order (slot s of root r is node (r << (F-L)) + s)."""
        L, F, off = layer
        n_roots, n_slots = 1 << L, 1 << (F - L)
        words = state[off : off + n_roots]
        shifts = jnp.arange(n_slots, dtype=jnp.uint32) * STATUS_BITS
        slots = (words[:, None] >> shifts[None, :]) & jnp.uint32(STATUS_MASK)
        return slots.astype(jnp.int32).reshape(-1)

    def derive(self, cfg, state: Array):
        """(any5, occ, busy) bool[cfg.n_words] node-indexed views:
        any5 = some status bit in the node's leaf range (the packed
        analogue of word != 0), occ = AND of the range's OCC bits
        (derived reservation), busy = OR of the range's busy bits
        (sub-tree holds a reserved node)."""
        n = 1 << (cfg.depth + 1)
        any5 = jnp.zeros(n, dtype=bool)
        occ = jnp.zeros(n, dtype=bool)
        busy = jnp.zeros(n, dtype=bool)
        for layer in self.layers(cfg):
            L, F, _ = layer
            st = self._slot_status(cfg, state, layer)
            a = st != 0
            o = (st & OCC) != 0
            b = (st & BUSY) != 0
            for lev in range(F, L - 1, -1):
                lo, hi = 1 << lev, 1 << (lev + 1)
                any5 = any5.at[lo:hi].set(a)
                occ = occ.at[lo:hi].set(o)
                busy = busy.at[lo:hi].set(b)
                if lev > L:
                    a = a.reshape(-1, 2).any(axis=1)
                    o = o.reshape(-1, 2).all(axis=1)
                    b = b.reshape(-1, 2).any(axis=1)
        return any5, occ, busy

    def allocatable(self, cfg, state: Array) -> Array:
        """Derived T2+T11: the node's whole leaf range is bit-free and
        no (derived-)occupied strict ancestor exists."""
        any5, occ, _ = self.derive(cfg, state)
        anc = _ancestor_occ_from(cfg.depth, occ)
        return ~any5 & ~anc

    def node_occ_at(self, cfg, state: Array, nodes: Array) -> Array:
        _, occ, _ = self.derive(cfg, state)
        return occ[nodes]

    # ------------------------------------------------------------------
    # Merged alloc commit: range CAS + cross-word climb, per word
    # ------------------------------------------------------------------
    def commit_allocs(self, cfg, state: Array, win_mask: Array):
        """All winners at once: each bunch word ORs in (a) BUSY over the
        leaf ranges of winners inside the bunch (the §III-D range CAS)
        and (b) OCC_LEFT/OCC_RIGHT cross marks on leaf slots whose child
        bunches contain a winner (the one-RMW-per-B-levels climb).
        Interior bits re-derive from the leaves (Fig. 6), so the climb
        only crosses words at bunch roots.  merged_writes counts packed
        words whose value changed."""
        depth = cfg.depth
        # swin[n]: a winner lives in subtree(n) (including n itself)
        swin = win_mask
        for lev in range(depth - 1, -1, -1):
            lo, hi = 1 << lev, 1 << (lev + 1)
            child = swin[2 * lo : 2 * hi].reshape(-1, 2)
            swin = swin.at[lo:hi].set(
                swin[lo:hi] | child[:, 0] | child[:, 1]
            )
        merged = jnp.int32(0)
        for L, F, off in self.layers(cfg):
            n_roots, n_slots = 1 << L, 1 << (F - L)
            # winners at-or-above each leaf slot *within this layer*
            # (winners above the layer never touch it: their sub-bunches
            # stay all-zero)
            cl = win_mask[1 << L : 1 << (L + 1)]
            for lev in range(L + 1, F + 1):
                cl = jnp.repeat(cl, 2) | win_mask[1 << lev : 1 << (lev + 1)]
            if F < depth:
                sub = swin[1 << (F + 1) : 1 << (F + 2)].reshape(-1, 2)
                bl, br = sub[:, 0], sub[:, 1]
            else:
                bl = br = jnp.zeros(1 << F, dtype=bool)
            slot_or = (
                jnp.where(cl, jnp.uint32(BUSY), jnp.uint32(0))
                | jnp.where(bl, jnp.uint32(OCC_LEFT), jnp.uint32(0))
                | jnp.where(br, jnp.uint32(OCC_RIGHT), jnp.uint32(0))
            )
            shifts = jnp.arange(n_slots, dtype=jnp.uint32) * STATUS_BITS
            word_or = (
                slot_or.reshape(n_roots, n_slots) << shifts[None, :]
            ).sum(axis=1, dtype=jnp.uint32)
            old = state[off : off + n_roots]
            new = old | word_or
            merged = merged + (new != old).sum(dtype=jnp.int32)
            state = state.at[off : off + n_roots].set(new)
        return state, merged

    # ------------------------------------------------------------------
    # Merged release: clear ranges, rebuild the canonical derived state
    # ------------------------------------------------------------------
    def apply_frees(self, cfg, state: Array, freed_mask: Array):
        """Clear every freed node's leaf range (the §III-D one-word F19)
        then one bottom-up sweep over *layers*: within each word the
        interior bits re-derive from the surviving leaf occupancy
        (Fig. 6), and the sweep crosses words only at bunch roots, where
        each leaf slot's OCC_LEFT/OCC_RIGHT re-derive from its child
        bunches' occupancy — the packed form of `free_round` phase 2's
        fixed-point OR.  merged_writes counts packed words changed."""
        depth = cfg.depth
        layers = self.layers(cfg)
        # per-layer surviving slot occupancy after clearing freed ranges
        in_occ_new = {}
        for layer in layers:
            L, F, off = layer
            st = self._slot_status(cfg, state, layer)
            occ_leaf = (st & OCC) != 0
            fl = freed_mask[1 << L : 1 << (L + 1)]
            for lev in range(L + 1, F + 1):
                fl = jnp.repeat(fl, 2) | freed_mask[1 << lev : 1 << (lev + 1)]
            in_occ_new[off] = occ_leaf & ~fl
        # bottom-up canonical rebuild (identity on untouched words)
        merged = jnp.int32(0)
        bocc = None  # child-layer bunch occupancy, keyed by bunch root
        for L, F, off in reversed(layers):
            n_roots, n_slots = 1 << L, 1 << (F - L)
            in_occ = in_occ_new[off]
            if F < depth:
                sub = bocc.reshape(-1, 2)
                bl, br = sub[:, 0], sub[:, 1]
            else:
                bl = br = jnp.zeros(1 << F, dtype=bool)
            slot_val = (
                jnp.where(in_occ, jnp.uint32(BUSY), jnp.uint32(0))
                | jnp.where(bl, jnp.uint32(OCC_LEFT), jnp.uint32(0))
                | jnp.where(br, jnp.uint32(OCC_RIGHT), jnp.uint32(0))
            )
            shifts = jnp.arange(n_slots, dtype=jnp.uint32) * STATUS_BITS
            word_new = (
                slot_val.reshape(n_roots, n_slots) << shifts[None, :]
            ).sum(axis=1, dtype=jnp.uint32)
            old = state[off : off + n_roots]
            merged = merged + (word_new != old).sum(dtype=jnp.int32)
            state = state.at[off : off + n_roots].set(word_new)
            slot_busy = in_occ | bl | br
            bocc = slot_busy.reshape(n_roots, n_slots).any(axis=1)
        return state, merged

    # ------------------------------------------------------------------
    # §III-D word-RMW cost model: one RMW per bunch, not per level
    # ------------------------------------------------------------------
    # NOTE: these build their level predicates from static Python loops
    # over the bunch-root levels (scalar compares, no constant arrays) so
    # the shared round bodies stay Pallas-traceable — pallas_call rejects
    # kernels that capture materialized jnp constants.

    def _crosses_of(self, cfg, levels: Array) -> Array:
        """Per-entry count of bunch-root levels in (max_level, level] —
        the cross-word RMWs of a run-alone climb from that level."""
        roots = {L for (L, _, _) in self.layers(cfg)}
        crosses = jnp.zeros(levels.shape, jnp.int32)
        for r in sorted(roots):
            if cfg.max_level < r:
                crosses = crosses + (levels >= r).astype(jnp.int32)
        return crosses

    def _is_root_level(self, cfg, levels: Array) -> Array:
        roots = {L for (L, _, _) in self.layers(cfg)}
        hit = jnp.zeros(levels.shape, bool)
        for r in sorted(roots):
            hit = hit | (levels == r)
        return hit

    def alloc_logical_rmws(self, cfg, win: Array, levels: Array) -> Array:
        """Run-alone §III-D cost: one range CAS in the node's own word
        plus one cross-leaf RMW per ancestor bunch."""
        lv = jnp.clip(levels, 0, cfg.depth)
        return jnp.where(win, 1 + self._crosses_of(cfg, lv), 0).sum(
            dtype=jnp.int32
        )

    def free_logical_rmws(
        self, cfg, state: Array, tgt: Array, valid: Array
    ) -> Array:
        """Run-alone §III-D release cost: the FREENODE walk takes its
        buddy-occupancy decisions at *every* level (derived within
        words) but RMWs only at cross-bunch boundaries; UNMARK re-walks
        the same segment, plus the one range-clear word op — i.e.
        2*cross_climb + 1 per free, against the pre-round state."""
        _, _, busy = self.derive(cfg, state)
        ub = cfg.max_level
        cur = jnp.where(valid, tgt, 1)
        climb = jnp.zeros(tgt.shape, jnp.int32)
        stopped = ~valid
        for _ in range(cfg.depth - ub):
            lev = _level_of(cur)
            in_climb = ~stopped & (lev > ub)
            crossing = in_climb & self._is_root_level(
                cfg, jnp.clip(lev, 0, cfg.depth)
            )
            climb = climb + jnp.where(crossing, 1, 0)
            buddy = jnp.where(cur > 1, cur ^ 1, 0)
            buddy_occ = busy[buddy]
            stopped = stopped | ~in_climb | buddy_occ
            cur = cur >> 1
        return jnp.where(valid, 2 * climb + 1, 0).sum(dtype=jnp.int32)


# The two canonical layout instances: default (oracle) and packed.
UNPACKED = Unpacked()
BUNCH_PACKED = BunchPacked()

TreeLayout = Unpacked | BunchPacked
