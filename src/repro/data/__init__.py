"""data substrate."""
