"""Deterministic synthetic LM data pipeline.

Training on real corpora is out of scope of the paper; the framework
still provides a production-shaped data path: stateless deterministic
sample generation (resumable from any step without replay), per-host
sharding (each process materializes only its slice of the global
batch), background prefetch, and device placement with the global-batch
sharding.

Tokens are a order-2 Markov-ish stream derived from a splitmix-style
integer hash, so the tiny-LM example has actual learnable structure
(next token depends on the previous two) while remaining fully
reproducible.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np

Array = jax.Array


def _splitmix(x: np.ndarray) -> np.ndarray:
    x = (x + np.uint64(0x9E3779B97F4A7C15)).astype(np.uint64)
    z = x
    z = (z ^ (z >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    z = (z ^ (z >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return z ^ (z >> np.uint64(31))


class SyntheticLM:
    """Deterministic, seekable synthetic token stream."""

    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        seed: int = 0,
        process_index: int = 0,
        process_count: int = 1,
        structured: bool = True,
    ) -> None:
        assert global_batch % process_count == 0
        self.vocab_size = vocab_size
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.local_batch = global_batch // process_count
        self.seed = seed
        self.process_index = process_index
        self.structured = structured

    def batch_at(self, step: int) -> dict:
        """Local slice of the global batch for `step` (stateless/seekable)."""
        b0 = self.process_index * self.local_batch
        rows = np.arange(b0, b0 + self.local_batch, dtype=np.uint64)
        cols = np.arange(self.seq_len + 1, dtype=np.uint64)
        base = (
            np.uint64(self.seed) * np.uint64(0x100000001B3)
            + np.uint64(step) * np.uint64(0x9E3779B1)
        )
        grid = _splitmix(base + rows[:, None] * np.uint64(1 << 20) + cols)
        toks = (grid % np.uint64(self.vocab_size)).astype(np.int32)
        if self.structured:
            # next token correlated with the previous two -> learnable
            toks[:, 2:] = (
                toks[:, 2:] // 4 * 4 + (toks[:, :-2] + toks[:, 1:-1]) % 4
            ) % self.vocab_size
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of a (possibly device-placing) iterator."""

    def __init__(self, it: Iterator, depth: int = 2, place=None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._place = place or (lambda x: x)
        self._it = it
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for item in self._it:
                self._q.put(self._place(item))
        except BaseException as e:  # surfaced on next()
            self._err = e
            self._q.put(None)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def place_on_mesh(batch: dict, mesh, dp_axes) -> dict:
    """Device-put a host batch with the global-batch sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec = lambda nd: P(dp_axes if len(dp_axes) > 1 else dp_axes[0],
                        *([None] * (nd - 1)))
    return {
        k: jax.device_put(v, NamedSharding(mesh, spec(v.ndim)))
        for k, v in batch.items()
    }
