"""Attention: GQA with RoPE; chunked (flash-style) jnp implementation.

Two compute paths:

  * `chunked_attention` — pure-jnp online-softmax over KV chunks via
    lax.scan.  This is the *memory-safe* path used under jit for
    training and long prefill (peak logits memory S x chunk instead of
    S x S) and the path that lowers in the CPU dry-run.  Supports a
    *traced* sliding-window size, which lets a scanned stack of layers
    carry a per-layer window array (gemma2 local/global alternation)
    through one scan body.
  * `repro.kernels.ops.flash_attention` — the fused Pallas kernel
    (static variant selection), picked when the backend can lower it
    and the window is static.

Decode attention over a full in-graph KV cache is plain dense attention
on [B, S] logits (one query token), with sharding constraints leaving
XLA's SPMD partitioner to produce the flash-decode partial-softmax
combine when the cache is sequence-sharded.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import apply_rope

Array = jax.Array
NEG_INF = -1e30


def init_attention(
    key: Array, d: int, n_heads: int, n_kv_heads: int, head_dim: int
) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d ** -0.5
    so = (n_heads * head_dim) ** -0.5
    return {
        "wq": jax.random.normal(kq, (d, n_heads * head_dim), jnp.float32) * s,
        "wk": jax.random.normal(kk, (d, n_kv_heads * head_dim), jnp.float32) * s,
        "wv": jax.random.normal(kv, (d, n_kv_heads * head_dim), jnp.float32) * s,
        "wo": jax.random.normal(ko, (n_heads * head_dim, d), jnp.float32) * so,
    }


def chunked_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: Optional[Array] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    chunk: int = 1024,
    q_offset: int = 0,
) -> Array:
    """Online-softmax attention over KV chunks.

    q: [B, Sq, Hq, D]; k, v: [B, Sk, Hkv, D]  (seq-major layout).
    `window` may be a traced scalar (<=0 means no window).
    Returns [B, Sq, Hq, D].
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    chunk = min(chunk, Sk)
    # Pad KV to a chunk multiple (masked out below).
    pad = (-Sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (Sk + pad) // chunk
    kc = k.reshape(B, n_chunks, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)

    # GQA via grouped einsum — no materialized repeat, no f32 cast of
    # K/V (bf16 on the wire, f32 MXU accumulation): the HLO-roofline
    # analysis showed cast+repeat dominating decode memory traffic.
    qg = q.reshape(B, Sq, Hkv, group, D)
    rows = q_offset + jnp.arange(Sq)
    win = None if window is None else jnp.asarray(window, jnp.int32)

    def step(carry, xs):
        m, l, acc = carry  # [B,Hkv,G,Sq], ..., [B,Hkv,G,Sq,D]
        ci, kch, vch = xs  # kch/vch: [B, chunk, Hkv, D]
        cols = ci * chunk + jnp.arange(chunk)
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg, kch,
            preferred_element_type=jnp.float32,
        ) * scale
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = (cols < Sk)[None, :]
        if causal:
            mask = mask & (cols[None, :] <= rows[:, None])
        if win is not None:
            mask = mask & (
                (win <= 0) | (cols[None, :] > rows[:, None] - win)
            )
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        alpha = jnp.where(m_new == NEG_INF, 1.0, alpha)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where((m_new == NEG_INF)[..., None], 0.0, p)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vch.dtype), vch,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((B, Hkv, group, Sq), NEG_INF, jnp.float32),
        jnp.zeros((B, Hkv, group, Sq), jnp.float32),
        jnp.zeros((B, Hkv, group, Sq, D), jnp.float32),
    )
    (m, l, acc), _ = lax.scan(
        step, init, (jnp.arange(n_chunks), kc, vc)
    )
    norm = jnp.where(l == 0.0, 1.0, l)
    out = acc / norm[..., None]  # [B,Hkv,G,Sq,D]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D)
    return out.astype(q.dtype)


def decode_attention(
    q: Array,
    k_cache: Array,
    v_cache: Array,
    context_len: Array,
    *,
    window: Optional[Array] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> Array:
    """One-token decode over a dense cache.

    q: [B, 1, Hq, D]; k_cache/v_cache: [B, S, Hkv, D]; context_len: [] or [B].
    """
    B, S, Hkv, D = k_cache.shape
    Hq = q.shape[2]
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    # grouped einsum: bf16 cache on the wire, f32 accumulation — never
    # materialize an f32 or head-repeated copy of the cache
    qg = q.reshape(B, -1, Hkv, group, D)
    s = jnp.einsum(
        "bqhgd,bkhd->bhgqk", qg, k_cache, preferred_element_type=jnp.float32
    ) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(S)[None, :]
    ctx = jnp.broadcast_to(jnp.asarray(context_len), (B,))[:, None]
    mask = pos < ctx
    if window is not None:
        win = jnp.asarray(window, jnp.int32)
        mask = mask & ((win <= 0) | (pos > ctx - 1 - win))
    s = jnp.where(mask[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgqk,bkhd->bqhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, -1, Hq, D).astype(q.dtype)


def attention_block(
    p: dict,
    x: Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    causal: bool = True,
    window: Optional[Array] = None,
    softcap: Optional[float] = None,
    positions: Optional[Array] = None,
    chunk: int = 1024,
) -> Array:
    """Full GQA block (projections + RoPE + chunked attention).

    x: [B, S, d] -> [B, S, d].
    """
    B, S, d = x.shape
    dtype = x.dtype
    q = (x @ p["wq"].astype(dtype)).reshape(B, S, n_heads, head_dim)
    k = (x @ p["wk"].astype(dtype)).reshape(B, S, n_kv_heads, head_dim)
    v = (x @ p["wv"].astype(dtype)).reshape(B, S, n_kv_heads, head_dim)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    out = chunked_attention(
        q, k, v, causal=causal, window=window, softcap=softcap, chunk=chunk
    )
    return out.reshape(B, S, n_heads * head_dim) @ p["wo"].astype(dtype)


def attention_decode_block(
    p: dict,
    x: Array,
    k_cache: Array,
    v_cache: Array,
    pos: Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    window: Optional[Array] = None,
    softcap: Optional[float] = None,
) -> Tuple[Array, Array, Array]:
    """Decode step: x [B, 1, d], cache [B, S, Hkv, D], pos [] scalar.

    Returns (out [B,1,d], new_k_cache, new_v_cache)."""
    B, _, d = x.shape
    dtype = x.dtype
    q = (x @ p["wq"].astype(dtype)).reshape(B, 1, n_heads, head_dim)
    k = (x @ p["wk"].astype(dtype)).reshape(B, 1, n_kv_heads, head_dim)
    v = (x @ p["wv"].astype(dtype)).reshape(B, 1, n_kv_heads, head_dim)
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    k_cache = lax.dynamic_update_slice(k_cache, k, (0, pos, 0, 0))
    v_cache = lax.dynamic_update_slice(v_cache, v, (0, pos, 0, 0))
    out = decode_attention(
        q, k_cache, v_cache, pos + 1, window=window, softcap=softcap
    )
    out = out.reshape(B, 1, n_heads * head_dim) @ p["wo"].astype(dtype)
    return out, k_cache, v_cache


def attention_decode_stacked(
    p: dict,
    x: Array,
    k_all: Array,
    v_all: Array,
    layer: Array,
    pos: Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    window: Optional[Array] = None,
    softcap: Optional[float] = None,
) -> Tuple[Array, Array, Array]:
    """Decode step against a stacked cache [L, B, S, Hkv, D].

    The new token's K/V is written *directly* into the stacked carry
    (a [1,B,1,Hkv,D] dynamic-update-slice — the roofline HLO walk showed
    that slicing a layer out and writing the whole [B,S,Hkv,D] slice
    back makes XLA materialize full-cache copies per step); the
    attention read then slices the updated layer.
    """
    B, _, d = x.shape
    dtype = x.dtype
    q = (x @ p["wq"].astype(dtype)).reshape(B, 1, n_heads, head_dim)
    k = (x @ p["wk"].astype(dtype)).reshape(B, 1, n_kv_heads, head_dim)
    v = (x @ p["wv"].astype(dtype)).reshape(B, 1, n_kv_heads, head_dim)
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    zero = jnp.zeros((), jnp.int32)
    k_all = lax.dynamic_update_slice(
        k_all, k[None], (layer, zero, pos, zero, zero)
    )
    v_all = lax.dynamic_update_slice(
        v_all, v[None], (layer, zero, pos, zero, zero)
    )
    kc = lax.dynamic_index_in_dim(k_all, layer, 0, keepdims=False)
    vc = lax.dynamic_index_in_dim(v_all, layer, 0, keepdims=False)
    out = decode_attention(q, kc, vc, pos + 1, window=window, softcap=softcap)
    out = out.reshape(B, 1, n_heads * head_dim) @ p["wo"].astype(dtype)
    return out, k_all, v_all
