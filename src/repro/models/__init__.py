"""Model zoo: functional decoder backbones for all assigned architectures."""

from repro.models.transformer import (  # noqa: F401
    decode_step,
    forward,
    init_cache,
    init_params,
    prefill,
    train_loss,
)
from repro.models.sharding import MeshAxes, param_specs  # noqa: F401
