"""Mixture-of-Experts layer with capacity-based dispatch (EP-shardable).

GShard/Switch-style: top-k routing, per-expert capacity buffers, one-hot
position assignment via cumulative sums, scatter dispatch / gather
combine.  The expert dimension of the buffers and weights is sharded on
the 'model' mesh axis (expert parallelism); the token->expert scatter
then lowers to an all-to-all under SPMD partitioning.

Dispatch locality (`n_blocks`): positions-in-expert computed with one
global cumsum over tokens serialize the token dimension — under SPMD
the compiler must all-gather the (T x E) running counts per layer,
which the dry-run roofline showed dominating the collective term
(~8.6 GB/layer at 32k prefill).  With `n_blocks` > 1 the cumsum runs
within token blocks aligned to the data shards (GShard's per-device
expert capacity): no cross-shard dependency, identical drop semantics
per block.  n_blocks=1 reproduces the global-capacity baseline.

Aux losses: load-balancing (Switch) + router z-loss.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.sharding import MeshAxes, act_spec, constrain

Array = jax.Array


def init_moe(key: Array, d: int, ff: int, n_experts: int) -> dict:
    kr, kg, ki, ko = jax.random.split(key, 4)
    s_in = d ** -0.5
    s_out = ff ** -0.5
    return {
        "router": jax.random.normal(kr, (d, n_experts), jnp.float32) * s_in,
        "w_gate": jax.random.normal(kg, (n_experts, d, ff), jnp.float32) * s_in,
        "w_in": jax.random.normal(ki, (n_experts, d, ff), jnp.float32) * s_in,
        "w_out": jax.random.normal(ko, (n_experts, ff, d), jnp.float32) * s_out,
    }


def apply_moe(
    p: dict,
    x: Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    dtype=jnp.bfloat16,
    n_blocks: int = 1,
    axes: Optional[MeshAxes] = None,
    dispatch: str = "scatter",
    group_size: int = 2048,
) -> Tuple[Array, Array]:
    """x: [B, S, d] -> (y: [B, S, d], aux_loss: scalar).

    dispatch="einsum" selects the GShard-style one-hot-matmul dispatch:
    the roofline HLO walk showed XLA lowering the cross-shard dispatch
    *scatter* as full-buffer f32 all-reduces (~1.7 GB x 4 per MoE layer
    at train_4k scale); the einsum formulation replaces them with MXU
    matmuls whose collective footprint is just the [G,E,C,d] buffer
    reshard — trading ~2x small matmul flops for the dominant
    collective term (docs/experiments.md §Perf, llama4/phi3.5 cells).
    """
    if dispatch == "einsum":
        return _apply_moe_einsum(
            p, x, top_k=top_k, capacity_factor=capacity_factor,
            dtype=dtype, axes=axes, group_size=group_size,
        )
    B, S, d = x.shape
    E = p["router"].shape[1]
    T = B * S
    if T % n_blocks != 0:
        n_blocks = 1
    Tb = T // n_blocks
    xf = x.reshape(T, d)

    router_logits = xf.astype(jnp.float32) @ p["router"]  # [T, E]
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # [T, K]
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9, None
    )

    # Load-balancing loss (Switch eq. 4) + router z-loss.
    me = probs.mean(axis=0)
    ce = jnp.zeros(E).at[expert_idx[:, 0]].add(1.0) / T
    aux = E * jnp.sum(me * ce)
    aux = aux + 1e-3 * jnp.square(jax.nn.logsumexp(router_logits, -1)).mean()

    cap_b = max(int(capacity_factor * Tb * top_k / E), 1)
    capacity = cap_b * n_blocks  # per-expert total slots
    # Position of each (token, slot) within its expert: computed per
    # token block (block-local cumsum, no cross-shard dependency), then
    # mapped to the expert's global slot range block*cap_b + pos.
    # n_blocks=1 is exactly the global formulation.
    e_blk = expert_idx.reshape(n_blocks, Tb, top_k).transpose(0, 2, 1)
    flat = e_blk.reshape(n_blocks, top_k * Tb)  # [NB, K*Tb]
    onehot = jax.nn.one_hot(flat, E, dtype=jnp.int32)  # [NB, K*Tb, E]
    pos_flat = (jnp.cumsum(onehot, axis=1) - 1) * onehot
    pos_b = pos_flat.sum(-1).reshape(n_blocks, top_k, Tb)  # [NB, K, Tb]
    keep_b = pos_b < cap_b
    blk = jnp.arange(n_blocks, dtype=jnp.int32)[:, None, None]
    slot_b = jnp.where(keep_b, pos_b + blk * cap_b, capacity)
    # back to the proven slot-major [K, T] scatter layout
    keep = keep_b.transpose(1, 0, 2).reshape(top_k, T)
    slot = slot_b.transpose(1, 0, 2).reshape(top_k, T)
    e_kt = e_blk.transpose(1, 0, 2).reshape(top_k, T)

    # Dispatch: scatter tokens into [E, capacity(+1 overflow), d].
    buf = jnp.zeros((E, capacity + 1, d), dtype=dtype)
    if axes is not None:
        buf = constrain(buf, axes, act_spec(axes, "tp", None, None))
    xe = jnp.broadcast_to(xf.astype(dtype), (top_k, T, d))
    buf = buf.at[e_kt, slot].set(xe)
    buf = buf[:, :capacity]  # drop overflow slot

    # Expert FFN (SwiGLU) — E dim shardable on 'model' (EP).
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(dtype))
    h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"].astype(dtype))
    act = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * h
    out_e = jnp.einsum("ecf,efd->ecd", act, p["w_out"].astype(dtype))
    out_e = jnp.pad(out_e, ((0, 0), (0, 1), (0, 0)))

    # Combine: gather each (token, slot) result, weight by gate.
    gathered = out_e[e_kt, slot]  # [K, T, d]
    w = (gate_vals.transpose(1, 0) * keep)[..., None].astype(jnp.float32)
    y = (gathered.astype(jnp.float32) * w).sum(0)
    return y.reshape(B, S, d).astype(x.dtype), aux


def _apply_moe_einsum(
    p: dict,
    x: Array,
    *,
    top_k: int,
    capacity_factor: float,
    dtype,
    axes: Optional[MeshAxes],
    group_size: int,
) -> Tuple[Array, Array]:
    """GShard-style dispatch: one-hot (token -> expert,slot) tensors
    contracted with matmuls; no scatter/gather anywhere.

    Tokens are split into G groups of Sg (groups align with the data
    shards); capacity is per (group, expert).  group_size == T
    reproduces the global-capacity semantics of the scatter path
    exactly (same slot-major priority)."""
    B, S, d = x.shape
    E = p["router"].shape[1]
    T = B * S
    G = max(T // group_size, 1)
    while T % G:
        G -= 1
    Sg = T // G
    xg = x.reshape(G, Sg, d)

    router_logits = xg.astype(jnp.float32) @ p["router"]  # [G, Sg, E]
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # [G, Sg, K]
    gate_vals = gate_vals / jnp.clip(
        gate_vals.sum(-1, keepdims=True), 1e-9, None
    )

    me = probs.mean(axis=(0, 1))
    ce = jnp.zeros(E).at[expert_idx[..., 0].reshape(-1)].add(1.0) / T
    aux = E * jnp.sum(me * ce)
    aux = aux + 1e-3 * jnp.square(jax.nn.logsumexp(router_logits, -1)).mean()

    C = max(int(capacity_factor * Sg * top_k / E), 1)
    # slot-major positions within (group, expert)
    e_sm = expert_idx.transpose(0, 2, 1)  # [G, K, Sg]
    oh = jax.nn.one_hot(e_sm, E, dtype=jnp.int32)  # [G, K, Sg, E]
    ohf = oh.reshape(G, top_k * Sg, E)
    pos = ((jnp.cumsum(ohf, axis=1) - 1) * ohf).sum(-1)
    pos = pos.reshape(G, top_k, Sg)
    keep = pos < C
    # one_hot of an out-of-range index is all-zeros: dropped tokens
    # vanish from both dispatch and combine automatically
    pos_oh = jax.nn.one_hot(
        jnp.where(keep, pos, C), C, dtype=dtype
    )  # [G, K, Sg, C]

    disp = jnp.einsum(
        "gkse,gksc->gsec", oh.astype(dtype), pos_oh
    )  # [G, Sg, E, C]
    buf = jnp.einsum("gsec,gsd->gecd", disp, xg.astype(dtype))
    if axes is not None:
        buf = constrain(buf, axes, act_spec(axes, "dp", "tp", None, None))

    g = jnp.einsum("gecd,edf->gecf", buf, p["w_gate"].astype(dtype))
    h = jnp.einsum("gecd,edf->gecf", buf, p["w_in"].astype(dtype))
    act = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * h
    out_e = jnp.einsum("gecf,efd->gecd", act, p["w_out"].astype(dtype))

    gates_sm = gate_vals.transpose(0, 2, 1)  # [G, K, Sg] slot-major
    comb = jnp.einsum(
        "gkse,gksc,gks->gsec",
        oh.astype(jnp.float32),
        pos_oh.astype(jnp.float32),
        gates_sm * keep,
    ).astype(dtype)
    y = jnp.einsum("gsec,gecd->gsd", comb, out_e)
    return y.reshape(B, S, d).astype(x.dtype), aux
