"""Shared model layers: norms, RoPE, MLPs, embeddings.

Functional style throughout: `init_*` returns a param pytree (dict of
jnp arrays); `apply` functions are pure.  Params are created in float32
(master weights); compute casts to the config dtype (bf16 on TPU).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array


def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    # gemma-style (1 + scale) parameterization keeps init at identity.
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def init_rms_norm(d: int) -> Array:
    return jnp.zeros((d,), jnp.float32)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    angles = angles[..., None, :]  # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_swiglu(key: Array, d: int, ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = ff ** -0.5
    return {
        "w_gate": jax.random.normal(k1, (d, ff), jnp.float32) * s_in,
        "w_in": jax.random.normal(k2, (d, ff), jnp.float32) * s_in,
        "w_out": jax.random.normal(k3, (ff, d), jnp.float32) * s_out,
    }


def apply_swiglu(p: dict, x: Array, dtype=jnp.bfloat16) -> Array:
    g = x @ p["w_gate"].astype(dtype)
    h = x @ p["w_in"].astype(dtype)
    act = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * h
    return act @ p["w_out"].astype(dtype)


def init_gelu_mlp(key: Array, d: int, ff: int) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w_in": jax.random.normal(k1, (d, ff), jnp.float32) * d ** -0.5,
        "w_out": jax.random.normal(k2, (ff, d), jnp.float32) * ff ** -0.5,
    }


def apply_gelu_mlp(p: dict, x: Array, dtype=jnp.bfloat16) -> Array:
    h = x @ p["w_in"].astype(dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(dtype)
    return h @ p["w_out"].astype(dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def init_embedding(key: Array, vocab: int, d: int) -> Array:
    return jax.random.normal(key, (vocab, d), jnp.float32) * (d ** -0.5)


def embed(table: Array, tokens: Array, dtype=jnp.bfloat16, scale: bool = False):
    x = table.astype(dtype)[tokens]
    if scale:
        x = x * jnp.asarray(table.shape[1] ** 0.5, dtype)
    return x


def logits(
    x: Array,
    table: Array,
    softcap: Optional[float] = None,
) -> Array:
    """LM head (tied or untied table [V, d]); returns fp32 logits."""
    out = jnp.einsum("...d,vd->...v", x.astype(jnp.float32), table.astype(jnp.float32))
    if softcap:
        out = softcap * jnp.tanh(out / softcap)
    return out


def cross_entropy(
    lg: Array, labels: Array, z_loss: float = 1e-4
) -> Array:
    """Mean token cross-entropy with an optional z-loss regularizer."""
    lse = jax.nn.logsumexp(lg, axis=-1)
    ll = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    loss = (lse - ll).mean()
    if z_loss:
        loss = loss + z_loss * jnp.square(lse).mean()
    return loss
