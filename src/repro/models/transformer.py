"""Scan-over-layers decoder backbone for all ten assigned architectures.

One backbone, four family bodies:

  dense/moe/vlm/audio — GQA attention + SwiGLU-or-MoE, with a per-layer
      sliding-window array threaded through one scan body (this is how
      gemma2's local/global alternation lives inside a single scanned
      body: the window size is a traced scalar in `chunked_attention`).
  hybrid (zamba2)     — groups of `attn_every` Mamba2 mixers followed by
      one *shared* attention block (shared parameters, per-group KV
      cache sites).
  ssm (rwkv6)         — RWKV6 time-mix/channel-mix blocks.

Layer parameters are stacked on a leading axis and scanned (keeps the
HLO one-layer-sized for the 512-device dry-run compiles); training wraps
the body in jax.checkpoint (full per-layer remat).

Entry points (all pure):
  init_params(cfg, key)
  forward(cfg, params, x, ...)               -> [B, S, d] hidden states
  train_loss(cfg, params, batch, ...)        -> scalar loss
  prefill(cfg, params, batch, max_len, ...)  -> (last-token logits, cache)
  decode_step(cfg, params, cache, tokens, .) -> (logits, cache)
  init_cache(cfg, batch, max_len)            -> cache pytree
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.models import moe as moe_lib
from repro.models import rwkv as rwkv_lib
from repro.models import ssm as ssm_lib
from repro.models.attention import (
    apply_rope,
    attention_block,
    attention_decode_block,
    attention_decode_stacked,
    chunked_attention,
    init_attention,
)
from repro.models.layers import (
    apply_swiglu,
    cross_entropy,
    embed,
    init_embedding,
    init_rms_norm,
    init_swiglu,
    logits as lm_logits,
    rms_norm,
)
from repro.models.sharding import MeshAxes, act_spec, constrain

Array = jax.Array


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_dense_layer(cfg: ArchConfig, key: Array) -> dict:
    ka, km = jax.random.split(key)
    p = {
        "ln1": init_rms_norm(cfg.d_model),
        "ln2": init_rms_norm(cfg.d_model),
        "attn": init_attention(
            ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        ),
    }
    if cfg.post_norm:
        p["ln1_post"] = init_rms_norm(cfg.d_model)
        p["ln2_post"] = init_rms_norm(cfg.d_model)
    if cfg.n_experts:
        p["moe"] = moe_lib.init_moe(km, cfg.d_model, cfg.d_ff, cfg.n_experts)
    else:
        p["mlp"] = init_swiglu(km, cfg.d_model, cfg.d_ff)
    return p


def _init_mamba_layer(cfg: ArchConfig, key: Array) -> dict:
    return {
        "ln": init_rms_norm(cfg.d_model),
        "mamba": ssm_lib.init_mamba2(
            key, cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
        ),
    }


def init_params(cfg: ArchConfig, key: Array) -> dict:
    kemb, klay, kattn, khead = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": init_embedding(kemb, cfg.vocab_size, cfg.d_model),
        "final_norm": init_rms_norm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embedding(khead, cfg.vocab_size, cfg.d_model)
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        keys = jax.random.split(klay, cfg.n_layers)
        params["layers"] = jax.vmap(
            functools.partial(_init_dense_layer, cfg)
        )(keys)
    elif cfg.family == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        keys = jax.random.split(klay, G * cfg.attn_every).reshape(
            G, cfg.attn_every, 2
        )
        params["groups"] = jax.vmap(
            jax.vmap(functools.partial(_init_mamba_layer, cfg))
        )(keys)
        params["shared_attn"] = {
            "ln": init_rms_norm(cfg.d_model),
            "attn": init_attention(
                kattn, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
            ),
        }
    elif cfg.family == "ssm":
        keys = jax.random.split(klay, cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda k: rwkv_lib.init_rwkv6(
                k, cfg.d_model, cfg.d_ff, cfg.rwkv_head_dim
            )
        )(keys)
    else:
        raise ValueError(cfg.family)
    return params


def window_array(cfg: ArchConfig) -> Array:
    """Per-layer sliding window sizes (0 = global), cycled pattern."""
    if not cfg.window_pattern:
        return jnp.zeros((cfg.n_layers,), jnp.int32)
    pat = jnp.asarray(cfg.window_pattern, jnp.int32)
    reps = -(-cfg.n_layers // len(cfg.window_pattern))
    return jnp.tile(pat, reps)[: cfg.n_layers]


# ---------------------------------------------------------------------------
# Forward (training / prefill trunk)
# ---------------------------------------------------------------------------


def _attn_kwargs(cfg: ArchConfig) -> dict:
    return dict(
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.head_dim,
        rope_theta=cfg.rope_theta,
        softcap=cfg.attn_softcap or None,
    )


def _dense_body(cfg: ArchConfig, axes, carry, xs):
    x, aux = carry
    lp, window = xs
    x = constrain(x, axes, act_spec(axes, "dp", None, None))
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    h = attention_block(lp["attn"], h, window=window, **_attn_kwargs(cfg))
    if cfg.post_norm:
        h = rms_norm(h, lp["ln1_post"], cfg.norm_eps)
    x = x + h
    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.n_experts:
        h, a = moe_lib.apply_moe(
            lp["moe"],
            h,
            top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor,
            dtype=h.dtype,
            n_blocks=cfg.dispatch_blocks,
            axes=axes,
            dispatch=cfg.dispatch_mode,
            group_size=cfg.dispatch_group,
        )
        aux = aux + a
    else:
        h = apply_swiglu(lp["mlp"], h, dtype=h.dtype)
    if cfg.post_norm:
        h = rms_norm(h, lp["ln2_post"], cfg.norm_eps)
    return (x + h, aux)


def _hybrid_body(cfg: ArchConfig, axes, shared, carry, xs):
    x, aux = carry
    gp = xs  # leaves [attn_every, ...]
    x = constrain(x, axes, act_spec(axes, "dp", None, None))
    for i in range(cfg.attn_every):
        lp = jax.tree.map(lambda a: a[i], gp)
        h = rms_norm(x, lp["ln"], cfg.norm_eps)
        h = ssm_lib.apply_mamba2(
            lp["mamba"],
            h,
            d_inner=cfg.d_inner,
            d_state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim,
        )
        x = x + h
    h = rms_norm(x, shared["ln"], cfg.norm_eps)
    h = attention_block(shared["attn"], h, window=None, **_attn_kwargs(cfg))
    return (x + h, aux)


def _ssm_body(cfg: ArchConfig, axes, carry, xs):
    x, aux = carry
    lp = xs
    x = constrain(x, axes, act_spec(axes, "dp", None, None))
    x, _ = rwkv_lib.apply_rwkv6(lp, x, head_dim=cfg.rwkv_head_dim)
    return (x, aux)


def forward(
    cfg: ArchConfig,
    params: dict,
    x: Array,
    *,
    axes: Optional[MeshAxes] = None,
    remat: bool = False,
) -> Tuple[Array, Array]:
    """x: [B, S, d] embedded inputs -> (hidden [B, S, d], aux loss)."""
    aux0 = jnp.zeros((), jnp.float32)
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        body = functools.partial(_dense_body, cfg, axes)
        xs = (params["layers"], window_array(cfg))
    elif cfg.family == "hybrid":
        body = functools.partial(_hybrid_body, cfg, axes, params["shared_attn"])
        xs = params["groups"]
    elif cfg.family == "ssm":
        body = functools.partial(_ssm_body, cfg, axes)
        xs = params["layers"]
    else:
        raise ValueError(cfg.family)

    def scan_body(carry, xs_):
        return body(carry, xs_), None

    if remat:
        scan_body = jax.checkpoint(scan_body)
    (x, aux), _ = lax.scan(scan_body, (x, aux0), xs)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux


def _embed_inputs(cfg: ArchConfig, params: dict, batch: dict, dtype) -> Array:
    if cfg.frontend != "none" and "embeds" in batch:
        # Modality frontend is a stub: precomputed frame/patch embeddings.
        return batch["embeds"].astype(dtype)
    return embed(params["embed"], batch["tokens"], dtype, scale=cfg.embed_scale)


def train_loss(
    cfg: ArchConfig,
    params: dict,
    batch: dict,
    *,
    axes: Optional[MeshAxes] = None,
    dtype=jnp.bfloat16,
    remat: bool = True,
) -> Array:
    x = _embed_inputs(cfg, params, batch, dtype)
    x = constrain(x, axes, act_spec(axes, "dp", None, None))
    h, aux = forward(cfg, params, x, axes=axes, remat=remat)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    lg = lm_logits(h, table, cfg.final_softcap or None)
    lg = constrain(lg, axes, act_spec(axes, "dp", None, "tp"))
    return cross_entropy(lg, batch["labels"]) + 0.01 * aux


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------


def init_cache(
    cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16
) -> dict:
    """Cache pytree for decode. 'pos' is the current context length."""
    kv = lambda sites: jnp.zeros(
        (sites, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype
    )
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        cache["k"] = kv(cfg.n_layers)
        cache["v"] = kv(cfg.n_layers)
    elif cfg.family == "hybrid":
        G = cfg.n_layers // cfg.attn_every
        cache["k"] = kv(G)
        cache["v"] = kv(G)
        cache["mamba"] = jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (G, cfg.attn_every) + a.shape
            ),
            ssm_lib.init_mamba2_state(
                batch, cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim, dtype=dtype
            ),
        )
    elif cfg.family == "ssm":
        cache["rwkv"] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape),
            rwkv_lib.init_rwkv6_state(batch, cfg.d_model, cfg.rwkv_head_dim),
        )
    return cache


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def decode_step(
    cfg: ArchConfig,
    params: dict,
    cache: dict,
    tokens: Array,
    *,
    axes: Optional[MeshAxes] = None,
    dtype=jnp.bfloat16,
) -> Tuple[Array, dict]:
    """One decode step. tokens: [B] int32 -> (logits [B, V], cache)."""
    B = tokens.shape[0]
    pos = cache["pos"]
    x = embed(params["embed"], tokens[:, None], dtype, scale=cfg.embed_scale)
    x = constrain(x, axes, act_spec(axes, "dp", None, None))

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        windows = window_array(cfg)

        # The cache is threaded as a scan CARRY with a tiny in-place
        # dynamic-update-slice per layer — xs/ys threading (or slice +
        # full-slice write-back) makes XLA materialize full-cache copies
        # per step (verified via the HLO roofline walk).
        def body(carry, xs):
            x, k_all, v_all = carry
            lp, window, li = xs
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            h, k_all, v_all = attention_decode_stacked(
                lp["attn"], h, k_all, v_all, li, pos,
                window=window, **_attn_kwargs(cfg),
            )
            if cfg.post_norm:
                h = rms_norm(h, lp["ln1_post"], cfg.norm_eps)
            x = x + h
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.n_experts:
                # Decode defaults to drop-free capacity (= n_experts x
                # the mean load): capacity dropping is a training
                # trade-off, not acceptable at serving time.
                # Dispatch is always the scatter path at decode: with
                # T = batch tokens the one-hot matmuls of the einsum
                # mode cost more than the tiny scatter (measured:
                # docs/experiments.md §Perf generalization table).
                h, _ = moe_lib.apply_moe(
                    lp["moe"],
                    h,
                    top_k=cfg.top_k,
                    capacity_factor=(
                        cfg.serve_capacity_factor or float(cfg.n_experts)
                    ),
                    dtype=h.dtype,
                    n_blocks=cfg.dispatch_blocks,
                    axes=axes,
                    dispatch="scatter",
                )
            else:
                h = apply_swiglu(lp["mlp"], h, dtype=h.dtype)
            if cfg.post_norm:
                h = rms_norm(h, lp["ln2_post"], cfg.norm_eps)
            return (x + h, k_all, v_all), None

        (x, k_new, v_new), _ = lax.scan(
            body,
            (x, cache["k"], cache["v"]),
            (params["layers"], windows, jnp.arange(cfg.n_layers)),
        )
        cache = dict(cache, k=k_new, v=v_new)

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]
        G = cfg.n_layers // cfg.attn_every

        def body(carry, xs):
            x, k_all, v_all, m_all = carry
            gp, gi = xs
            mstate = jax.tree.map(
                lambda a: lax.dynamic_index_in_dim(a, gi, 0, keepdims=False),
                m_all,
            )
            new_m = []
            for i in range(cfg.attn_every):
                lp = jax.tree.map(lambda a: a[i], gp)
                st = jax.tree.map(lambda a: a[i], mstate)
                h = rms_norm(x, lp["ln"], cfg.norm_eps)
                h, st = ssm_lib.apply_mamba2_decode(
                    lp["mamba"],
                    h,
                    st,
                    d_inner=cfg.d_inner,
                    d_state=cfg.ssm_state,
                    head_dim=cfg.ssm_head_dim,
                )
                x = x + h
                new_m.append(st)
            mstate = jax.tree.map(lambda *a: jnp.stack(a), *new_m)
            h = rms_norm(x, shared["ln"], cfg.norm_eps)
            h, k_all, v_all = attention_decode_stacked(
                shared["attn"], h, k_all, v_all, gi, pos,
                window=None, **_attn_kwargs(cfg),
            )
            m_all = jax.tree.map(
                lambda a, s: lax.dynamic_update_index_in_dim(a, s, gi, 0),
                m_all,
                mstate,
            )
            return (x + h, k_all, v_all, m_all), None

        (x, k_new, v_new, m_new), _ = lax.scan(
            body,
            (x, cache["k"], cache["v"], cache["mamba"]),
            (params["groups"], jnp.arange(G)),
        )
        cache = dict(cache, k=k_new, v=v_new, mamba=m_new)

    elif cfg.family == "ssm":

        def body(carry, xs):
            x, st_all = carry
            lp, li = xs
            st = jax.tree.map(
                lambda a: lax.dynamic_index_in_dim(a, li, 0, keepdims=False),
                st_all,
            )
            x, st = rwkv_lib.apply_rwkv6(
                lp, x, head_dim=cfg.rwkv_head_dim, state=st
            )
            st_all = jax.tree.map(
                lambda a, s: lax.dynamic_update_index_in_dim(a, s, li, 0),
                st_all,
                st,
            )
            return (x, st_all), None

        (x, r_new), _ = lax.scan(
            body,
            (x, cache["rwkv"]),
            (params["layers"], jnp.arange(cfg.n_layers)),
        )
        cache = dict(cache, rwkv=r_new)
    else:
        raise ValueError(cfg.family)

    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    lg = lm_logits(h[:, 0], table, cfg.final_softcap or None)
    lg = constrain(lg, axes, act_spec(axes, "dp", "tp"))
    cache = dict(cache, pos=pos + 1)
    return lg, cache


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------


def prefill(
    cfg: ArchConfig,
    params: dict,
    batch: dict,
    max_len: int,
    *,
    axes: Optional[MeshAxes] = None,
    dtype=jnp.bfloat16,
) -> Tuple[Array, dict]:
    """Process the prompt; returns (last-token logits [B, V], cache).

    The trunk is the same scanned forward; per-layer KV (or SSM/RWKV
    state) is collected as scan outputs.  KV caches are written into
    max_len-sized buffers (the serving engine's NBBS pages back the
    paged variant; this dense path is what the dry-run lowers).
    """
    if cfg.frontend != "none" and "embeds" in batch:
        x = batch["embeds"].astype(dtype)
        B, S = x.shape[:2]
    else:
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = embed(params["embed"], tokens, dtype, scale=cfg.embed_scale)
    x = constrain(x, axes, act_spec(axes, "dp", None, None))
    positions = jnp.arange(S)[None, :]
    cache = init_cache(cfg, B, max_len, dtype)

    def pad_kv(k):  # [B, S, Hkv, D] -> [B, max_len, Hkv, D]
        return jnp.pad(k, ((0, 0), (0, max_len - S), (0, 0), (0, 0)))

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        windows = window_array(cfg)

        def body(x, xs):
            lp, window = xs
            h = rms_norm(x, lp["ln1"], cfg.norm_eps)
            # attention with KV capture for the cache
            Bx, Sx, d = h.shape
            dt = h.dtype
            q = (h @ lp["attn"]["wq"].astype(dt)).reshape(
                Bx, Sx, cfg.n_heads, cfg.head_dim
            )
            k = (h @ lp["attn"]["wk"].astype(dt)).reshape(
                Bx, Sx, cfg.n_kv_heads, cfg.head_dim
            )
            v = (h @ lp["attn"]["wv"].astype(dt)).reshape(
                Bx, Sx, cfg.n_kv_heads, cfg.head_dim
            )
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            o = chunked_attention(
                q, k, v, causal=True, window=window,
                softcap=cfg.attn_softcap or None,
            )
            h = o.reshape(Bx, Sx, -1) @ lp["attn"]["wo"].astype(dt)
            if cfg.post_norm:
                h = rms_norm(h, lp["ln1_post"], cfg.norm_eps)
            x = x + h
            h = rms_norm(x, lp["ln2"], cfg.norm_eps)
            if cfg.n_experts:
                # serving path: drop-free by default (see decode_step)
                h, _ = moe_lib.apply_moe(
                    lp["moe"], h, top_k=cfg.top_k,
                    capacity_factor=(
                        cfg.serve_capacity_factor or float(cfg.n_experts)
                    ),
                    dtype=h.dtype, n_blocks=cfg.dispatch_blocks, axes=axes,
                    dispatch=cfg.dispatch_mode,
                    group_size=cfg.dispatch_group,
                )
            else:
                h = apply_swiglu(lp["mlp"], h, dtype=h.dtype)
            if cfg.post_norm:
                h = rms_norm(h, lp["ln2_post"], cfg.norm_eps)
            return x + h, (pad_kv(k), pad_kv(v))

        x, (ks, vs) = lax.scan(body, x, (params["layers"], windows))
        cache = dict(cache, k=ks, v=vs)

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def body(x, gp):
            # mamba sub-layers: chunked forward, exact final state captured
            new_m = []
            for i in range(cfg.attn_every):
                lp = jax.tree.map(lambda a: a[i], gp)
                h = rms_norm(x, lp["ln"], cfg.norm_eps)
                h, st = ssm_lib.apply_mamba2(
                    lp["mamba"], h, d_inner=cfg.d_inner,
                    d_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
                    return_state=True,
                )
                x = x + h
                new_m.append(st)
            mstate = jax.tree.map(lambda *a: jnp.stack(a), *new_m)
            h = rms_norm(x, shared["ln"], cfg.norm_eps)
            Bx, Sx, d = h.shape
            dt = h.dtype
            q = (h @ shared["attn"]["wq"].astype(dt)).reshape(
                Bx, Sx, cfg.n_heads, cfg.head_dim
            )
            k = (h @ shared["attn"]["wk"].astype(dt)).reshape(
                Bx, Sx, cfg.n_kv_heads, cfg.head_dim
            )
            v = (h @ shared["attn"]["wv"].astype(dt)).reshape(
                Bx, Sx, cfg.n_kv_heads, cfg.head_dim
            )
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            o = chunked_attention(
                q, k, v, causal=True, softcap=cfg.attn_softcap or None
            )
            h = o.reshape(Bx, Sx, -1) @ shared["attn"]["wo"].astype(dt)
            return x + h, (pad_kv(k), pad_kv(v), mstate)

        x, (ks, vs, ms) = lax.scan(body, x, params["groups"])
        cache = dict(cache, k=ks, v=vs, mamba=ms)

    elif cfg.family == "ssm":

        def body(x, lp):
            x, st = rwkv_lib.apply_rwkv6(lp, x, head_dim=cfg.rwkv_head_dim)
            return x, st

        x, states = lax.scan(body, x, params["layers"])
        cache = dict(cache, rwkv=states)
    else:
        raise ValueError(cfg.family)

    h = rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    lg = lm_logits(h[:, -1], table, cfg.final_softcap or None)
    cache = dict(cache, pos=jnp.asarray(S, jnp.int32))
    return lg, cache
