"""RWKV6 "Finch" block — attention-free mixer with data-dependent decay.

Time-mix (per head, head size P):
    w_t = exp(-exp(w0 + lora_w(x~_t)))          data-dependent decay [d]
    S_t = diag(w_t) S_{t-1} + k_t (x) v_t       state [P, P] per head
    y_t = r_t . (S_{t-1} + diag(u (.) k_t) v_t)  (u = per-channel bonus)

followed by per-head group-norm, a silu gate, and an output projection.
Channel-mix is the squared-relu two-layer MLP with token shift.

Training runs lax.scan over time on the [B, H, P, P] state (the
recurrence is inherently sequential in its data-dependent decay; a
chunked parallel form is a §Perf candidate, see docs/experiments.md).
Decode carries {token-shift xs, wkv state} — O(1) per token, which is
what long_500k exercises.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

Array = jax.Array


def init_rwkv6(key: Array, d: int, d_ff: int, head_dim: int, lora: int = 64) -> dict:
    H = d // head_dim
    ks = jax.random.split(key, 10)
    s = d ** -0.5
    return {
        # token-shift interpolation weights per stream
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_v": jnp.full((d,), 0.5, jnp.float32),
        "mu_g": jnp.full((d,), 0.5, jnp.float32),
        "mu_w": jnp.full((d,), 0.5, jnp.float32),
        "w_r": jax.random.normal(ks[0], (d, d), jnp.float32) * s,
        "w_k": jax.random.normal(ks[1], (d, d), jnp.float32) * s,
        "w_v": jax.random.normal(ks[2], (d, d), jnp.float32) * s,
        "w_g": jax.random.normal(ks[3], (d, d), jnp.float32) * s,
        "w_o": jax.random.normal(ks[4], (d, d), jnp.float32) * s,
        # data-dependent decay LoRA (the Finch signature feature)
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "wl_a": jax.random.normal(ks[5], (d, lora), jnp.float32) * s,
        "wl_b": jax.random.normal(ks[6], (lora, d), jnp.float32) * lora ** -0.5,
        "u": jax.random.normal(ks[7], (d,), jnp.float32) * 0.1,
        "ln_scale": jnp.ones((d,), jnp.float32),
        # channel mix
        "cm_mu_k": jnp.full((d,), 0.5, jnp.float32),
        "cm_mu_r": jnp.full((d,), 0.5, jnp.float32),
        "cm_k": jax.random.normal(ks[8], (d, d_ff), jnp.float32) * s,
        "cm_v": jax.random.normal(ks[9], (d_ff, d), jnp.float32) * d_ff ** -0.5,
        "cm_r": jax.random.normal(jax.random.fold_in(key, 99), (d, d), jnp.float32)
        * s,
        # pre-mix layer norms (RWKV uses LN; scale-only RMS-style here
        # keeps the param layout uniform with the rest of the zoo)
        "ln1": jnp.zeros((d,), jnp.float32),
        "ln2": jnp.zeros((d,), jnp.float32),
    }


def _shift(x: Array, x_prev: Array) -> Array:
    """Token shift: previous token per position; x_prev seeds position 0."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _group_norm(y: Array, scale: Array, H: int) -> Array:
    """Per-head layer norm over [B, T, H*P]."""
    B, T, d = y.shape
    yh = y.reshape(B, T, H, d // H).astype(jnp.float32)
    mean = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mean) * jax.lax.rsqrt(var + 1e-5)
    return (yh.reshape(B, T, d) * scale).astype(y.dtype)


def _wkv_scan(r, k, v, w, u, head_dim: int, state: Array):
    """r,k,v,w: [B, T, d] (w = per-step decay in (0,1)); u: [d].

    Returns (y: [B, T, d], final state [B, H, P, P])."""
    B, T, d = r.shape
    H = d // head_dim
    P = head_dim

    def reshape(a):
        return a.reshape(B, T, H, P).swapaxes(0, 1)  # [T, B, H, P]

    rs, ks, vs, ws = map(reshape, (r, k, v, w))
    uh = u.reshape(H, P)

    def step(S, xs):
        rt, kt, vt, wt = xs  # [B, H, P]
        kv = jnp.einsum("bhi,bhj->bhij", kt, vt)  # [B,H,P,P]
        y = jnp.einsum("bhi,bhij->bhj", rt, S + uh[None, :, :, None] * kv)
        S = wt[..., None] * S + kv
        return S, y

    S, ys = lax.scan(step, state, (rs, ks, vs, ws))
    return ys.swapaxes(0, 1).reshape(B, T, d), S


def apply_rwkv6(
    p: dict, x: Array, *, head_dim: int, state: dict | None = None
) -> Tuple[Array, dict]:
    """Full block (time-mix + channel-mix). x: [B, S, d].

    `state` (decode/chunk streaming) carries:
      tm_x, cm_x: [B, d] last-token shifts; wkv: [B, H, P, P].
    Returns (out, new_state).
    """
    B, S, d = x.shape
    dtype = x.dtype
    H = d // head_dim
    if state is None:
        state = init_rwkv6_state(B, d, head_dim)
    from repro.models.layers import rms_norm

    residual = x
    x = rms_norm(x, p["ln1"])
    x_in = x

    # ---- time mix -----------------------------------------------------
    xprev = _shift(x, state["tm_x"].astype(dtype))
    def mix(mu):
        return x + (xprev - x) * mu.astype(dtype)

    xr, xk, xv, xg, xw = (
        mix(p["mu_r"]),
        mix(p["mu_k"]),
        mix(p["mu_v"]),
        mix(p["mu_g"]),
        mix(p["mu_w"]),
    )
    r = xr @ p["w_r"].astype(dtype)
    k = xk @ p["w_k"].astype(dtype)
    v = xv @ p["w_v"].astype(dtype)
    g = xg @ p["w_g"].astype(dtype)
    dd = jnp.tanh(xw.astype(jnp.float32) @ p["wl_a"]) @ p["wl_b"]
    w = jnp.exp(-jnp.exp(p["w0"][None, None] + dd))  # [B,S,d] in (0,1)

    y, wkv = _wkv_scan(
        r.astype(jnp.float32),
        k.astype(jnp.float32),
        v.astype(jnp.float32),
        w,
        p["u"],
        head_dim,
        state["wkv"],
    )
    y = _group_norm(y.astype(dtype), p["ln_scale"], H)
    y = (y * jax.nn.silu(g.astype(jnp.float32)).astype(dtype)) @ p["w_o"].astype(
        dtype
    )
    residual = residual + y

    # ---- channel mix ---------------------------------------------------
    xc = rms_norm(residual, p["ln2"])
    xprev_c = _shift(xc, state["cm_x"].astype(dtype))
    xk_c = xc + (xprev_c - xc) * p["cm_mu_k"].astype(dtype)
    xr_c = xc + (xprev_c - xc) * p["cm_mu_r"].astype(dtype)
    kk = jnp.square(
        jax.nn.relu((xk_c @ p["cm_k"].astype(dtype)).astype(jnp.float32))
    ).astype(dtype)
    rr = jax.nn.sigmoid((xr_c @ p["cm_r"].astype(dtype)).astype(jnp.float32))
    out = residual + (kk @ p["cm_v"].astype(dtype)) * rr.astype(dtype)

    new_state = {
        # next chunk's shifts: last token of the time-mix input and of
        # the channel-mix input respectively
        "tm_x": x_in[:, -1].astype(jnp.float32),
        "cm_x": xc[:, -1].astype(jnp.float32),
        "wkv": wkv,
    }
    return out, new_state


def init_rwkv6_state(batch: int, d: int, head_dim: int) -> dict:
    H = d // head_dim
    return {
        "tm_x": jnp.zeros((batch, d), jnp.float32),
        "cm_x": jnp.zeros((batch, d), jnp.float32),
        "wkv": jnp.zeros((batch, H, head_dim, head_dim), jnp.float32),
    }
