"""Mamba2 block (chunked SSD) — zamba2's backbone mixer.

State-space duality form ("Transformers are SSMs", Dao & Gu 2024),
scalar-per-head A, shared B/C across heads (ngroups=1):

    h_t = exp(A * dt_t) h_{t-1} + dt_t * B_t (x) x_t        (state [H,P,N])
    y_t = C_t . h_t + D x_t

Training runs a lax.scan over sequence *chunks*: within a chunk the
quadratic (attention-like) form computes intra-chunk outputs, and the
carried state provides the inter-chunk contribution — O(S*L) compute
with only [B, L, L, H] transient memory (L = chunk length), never the
full [S, S] matrix nor a materialized [S, H, P, N] state history.

Decode is the O(1) recurrence on the carried state — this is what makes
long_500k a constant-memory decode for the hybrid/ssm architectures
(docs/design.md §5).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import rms_norm

Array = jax.Array


def init_mamba2(
    key: Array, d: int, d_inner: int, d_state: int, head_dim: int, d_conv: int = 4
) -> dict:
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 6)
    conv_dim = d_inner + 2 * d_state
    return {
        # in_proj -> [z, x, B, C, dt]
        "w_in": jax.random.normal(
            ks[0], (d, 2 * d_inner + 2 * d_state + n_heads), jnp.float32
        )
        * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (d_conv, conv_dim), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(
            jnp.linspace(1.0, float(n_heads), n_heads, dtype=jnp.float32)
        ),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "norm": jnp.zeros((d_inner,), jnp.float32),
        "w_out": jax.random.normal(ks[2], (d_inner, d), jnp.float32)
        * d_inner ** -0.5,
    }


def _split_proj(p, x, d_inner, d_state, n_heads, dtype):
    proj = x @ p["w_in"].astype(dtype)
    z, xs, Bc, Cc, dt = jnp.split(
        proj,
        [d_inner, 2 * d_inner, 2 * d_inner + d_state, 2 * d_inner + 2 * d_state],
        axis=-1,
    )
    return z, xs, Bc, Cc, dt


def _causal_conv(xBC: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv over [B, S, C] with kernel [K, C]."""
    K = w.shape[0]
    xp = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(
        xp[:, i : xp.shape[1] - (K - 1 - i), :] * w[i][None, None, :]
        for i in range(K)
    )
    return jax.nn.silu((out + b[None, None, :]).astype(jnp.float32)).astype(
        xBC.dtype
    )


def apply_mamba2(
    p: dict,
    x: Array,
    *,
    d_inner: int,
    d_state: int,
    head_dim: int,
    chunk: int = 128,
    return_state: bool = False,
):
    """x: [B, S, d] -> [B, S, d] (training / prefill path).

    With return_state=True also returns the decode state dict (final SSM
    state from the chunk scan + the last d_conv-1 raw conv inputs), so
    prefill hands decode an exact continuation point."""
    Bsz, S, d = x.shape
    dtype = x.dtype
    H = d_inner // head_dim
    P, N = head_dim, d_state
    z, xs, Bc, Cc, dt = _split_proj(p, x, d_inner, d_state, H, dtype)
    xBC_raw = jnp.concatenate([xs, Bc, Cc], axis=-1)
    xBC = _causal_conv(xBC_raw, p["conv_w"], p["conv_b"])
    xs, Bc, Cc = jnp.split(xBC, [d_inner, d_inner + d_state], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,S,H]
    A = -jnp.exp(p["A_log"])  # [H], negative
    la = dt * A  # log decay per step [B,S,H]
    xh = xs.reshape(Bsz, S, H, P).astype(jnp.float32)
    xd = xh * dt[..., None]  # dt-scaled input
    Bf = Bc.astype(jnp.float32)  # [B,S,N]
    Cf = Cc.astype(jnp.float32)

    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))
        xd = jnp.pad(xd, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bf = jnp.pad(Bf, ((0, 0), (0, pad), (0, 0)))
        Cf = jnp.pad(Cf, ((0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // L

    def to_chunks(a):
        return a.reshape((Bsz, nc, L) + a.shape[2:]).swapaxes(0, 1)

    las, xds, Bs, Cs = map(to_chunks, (la, xd, Bf, Cf))

    def body(Hst, xs_):
        la_c, xd_c, B_c, C_c = xs_  # [B,L,H], [B,L,H,P], [B,L,N], [B,L,N]
        cums = jnp.cumsum(la_c, axis=1)  # [B,L,H]
        total = cums[:, -1]  # [B,H]
        # inter-chunk: y_i += C_i . (decay_i * H)
        yin = jnp.einsum("bln,bhnp->blhp", C_c, Hst) * jnp.exp(cums)[..., None]
        # intra-chunk quadratic form (mask inside the exp: the i<j
        # entries have positive exponents that overflow to inf and would
        # poison the product with NaN = inf * 0)
        cb = jnp.einsum("bin,bjn->bij", C_c, B_c)  # [B,L,L]
        mask = (
            jnp.arange(L)[:, None] >= jnp.arange(L)[None, :]
        )  # causal within chunk
        diff = cums[:, :, None, :] - cums[:, None, :, :]  # [B,i,j,H]
        dec = jnp.exp(jnp.where(mask[None, :, :, None], diff, -jnp.inf))
        w = cb[..., None] * dec
        yintra = jnp.einsum("bijh,bjhp->bihp", w, xd_c)
        # state update
        decay_j = jnp.exp(total[:, None, :] - cums)  # [B,L,H]
        S_c = jnp.einsum("bjh,bjn,bjhp->bhnp", decay_j, B_c, xd_c)
        H_new = jnp.exp(total)[..., None, None] * Hst + S_c
        return H_new, yin + yintra

    H0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    Hfin, ys = lax.scan(body, H0, (las, xds, Bs, Cs))
    y = ys.swapaxes(0, 1).reshape(Bsz, nc * L, H, P)[:, :S]
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(Bsz, S, d_inner).astype(dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dtype), p["norm"])
    out = y @ p["w_out"].astype(dtype)
    if not return_state:
        return out
    # Trailing-pad correction: padded steps have dt-scaled input 0 but a
    # decay factor exp(0 * A) = 1, so the final carried state equals the
    # state at position S-1 exactly — no correction needed.
    K = p["conv_w"].shape[0]
    tail = xBC_raw[:, max(S - (K - 1), 0) :]
    if S < K - 1:
        tail = jnp.pad(tail, ((0, 0), (K - 1 - S, 0), (0, 0)))
    return out, {"ssm": Hfin, "conv": tail}


def init_mamba2_state(
    batch: int, d_inner: int, d_state: int, head_dim: int, d_conv: int = 4,
    dtype=jnp.bfloat16,
) -> dict:
    H = d_inner // head_dim
    conv_dim = d_inner + 2 * d_state
    return {
        "ssm": jnp.zeros((batch, H, d_state, head_dim), jnp.float32),
        "conv": jnp.zeros((batch, d_conv - 1, conv_dim), dtype),
    }


def apply_mamba2_decode(
    p: dict,
    x: Array,
    state: dict,
    *,
    d_inner: int,
    d_state: int,
    head_dim: int,
) -> Tuple[Array, dict]:
    """One-token decode. x: [B, 1, d]; O(1) state update."""
    Bsz, _, d = x.shape
    dtype = x.dtype
    H = d_inner // head_dim
    P, N = head_dim, d_state
    z, xs, Bc, Cc, dt = _split_proj(p, x, d_inner, d_state, H, dtype)
    xBC = jnp.concatenate([xs, Bc, Cc], axis=-1)  # [B,1,conv_dim]
    conv_buf = jnp.concatenate([state["conv"].astype(dtype), xBC], axis=1)
    K = p["conv_w"].shape[0]
    out = (conv_buf * p["conv_w"].astype(dtype)[None]).sum(1) + p[
        "conv_b"
    ].astype(dtype)
    xBC_t = jax.nn.silu(out.astype(jnp.float32)).astype(dtype)  # [B, conv_dim]
    new_conv = conv_buf[:, 1:]
    xs, Bc, Cc = jnp.split(xBC_t, [d_inner, d_inner + d_state], axis=-1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # [B,H]
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)  # [B,H]
    xh = xs.reshape(Bsz, H, P).astype(jnp.float32)
    Bf = Bc.astype(jnp.float32)  # [B,N]
    Cf = Cc.astype(jnp.float32)
    hs = state["ssm"] * a[..., None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, Bf, xh
    )
    y = jnp.einsum("bn,bhnp->bhp", Cf, hs) + xh * p["D"][None, :, None]
    y = y.reshape(Bsz, 1, d_inner).astype(dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(dtype), p["norm"])
    return y @ p["w_out"].astype(dtype), {"ssm": hs, "conv": new_conv}
