"""Parameter / activation partitioning rules (DP x TP x EP x SP).

Static `MeshAxes` describes the logical axes of the active mesh; the
launch layer constructs it from the production mesh (('pod','data')
fused as the DP group on the multi-pod mesh).  Model code calls
`constrain` with logical specs; when `axes` is None (CPU unit tests) it
is a no-op, keeping the model code mesh-agnostic.

Parameter rules (FSDP x TP, MaxText-style): every matmul weight shards
its TP-parallel dimension on 'model' (attention heads / ffn hidden /
vocab / experts) and its other large dimension on the DP group
(ZeRO-3-style weight sharding — required to fit e.g. llama4-scout's
~100B params on 256 chips; XLA SPMD inserts the per-layer all-gathers).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    dp: Tuple[str, ...] = ("data",)  # ('pod','data') on the multi-pod mesh
    tp: str = "model"
    # FSDP weight sharding over the dp group (ZeRO-3). Disable to keep
    # weights replicated across DP (small models).
    fsdp: bool = True


def constrain(x: Array, axes: Optional[MeshAxes], spec: P) -> Array:
    if axes is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def act_spec(axes: Optional[MeshAxes], *dims) -> P:
    """Build a PartitionSpec from logical dim tags:
    'dp' -> dp group, 'tp' -> model axis, None -> replicated."""
    if axes is None:
        return P()
    out = []
    for d in dims:
        if d == "dp":
            out.append(axes.dp if len(axes.dp) > 1 else axes.dp[0])
        elif d == "tp":
            out.append(axes.tp)
        else:
            out.append(None)
    return P(*out)


# ---------------------------------------------------------------------------
# Parameter specs by path-name rules
# ---------------------------------------------------------------------------

# (substring match on the flattened path, spec-tags per dimension)
# Order matters: first match wins.
_RULES = [
    # attention
    ("wq", ("fsdp", "tp")),
    ("wk", ("fsdp", "tp")),
    ("wv", ("fsdp", "tp")),
    ("wo", ("tp", "fsdp")),
    # dense MLP
    ("w_gate", ("fsdp", "tp")),
    ("w_in", ("fsdp", "tp")),
    ("w_out", ("tp", "fsdp")),
    # MoE (leading expert dim) — matched before generic by dim count below
    ("router", (None, None)),
    # embeddings / head
    ("embed", ("tp", "fsdp")),
    ("lm_head", ("tp", "fsdp")),
    # rwkv
    ("w_r", ("fsdp", "tp")),
    ("w_k", ("fsdp", "tp")),
    ("w_v", ("fsdp", "tp")),
    ("w_g", ("fsdp", "tp")),
    ("w_o", ("tp", "fsdp")),
    ("cm_k", ("fsdp", "tp")),
    ("cm_v", ("tp", "fsdp")),
    ("cm_r", ("fsdp", "tp")),
    ("wl_a", ("fsdp", None)),
    ("wl_b", (None, "fsdp")),
    # mamba conv
    ("conv_w", (None, "tp")),
    ("conv_b", ("tp",)),
]

_MOE_3D = {"w_gate": ("tp", None, "fsdp"), "w_in": ("tp", None, "fsdp"),
           "w_out": ("tp", "fsdp", None)}


def _tags_to_spec(axes: MeshAxes, tags, ndim: int, stacked: int) -> P:
    dims = []
    for t in tags:
        if t == "tp":
            dims.append(axes.tp)
        elif t == "fsdp":
            dims.append(
                (axes.dp if len(axes.dp) > 1 else axes.dp[0])
                if axes.fsdp
                else None
            )
        else:
            dims.append(None)
    # account for leading stacked layer/group dims
    return P(*([None] * stacked + dims))


def param_specs(axes: Optional[MeshAxes], params) -> object:
    """Pytree of PartitionSpec matching `params` (by path rules).

    Leaves under 'layers'/'groups' carry 1 (or 2: hybrid groups) leading
    stacked dims which are never sharded.
    """
    if axes is None:
        return jax.tree.map(lambda _: P(), params)

    def spec_for(path, leaf) -> P:
        names = [
            p.key for p in path if isinstance(p, jax.tree_util.DictKey)
        ]
        pathstr = "/".join(names)
        stacked = 0
        if "layers" in names or "groups" in names:
            stacked = 1
            if "groups" in names:  # hybrid: [G, A, ...]
                stacked = 2
        eff_ndim = leaf.ndim - stacked
        last = names[-1] if names else ""
        # MoE expert tensors: leading E dim (3D after stacking)
        if eff_ndim == 3 and last in _MOE_3D:
            return _tags_to_spec(axes, _MOE_3D[last], leaf.ndim, stacked)
        for key, tags in _RULES:
            if last == key and len(tags) == eff_ndim:
                return _tags_to_spec(axes, tags, leaf.ndim, stacked)
        # default: replicate (norms, scalars, biases, mu/u vectors)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params)
