"""Pallas TPU paged decode attention — the NBBS consumer (serving hot spot).

One new token per sequence attends over a KV cache stored as
buddy-allocated pages in a global pool.  The page indirection uses the
TPU scalar-prefetch pattern (`PrefetchScalarGridSpec`): the block table
is prefetched into SMEM and the k/v BlockSpec index maps read it to
steer each grid step's DMA at the right pool page — the TPU-native
equivalent of vLLM's gather, with two NBBS-specific advantages
(docs/design.md §2): buddy blocks are power-of-two *contiguous* page runs,
so (a) larger pages are addressable with the same table and (b) the
pool fragments without external holes (the paper's coalescing at work).

Grid: (batch, q_heads, pages); pages innermost with fp32 online-softmax
scratch, invalid pages (table id < 0, or beyond the sequence's context
length) skipped with @pl.when.

Validated with interpret=True against `ref.paged_attention_reference`
over shape/dtype/page-size sweeps.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30


def _paged_decode_kernel(
    # static
    scale: float,
    softcap: Optional[float],
    page: int,
    group: int,
    # prefetched scalars
    tables_ref,
    lens_ref,
    # tensor refs
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
):
    b = pl.program_id(0)
    j = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    ctx = lens_ref[b]
    page_id = tables_ref[b, j]
    live = (page_id >= 0) & (j * page < ctx)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [D]
        k = k_ref[0, :, 0].astype(jnp.float32)  # [page, D]
        v = v_ref[0, :, 0].astype(jnp.float32)  # [page, D]
        s = (k @ q) * scale  # [page]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        pos = j * page + jax.lax.broadcasted_iota(jnp.int32, (page,), 0)
        s = jnp.where(pos < ctx, s, NEG_INF)

        m_prev = m_scr[0]
        m_cur = jnp.maximum(m_prev, s.max())
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur)
        p = jnp.where(m_cur == NEG_INF, 0.0, p)
        alpha = jnp.where(m_cur == NEG_INF, 1.0, alpha)
        m_scr[0] = m_cur
        l_scr[0] = l_scr[0] * alpha + p.sum()
        acc_scr[...] = acc_scr[...] * alpha + p @ v

    @pl.when(j == n_pages - 1)
    def _finalize():
        l = l_scr[0]
        norm = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / norm).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("softcap", "scale", "interpret")
)
def paged_attention(
    q: Array,
    k_pages: Array,
    v_pages: Array,
    block_tables: Array,
    context_lens: Array,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    interpret: bool = True,
) -> Array:
    """q: [B,Hq,D]; k/v_pages: [P,page,Hkv,D]; tables: [B,max_pages]."""
    B, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    assert Hq % Hkv == 0
    group = Hq // Hkv
    max_pages = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(_paged_decode_kernel, scale, softcap, page, group)

    def q_map(b, h, j, tables, lens):
        return (b, h, 0)

    def kv_map(b, h, j, tables, lens):
        return (jnp.maximum(tables[b, j], 0), 0, h // group, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hq, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, D), q_map),
            pl.BlockSpec((1, page, 1, D), kv_map),
            pl.BlockSpec((1, page, 1, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, D), q_map),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((D,), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(
        block_tables.astype(jnp.int32),
        context_lens.astype(jnp.int32),
        q,
        k_pages,
        v_pages,
    )
