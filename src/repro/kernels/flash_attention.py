"""Pallas TPU flash attention (forward) — training/prefill hot spot.

Canonical TPU tiling: grid (batch, q_heads, q_blocks, kv_blocks) with the
kv dimension innermost (sequential revisiting of the output block), fp32
online-softmax state (running max / denominator / accumulator) in VMEM
scratch.  Block sizes default to 128x128 — MXU-aligned (128 multiples)
and (8,128) VPU-tile aligned.

Supported attention variants (exactly those required by the assigned
architectures):
  * GQA              — kv head = q head // group (llama/phi/gemma/zamba)
  * causal masking   — decoder LMs
  * sliding window   — gemma2 local layers
  * logit softcap    — gemma2 (softcap * tanh(logits / softcap))

Fully-masked kv blocks (beyond the causal diagonal or outside the
window) are skipped with @pl.when — the TPU analogue of flash
attention's block skipping on GPUs.

Backward: `ops.flash_attention` wraps this forward in a jax.custom_vjp
whose backward recomputes attention with the pure-jnp reference oracle
(`ref.mha_reference`) — identical math, so gradients are exact while
the forward enjoys the fused kernel.  (A fused Pallas backward is a
further optimization documented in docs/experiments.md §Perf.)
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU scratch memory spaces; interpret mode accepts them too.
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

Array = jax.Array

NEG_INF = -1e30


def _flash_fwd_kernel(
    # static
    scale: float,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    bq: int,
    bk: int,
    kv_len: int,
    # refs
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    m_scr,
    l_scr,
    acc_scr,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    row0 = iq * bq
    col0 = ik * bk

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level skip: beyond causal diagonal / outside sliding window.
    live = jnp.bool_(True)
    if causal:
        live &= col0 <= row0 + bq - 1
    if window is not None:
        live &= col0 + bk - 1 >= row0 - window + 1

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)  # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)  # [bk, d]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # [bq, bk]
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = cols < kv_len
        if causal:
            mask &= cols <= rows
        if window is not None:
            mask &= cols > rows - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]  # [bq]
        m_cur = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        # Rows where everything so far is masked: keep state neutral.
        p = jnp.where((m_cur == NEG_INF)[:, None], 0.0, p)
        alpha = jnp.where(m_cur == NEG_INF, 1.0, alpha)
        l_cur = l_scr[...] * alpha + p.sum(axis=1)
        acc = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_cur
        l_scr[...] = l_cur
        acc_scr[...] = acc

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        norm = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / norm[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal",
        "window",
        "softcap",
        "scale",
        "block_q",
        "block_k",
        "interpret",
    ),
)
def flash_attention_fwd(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> Array:
    """q: [B, Hq, S, D]; k, v: [B, Hkv, S, D]; returns [B, Hq, S, D]."""
    B, Hq, S, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    bq = min(block_q, S)
    bk = min(block_k, Sk)
    assert S % bq == 0 and Sk % bk == 0, (S, bq, Sk, bk)
    nq, nk = S // bq, Sk // bk
    if scale is None:
        scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _flash_fwd_kernel, scale, causal, window, softcap, bq, bk, Sk
    )
    scratch = [
        pltpu.VMEM((bq,), jnp.float32),
        pltpu.VMEM((bq,), jnp.float32),
        pltpu.VMEM((bq, D), jnp.float32),
    ]
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec(
                (1, 1, bk, D), lambda b, h, i, j, g=group: (b, h // g, j, 0)
            ),
            pl.BlockSpec(
                (1, 1, bk, D), lambda b, h, i, j, g=group: (b, h // g, j, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
