"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics-defining implementations: kernel tests sweep
shapes/dtypes and assert allclose against these functions; the model
code uses them on backends where Mosaic lowering is unavailable (this
CPU container's dry-run) — selected by `ops.py`.

The NBBS wavefront kernel's oracle is `repro.core.concurrent.
wavefront_alloc` (shared code, by construction identical); re-exported
here for uniformity.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.concurrent import wavefront_alloc as nbbs_wavefront_reference  # noqa: F401

Array = jax.Array

NEG_INF = -1e30


def mha_reference(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> Array:
    """Dense reference attention. q: [B,Hq,S,D]; k,v: [B,Hkv,Sk,D].

    GQA broadcast, causal/sliding-window masks and logit softcap match
    `flash_attention.flash_attention_fwd` exactly.
    """
    B, Hq, S, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    kr = jnp.repeat(k, group, axis=1)
    vr = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(Sk)[None, :]
    mask = jnp.ones((S, Sk), dtype=bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    # Neutralize fully-masked rows (can only happen with degenerate
    # windows); softmax over all-NEG_INF rows would be uniform garbage.
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[None, None].any(-1, keepdims=True), p, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)


def paged_attention_reference(
    q: Array,
    k_pages: Array,
    v_pages: Array,
    block_tables: Array,
    context_lens: Array,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> Array:
    """Decode attention through a page table (the NBBS consumer).

    q:            [B, Hq, D]        — one new token per sequence
    k/v_pages:    [P, page, Hkv, D] — global page pool (buddy blocks)
    block_tables: [B, max_pages]    — page ids per sequence, -1 padded
    context_lens: [B]               — valid kv length per sequence
    returns       [B, Hq, D]
    """
    B, Hq, D = q.shape
    P, page, Hkv, _ = k_pages.shape
    group = Hq // Hkv
    if scale is None:
        scale = 1.0 / math.sqrt(D)
    max_pages = block_tables.shape[1]

    safe_tables = jnp.maximum(block_tables, 0)
    k = k_pages[safe_tables]  # [B, max_pages, page, Hkv, D]
    v = v_pages[safe_tables]
    k = k.reshape(B, max_pages * page, Hkv, D)
    v = v.reshape(B, max_pages * page, Hkv, D)
    kr = jnp.repeat(k, group, axis=2)  # [B, L, Hq, D]
    vr = jnp.repeat(v, group, axis=2)
    s = jnp.einsum(
        "bhd,blhd->bhl", q.astype(jnp.float32), kr.astype(jnp.float32)
    ) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(max_pages * page)[None, :]
    valid_page = (block_tables >= 0)[:, :, None]  # [B, max_pages, 1]
    valid = jnp.broadcast_to(valid_page, (B, max_pages, page)).reshape(
        B, max_pages * page
    )
    valid &= pos < context_lens[:, None]
    s = jnp.where(valid[:, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhl,blhd->bhd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
