"""Public kernel ops: backend dispatch + differentiability.

Selection policy (`impl`):
  "auto"      — Pallas/Mosaic on TPU backends, pure-jnp reference
                otherwise (XLA CPU/GPU cannot lower Mosaic kernels;
                the dry-run lowers the reference path — identical math,
                verified allclose by the kernel test sweeps).
  "pallas"    — compiled Pallas (TPU runtime).
  "interpret" — Pallas interpret mode (CPU validation; slow).
  "reference" — pure-jnp oracle.

`flash_attention` is differentiable: forward may use the fused kernel,
backward recomputes through the reference (identical math -> exact
gradients w.r.t. the reference function).

The NBBS dispatchers are tree-layout-agnostic: the `cfg`/`pcfg` they
take carries its `TreeLayout` (docs/design.md §3), and every impl path
— reference, interpret, pallas — runs the same layout-parameterized
round bodies, so packed and unpacked configs dispatch identically.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import fastpath
from repro.core.concurrent import TreeConfig, wavefront_step
from repro.core.pool import PoolConfig, home_shard, pool_wavefront_step
from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.nbbs_alloc import (
    pool_wavefront_step_pallas,
    wavefront_alloc_pallas,
    wavefront_step_pallas,
)
from repro.kernels.paged_attention import paged_attention as paged_attention_pallas

Array = jax.Array


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def _resolve(impl: str) -> str:
    return default_impl() if impl == "auto" else impl


# ---------------------------------------------------------------------------
# Flash attention (differentiable)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def _flash_attention(q, k, v, causal, window, softcap, scale, impl):
    if impl == "reference":
        return kref.mha_reference(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale
        )
    return flash_attention_fwd(
        q,
        k,
        v,
        causal=causal,
        window=window,
        softcap=softcap,
        scale=scale,
        interpret=(impl == "interpret"),
    )


def _flash_fwd(q, k, v, causal, window, softcap, scale, impl):
    out = _flash_attention(q, k, v, causal, window, softcap, scale, impl)
    return out, (q, k, v)


def _flash_bwd(causal, window, softcap, scale, impl, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: kref.mha_reference(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale
        ),
        q,
        k,
        v,
    )
    return vjp(g)


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    impl: str = "auto",
) -> Array:
    """Differentiable attention. q:[B,Hq,S,D], k/v:[B,Hkv,Sk,D]."""
    return _flash_attention(
        q, k, v, causal, window, softcap, scale, _resolve(impl)
    )


# ---------------------------------------------------------------------------
# Paged decode attention (inference only — no vjp needed)
# ---------------------------------------------------------------------------


def paged_attention(
    q: Array,
    k_pages: Array,
    v_pages: Array,
    block_tables: Array,
    context_lens: Array,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    impl: str = "auto",
) -> Array:
    impl = _resolve(impl)
    if impl == "reference":
        return kref.paged_attention_reference(
            q,
            k_pages,
            v_pages,
            block_tables,
            context_lens,
            softcap=softcap,
            scale=scale,
        )
    return paged_attention_pallas(
        q,
        k_pages,
        v_pages,
        block_tables,
        context_lens,
        softcap=softcap,
        scale=scale,
        interpret=(impl == "interpret"),
    )


# ---------------------------------------------------------------------------
# NBBS wavefront allocation
# ---------------------------------------------------------------------------


def nbbs_wavefront_alloc(
    cfg: TreeConfig,
    tree: Array,
    levels: Array,
    *,
    active: Array | None = None,
    max_rounds: int = 64,
    impl: str = "auto",
):
    """Returns (tree, nodes, ok, stats-dict)."""
    impl = _resolve(impl)
    if impl == "reference":
        if active is None:
            active = jnp.ones(levels.shape, dtype=bool)
        return kref.nbbs_wavefront_reference(
            cfg, tree, levels, active, max_rounds
        )
    tree, nodes, ok, stats = wavefront_alloc_pallas(
        cfg,
        tree,
        levels,
        max_rounds,
        active=active,
        interpret=(impl == "interpret"),
    )
    return tree, nodes, ok, {
        "rounds": stats[0],
        "merged_writes": stats[1],
        "logical_rmws": stats[2],
    }


def nbbs_wavefront_step(
    cfg: TreeConfig,
    tree: Array,
    free_nodes: Array,
    free_active: Array,
    levels: Array,
    *,
    active: Array | None = None,
    max_rounds: int = 64,
    impl: str = "auto",
):
    """Mixed release+allocation round (frees via the merged vectorized
    pass, then the alloc wavefront).  Returns (tree, nodes, ok, stats)."""
    impl = _resolve(impl)
    if active is None:
        active = jnp.ones(levels.shape, dtype=bool)
    if impl == "reference":
        return wavefront_step(
            cfg, tree, free_nodes, free_active, levels, active, max_rounds
        )
    tree, nodes, ok, stats = wavefront_step_pallas(
        cfg,
        tree,
        free_nodes,
        free_active,
        levels,
        max_rounds,
        active=active,
        interpret=(impl == "interpret"),
    )
    return tree, nodes, ok, {
        "rounds": stats[0],
        "merged_writes": stats[1],
        "logical_rmws": stats[2],
        "free_writes": stats[3],
        "free_merged_writes": stats[3],
        "free_logical_rmws": stats[4],
        "freed": stats[5],
    }


def nbbs_pool_wavefront_step(
    pcfg: PoolConfig,
    trees: Array,
    free_nodes: Array,
    free_shard: Array,
    free_active: Array,
    levels: Array,
    *,
    lane_ids: Array | None = None,
    active: Array | None = None,
    max_rounds: int = 64,
    impl: str = "auto",
):
    """Pooled mixed release+allocation step across S sharded trees.

    "reference" runs the in-graph lockstep router (`pool_wavefront_step`
    — lanes re-route between pool rounds).  The Pallas paths launch the
    grid-over-shards kernel once per probe attempt: every launch keeps
    one shard's tree VMEM-resident per program, and lanes whose shard is
    exhausted are re-routed to the next shard in the pool's fixed probe
    order before the next launch (an attempt-granular linearization of
    the same routing; identical to the reference whenever no lane
    overflows).  Returns (trees, nodes, shard, ok, stats).
    """
    impl = _resolve(impl)
    K = levels.shape[0]
    if active is None:
        active = jnp.ones(levels.shape, dtype=bool)
    if lane_ids is None:
        lane_ids = jnp.arange(K, dtype=jnp.int32)
    if impl == "reference":
        return pool_wavefront_step(
            pcfg, trees, free_nodes, free_shard, free_active, levels,
            active, max_rounds, lane_ids,
        )
    S = pcfg.n_shards
    home = home_shard(pcfg, lane_ids)
    shard = home
    pending = active
    nodes = jnp.zeros(K, dtype=jnp.int32)
    out_shard = shard
    fa = free_active
    agg = {
        "rounds": jnp.int32(0),
        "merged_writes": jnp.int32(0),
        "logical_rmws": jnp.int32(0),
        "free_writes": jnp.int32(0),
        "free_logical_rmws": jnp.int32(0),
        "freed": jnp.int32(0),
        "fastpath_hits": jnp.int32(0),
    }
    for _ in range(S):
        trees, n_a, ok_a, st = pool_wavefront_step_pallas(
            pcfg,
            trees,
            free_nodes,
            free_shard,
            fa,
            levels,
            shard,
            max_rounds,
            active=pending,
            interpret=(impl == "interpret"),
        )
        won = pending & ok_a
        nodes = jnp.where(won, n_a, nodes)
        out_shard = jnp.where(won, shard, out_shard)
        pending = pending & ~ok_a
        shard = jnp.where(pending, (shard + 1) % S, shard)
        # shards run concurrently within a launch: rounds is the max row
        agg["rounds"] = agg["rounds"] + st[:, 0].max()
        agg["merged_writes"] = agg["merged_writes"] + st[:, 1].sum()
        agg["logical_rmws"] = agg["logical_rmws"] + st[:, 2].sum()
        agg["free_writes"] = agg["free_writes"] + st[:, 3].sum()
        agg["free_logical_rmws"] = agg["free_logical_rmws"] + st[:, 4].sum()
        agg["freed"] = agg["freed"] + st[:, 5].sum()
        agg["fastpath_hits"] = agg["fastpath_hits"] + st[:, 6].sum()
        fa = jnp.zeros_like(free_active)  # frees apply on the first launch
        # early exit is an eager-mode optimization only: under jit
        # `pending` is a tracer and the loop simply runs all S launches
        if not isinstance(pending, jax.core.Tracer) and not bool(
            pending.any()
        ):
            break
    ok = nodes > 0
    agg["free_merged_writes"] = agg["free_writes"]
    agg["overflows"] = (ok & (out_shard != home)).sum(dtype=jnp.int32)
    if pcfg.fastpath is None:
        fast_total = jnp.int32(0)
    else:
        fast = levels == fastpath.fp_level(pcfg.tree, pcfg.fastpath)
        fast_total = (active & fast).sum(dtype=jnp.int32)
    agg["fastpath_spills"] = fast_total - agg["fastpath_hits"]
    return trees, nodes, out_shard, ok, agg
