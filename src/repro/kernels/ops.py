"""Public kernel ops: backend dispatch + differentiability.

Selection policy (`impl`):
  "auto"      — Pallas/Mosaic on TPU backends, pure-jnp reference
                otherwise (XLA CPU/GPU cannot lower Mosaic kernels;
                the dry-run lowers the reference path — identical math,
                verified allclose by the kernel test sweeps).
  "pallas"    — compiled Pallas (TPU runtime).
  "interpret" — Pallas interpret mode (CPU validation; slow).
  "reference" — pure-jnp oracle.

`flash_attention` is differentiable: forward may use the fused kernel,
backward recomputes through the reference (identical math -> exact
gradients w.r.t. the reference function).

The NBBS dispatchers are tree-layout-agnostic: the `cfg`/`pcfg` they
take carries its `TreeLayout` (docs/design.md §3), and every impl path
— reference, interpret, pallas — runs the same layout-parameterized
round bodies, so packed and unpacked configs dispatch identically.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import fastpath
from repro.core import magazine as magmod
from repro.core.concurrent import TreeConfig, wavefront_step
from repro.core.pool import (
    PoolConfig,
    _gid_parts,
    _mag_spill_all,
    _mag_stash_phase,
    home_shard,
    pool_wavefront_alloc,
    pool_wavefront_step,
    pool_wavefront_step_mag,
)
from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.nbbs_alloc import (
    pool_wavefront_step_pallas,
    wavefront_alloc_pallas,
    wavefront_step_pallas,
)
from repro.kernels.paged_attention import paged_attention as paged_attention_pallas
from repro.obs.schema import (
    POOL_STEP_SLOTS,
    WAVEFRONT_ALLOC_SLOTS,
    WAVEFRONT_STEP_SLOTS,
    unpack_slots,
)

Array = jax.Array


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def _resolve(impl: str) -> str:
    return default_impl() if impl == "auto" else impl


# ---------------------------------------------------------------------------
# Flash attention (differentiable)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def _flash_attention(q, k, v, causal, window, softcap, scale, impl):
    if impl == "reference":
        return kref.mha_reference(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale
        )
    return flash_attention_fwd(
        q,
        k,
        v,
        causal=causal,
        window=window,
        softcap=softcap,
        scale=scale,
        interpret=(impl == "interpret"),
    )


def _flash_fwd(q, k, v, causal, window, softcap, scale, impl):
    out = _flash_attention(q, k, v, causal, window, softcap, scale, impl)
    return out, (q, k, v)


def _flash_bwd(causal, window, softcap, scale, impl, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: kref.mha_reference(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale
        ),
        q,
        k,
        v,
    )
    return vjp(g)


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    impl: str = "auto",
) -> Array:
    """Differentiable attention. q:[B,Hq,S,D], k/v:[B,Hkv,Sk,D]."""
    return _flash_attention(
        q, k, v, causal, window, softcap, scale, _resolve(impl)
    )


# ---------------------------------------------------------------------------
# Paged decode attention (inference only — no vjp needed)
# ---------------------------------------------------------------------------


def paged_attention(
    q: Array,
    k_pages: Array,
    v_pages: Array,
    block_tables: Array,
    context_lens: Array,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    impl: str = "auto",
) -> Array:
    impl = _resolve(impl)
    if impl == "reference":
        return kref.paged_attention_reference(
            q,
            k_pages,
            v_pages,
            block_tables,
            context_lens,
            softcap=softcap,
            scale=scale,
        )
    return paged_attention_pallas(
        q,
        k_pages,
        v_pages,
        block_tables,
        context_lens,
        softcap=softcap,
        scale=scale,
        interpret=(impl == "interpret"),
    )


# ---------------------------------------------------------------------------
# NBBS wavefront allocation
# ---------------------------------------------------------------------------


def nbbs_wavefront_alloc(
    cfg: TreeConfig,
    tree: Array,
    levels: Array,
    *,
    active: Array | None = None,
    max_rounds: int = 64,
    impl: str = "auto",
):
    """Returns (tree, nodes, ok, stats-dict)."""
    impl = _resolve(impl)
    if impl == "reference":
        if active is None:
            active = jnp.ones(levels.shape, dtype=bool)
        return kref.nbbs_wavefront_reference(
            cfg, tree, levels, active, max_rounds
        )
    tree, nodes, ok, stats = wavefront_alloc_pallas(
        cfg,
        tree,
        levels,
        max_rounds,
        active=active,
        interpret=(impl == "interpret"),
    )
    # name the positional kernel row through the shared schema order
    return tree, nodes, ok, unpack_slots(WAVEFRONT_ALLOC_SLOTS, stats)


def nbbs_wavefront_step(
    cfg: TreeConfig,
    tree: Array,
    free_nodes: Array,
    free_active: Array,
    levels: Array,
    *,
    active: Array | None = None,
    max_rounds: int = 64,
    impl: str = "auto",
):
    """Mixed release+allocation round (frees via the merged vectorized
    pass, then the alloc wavefront).  Returns (tree, nodes, ok, stats)."""
    impl = _resolve(impl)
    if active is None:
        active = jnp.ones(levels.shape, dtype=bool)
    if impl == "reference":
        return wavefront_step(
            cfg, tree, free_nodes, free_active, levels, active, max_rounds
        )
    tree, nodes, ok, stats = wavefront_step_pallas(
        cfg,
        tree,
        free_nodes,
        free_active,
        levels,
        max_rounds,
        active=active,
        interpret=(impl == "interpret"),
    )
    out = unpack_slots(WAVEFRONT_STEP_SLOTS, stats)
    out["free_writes"] = out["free_merged_writes"]  # legacy alias
    return tree, nodes, ok, out


def nbbs_pool_wavefront_step(
    pcfg: PoolConfig,
    trees: Array,
    free_nodes: Array,
    free_shard: Array,
    free_active: Array,
    levels: Array,
    *,
    lane_ids: Array | None = None,
    active: Array | None = None,
    max_rounds: int = 64,
    impl: str = "auto",
    mags=None,
    free_mag_lane: Array | None = None,
    alloc_mag_lane: Array | None = None,
):
    """Pooled mixed release+allocation step across S sharded trees.

    "reference" runs the in-graph lockstep router (`pool_wavefront_step`
    — lanes re-route between pool rounds).  The Pallas paths launch the
    grid-over-shards kernel once per probe attempt: every launch keeps
    one shard's tree VMEM-resident per program, and lanes whose shard is
    exhausted are re-routed to the next shard in the pool's fixed probe
    order before the next launch (an attempt-granular linearization of
    the same routing; identical to the reference whenever no lane
    overflows).  Returns (trees, nodes, shard, ok, stats).

    With `mags` (a `core.magazine.MagazineState`; requires
    `pcfg.magazines`), the magazine layer fuses around the kernel
    launches: the stash pre-pass recycles freed leaf handles of
    `free_mag_lane` lanes before the first launch, the claim phase
    serves `alloc_mag_lane` lanes before any launch runs, and on
    exhaustion one merged spill-back plus a reference-path retry keeps
    failure semantics magazines-off-equivalent.  Magazines are per-lane
    state shared across shards, so these phases live here in the driver
    — the per-shard kernel rows keep their magazine slots zero — and
    the driver fills the aggregate 'magazine_*' slots.  Returns
    (trees, mags, nodes, shard, ok, stats) in this mode.
    """
    impl = _resolve(impl)
    K = levels.shape[0]
    if active is None:
        active = jnp.ones(levels.shape, dtype=bool)
    if lane_ids is None:
        lane_ids = jnp.arange(K, dtype=jnp.int32)
    if mags is not None and pcfg.magazines is None:
        raise ValueError("mags given but pcfg has no MagazineConfig")
    if impl == "reference":
        if mags is None:
            return pool_wavefront_step(
                pcfg, trees, free_nodes, free_shard, free_active, levels,
                active, max_rounds, lane_ids,
            )
        return pool_wavefront_step_mag(
            pcfg, trees, mags, free_nodes, free_shard, free_active,
            levels, active, max_rounds, lane_ids, free_mag_lane,
            alloc_mag_lane,
        )
    S = pcfg.n_shards
    home = home_shard(pcfg, lane_ids)
    shard = home
    pending = active
    nodes = jnp.zeros(K, dtype=jnp.int32)
    out_shard = shard
    fa = free_active
    mag_got = jnp.zeros(K, bool)
    f_spills = jnp.int32(0)
    n_stashed = jnp.int32(0)
    if mags is not None:
        # stash pre-pass: recycle freed leaf handles lane-locally; the
        # drop-through mask `fa` feeds the first launch's merged release
        if free_mag_lane is None:
            free_mag_lane = jnp.full(free_nodes.shape[0], -1, jnp.int32)
        mags, fa, stashed, f_spills = _mag_stash_phase(
            pcfg, trees, mags, free_nodes, free_shard, fa, free_mag_lane
        )
        n_stashed = stashed.sum(dtype=jnp.int32)
        # claim phase: leaf-octave lanes pop their magazines and skip
        # the launches entirely; misses stay pending
        if alloc_mag_lane is None:
            alloc_mag_lane = jnp.full(K, -1, jnp.int32)
        want = pending & (levels == pcfg.tree.depth)
        mags, gids, mag_got, _ = magmod.mag_claim(
            pcfg.magazines, mags, want, alloc_mag_lane
        )
        g_sh, g_nd = _gid_parts(pcfg, gids)
        nodes = jnp.where(mag_got, g_nd, nodes)
        out_shard = jnp.where(mag_got, g_sh, out_shard)
        pending = pending & ~mag_got
    # aggregation slots come from the same schema tuple the kernel
    # packs its per-shard stat rows with — neither side can drift
    agg = {name: jnp.int32(0) for name in POOL_STEP_SLOTS}
    for _ in range(S):
        trees, n_a, ok_a, st = pool_wavefront_step_pallas(
            pcfg,
            trees,
            free_nodes,
            free_shard,
            fa,
            levels,
            shard,
            max_rounds,
            active=pending,
            interpret=(impl == "interpret"),
        )
        won = pending & ok_a
        nodes = jnp.where(won, n_a, nodes)
        out_shard = jnp.where(won, shard, out_shard)
        pending = pending & ~ok_a
        shard = jnp.where(pending, (shard + 1) % S, shard)
        named = unpack_slots(POOL_STEP_SLOTS, st)  # [S] column per slot
        for name in POOL_STEP_SLOTS:
            # shards run concurrently within a launch: rounds is the
            # max row; every other slot sums across shards
            red = named[name].max() if name == "rounds" else named[name].sum()
            agg[name] = agg[name] + red
        fa = jnp.zeros_like(free_active)  # frees apply on the first launch
        # early exit is an eager-mode optimization only: under jit
        # `pending` is a tracer and the loop simply runs all S launches
        if not isinstance(pending, jax.core.Tracer) and not bool(
            pending.any()
        ):
            break
    if mags is not None:
        # exhaustion spill-back + retry: one merged release of every
        # stashed page, then failed lanes rerun on the reference
        # wavefront (the rare slow path; launches stay magazine-free)
        failed = active & ~(nodes > 0)
        do_spill = failed.any() & (magmod.mag_total(mags) > 0)

        def spill(args):
            return _mag_spill_all(pcfg, *args)

        def no_spill(args):
            trees, mags = args
            z = jnp.int32(0)
            return trees, mags, z, z, z

        trees, mags, sp_m, sp_l, n_spill = jax.lax.cond(
            do_spill, spill, no_spill, (trees, mags)
        )
        retry = failed & do_spill
        trees, n2, s2, ok2, rstats = pool_wavefront_alloc(
            pcfg, trees, levels, retry, max_rounds, lane_ids
        )
        won2 = retry & ok2
        nodes = jnp.where(won2, n2, nodes)
        out_shard = jnp.where(won2, s2, out_shard)
        agg["rounds"] = agg["rounds"] + rstats["rounds"]
        agg["merged_writes"] = (
            agg["merged_writes"] + rstats["merged_writes"] + sp_m
        )
        agg["logical_rmws"] = (
            agg["logical_rmws"] + rstats["logical_rmws"] + sp_l
        )
        agg["fastpath_hits"] = (
            agg["fastpath_hits"] + rstats["fastpath_hits"]
        )
        agg["freed"] = agg["freed"] + n_stashed
        agg["magazine_hits"] = mag_got.sum(dtype=jnp.int32)
        agg["magazine_spills"] = f_spills + n_spill
    ok = nodes > 0
    agg["free_writes"] = agg["free_merged_writes"]  # legacy alias
    # a magazine pop serves a lane off the popped page's recorded
    # shard — recycling, not an overflow probe
    agg["overflows"] = (
        (ok & ~mag_got & (out_shard != home)).sum(dtype=jnp.int32)
    )
    if pcfg.fastpath is None:
        fast_total = jnp.int32(0)
    else:
        fast = levels == fastpath.fp_level(pcfg.tree, pcfg.fastpath)
        fast_total = (active & fast).sum(dtype=jnp.int32)
        if fastpath.fp_level(pcfg.tree, pcfg.fastpath) == pcfg.tree.depth:
            # magazine-served lanes never reached the slab
            fast_total = fast_total - mag_got.sum(dtype=jnp.int32)
    agg["fastpath_spills"] = fast_total - agg["fastpath_hits"]
    if mags is not None:
        return trees, mags, nodes, out_shard, ok, agg
    return trees, nodes, out_shard, ok, agg
