"""Public kernel ops: backend dispatch + differentiability.

Selection policy (`impl`):
  "auto"      — Pallas/Mosaic on TPU backends, pure-jnp reference
                otherwise (XLA CPU/GPU cannot lower Mosaic kernels;
                the dry-run lowers the reference path — identical math,
                verified allclose by the kernel test sweeps).
  "pallas"    — compiled Pallas (TPU runtime).
  "interpret" — Pallas interpret mode (CPU validation; slow).
  "reference" — pure-jnp oracle.

`flash_attention` is differentiable: forward may use the fused kernel,
backward recomputes through the reference (identical math -> exact
gradients w.r.t. the reference function).

The NBBS dispatchers are tree-layout-agnostic: the `cfg`/`pcfg` they
take carries its `TreeLayout` (docs/design.md §3), and every impl path
— reference, interpret, pallas — runs the same layout-parameterized
round bodies, so packed and unpacked configs dispatch identically.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import fastpath
from repro.core.concurrent import TreeConfig, wavefront_step
from repro.core.pool import PoolConfig, home_shard, pool_wavefront_step
from repro.kernels import ref as kref
from repro.kernels.flash_attention import flash_attention_fwd
from repro.kernels.nbbs_alloc import (
    pool_wavefront_step_pallas,
    wavefront_alloc_pallas,
    wavefront_step_pallas,
)
from repro.kernels.paged_attention import paged_attention as paged_attention_pallas
from repro.obs.schema import (
    POOL_STEP_SLOTS,
    WAVEFRONT_ALLOC_SLOTS,
    WAVEFRONT_STEP_SLOTS,
    unpack_slots,
)

Array = jax.Array


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "reference"


def _resolve(impl: str) -> str:
    return default_impl() if impl == "auto" else impl


# ---------------------------------------------------------------------------
# Flash attention (differentiable)
# ---------------------------------------------------------------------------


@functools.partial(
    jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7)
)
def _flash_attention(q, k, v, causal, window, softcap, scale, impl):
    if impl == "reference":
        return kref.mha_reference(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale
        )
    return flash_attention_fwd(
        q,
        k,
        v,
        causal=causal,
        window=window,
        softcap=softcap,
        scale=scale,
        interpret=(impl == "interpret"),
    )


def _flash_fwd(q, k, v, causal, window, softcap, scale, impl):
    out = _flash_attention(q, k, v, causal, window, softcap, scale, impl)
    return out, (q, k, v)


def _flash_bwd(causal, window, softcap, scale, impl, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: kref.mha_reference(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale
        ),
        q,
        k,
        v,
    )
    return vjp(g)


_flash_attention.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: Array,
    k: Array,
    v: Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    impl: str = "auto",
) -> Array:
    """Differentiable attention. q:[B,Hq,S,D], k/v:[B,Hkv,Sk,D]."""
    return _flash_attention(
        q, k, v, causal, window, softcap, scale, _resolve(impl)
    )


# ---------------------------------------------------------------------------
# Paged decode attention (inference only — no vjp needed)
# ---------------------------------------------------------------------------


def paged_attention(
    q: Array,
    k_pages: Array,
    v_pages: Array,
    block_tables: Array,
    context_lens: Array,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    impl: str = "auto",
) -> Array:
    impl = _resolve(impl)
    if impl == "reference":
        return kref.paged_attention_reference(
            q,
            k_pages,
            v_pages,
            block_tables,
            context_lens,
            softcap=softcap,
            scale=scale,
        )
    return paged_attention_pallas(
        q,
        k_pages,
        v_pages,
        block_tables,
        context_lens,
        softcap=softcap,
        scale=scale,
        interpret=(impl == "interpret"),
    )


# ---------------------------------------------------------------------------
# NBBS wavefront allocation
# ---------------------------------------------------------------------------


def nbbs_wavefront_alloc(
    cfg: TreeConfig,
    tree: Array,
    levels: Array,
    *,
    active: Array | None = None,
    max_rounds: int = 64,
    impl: str = "auto",
):
    """Returns (tree, nodes, ok, stats-dict)."""
    impl = _resolve(impl)
    if impl == "reference":
        if active is None:
            active = jnp.ones(levels.shape, dtype=bool)
        return kref.nbbs_wavefront_reference(
            cfg, tree, levels, active, max_rounds
        )
    tree, nodes, ok, stats = wavefront_alloc_pallas(
        cfg,
        tree,
        levels,
        max_rounds,
        active=active,
        interpret=(impl == "interpret"),
    )
    # name the positional kernel row through the shared schema order
    return tree, nodes, ok, unpack_slots(WAVEFRONT_ALLOC_SLOTS, stats)


def nbbs_wavefront_step(
    cfg: TreeConfig,
    tree: Array,
    free_nodes: Array,
    free_active: Array,
    levels: Array,
    *,
    active: Array | None = None,
    max_rounds: int = 64,
    impl: str = "auto",
):
    """Mixed release+allocation round (frees via the merged vectorized
    pass, then the alloc wavefront).  Returns (tree, nodes, ok, stats)."""
    impl = _resolve(impl)
    if active is None:
        active = jnp.ones(levels.shape, dtype=bool)
    if impl == "reference":
        return wavefront_step(
            cfg, tree, free_nodes, free_active, levels, active, max_rounds
        )
    tree, nodes, ok, stats = wavefront_step_pallas(
        cfg,
        tree,
        free_nodes,
        free_active,
        levels,
        max_rounds,
        active=active,
        interpret=(impl == "interpret"),
    )
    out = unpack_slots(WAVEFRONT_STEP_SLOTS, stats)
    out["free_writes"] = out["free_merged_writes"]  # legacy alias
    return tree, nodes, ok, out


def nbbs_pool_wavefront_step(
    pcfg: PoolConfig,
    trees: Array,
    free_nodes: Array,
    free_shard: Array,
    free_active: Array,
    levels: Array,
    *,
    lane_ids: Array | None = None,
    active: Array | None = None,
    max_rounds: int = 64,
    impl: str = "auto",
):
    """Pooled mixed release+allocation step across S sharded trees.

    "reference" runs the in-graph lockstep router (`pool_wavefront_step`
    — lanes re-route between pool rounds).  The Pallas paths launch the
    grid-over-shards kernel once per probe attempt: every launch keeps
    one shard's tree VMEM-resident per program, and lanes whose shard is
    exhausted are re-routed to the next shard in the pool's fixed probe
    order before the next launch (an attempt-granular linearization of
    the same routing; identical to the reference whenever no lane
    overflows).  Returns (trees, nodes, shard, ok, stats).
    """
    impl = _resolve(impl)
    K = levels.shape[0]
    if active is None:
        active = jnp.ones(levels.shape, dtype=bool)
    if lane_ids is None:
        lane_ids = jnp.arange(K, dtype=jnp.int32)
    if impl == "reference":
        return pool_wavefront_step(
            pcfg, trees, free_nodes, free_shard, free_active, levels,
            active, max_rounds, lane_ids,
        )
    S = pcfg.n_shards
    home = home_shard(pcfg, lane_ids)
    shard = home
    pending = active
    nodes = jnp.zeros(K, dtype=jnp.int32)
    out_shard = shard
    fa = free_active
    # aggregation slots come from the same schema tuple the kernel
    # packs its per-shard stat rows with — neither side can drift
    agg = {name: jnp.int32(0) for name in POOL_STEP_SLOTS}
    for _ in range(S):
        trees, n_a, ok_a, st = pool_wavefront_step_pallas(
            pcfg,
            trees,
            free_nodes,
            free_shard,
            fa,
            levels,
            shard,
            max_rounds,
            active=pending,
            interpret=(impl == "interpret"),
        )
        won = pending & ok_a
        nodes = jnp.where(won, n_a, nodes)
        out_shard = jnp.where(won, shard, out_shard)
        pending = pending & ~ok_a
        shard = jnp.where(pending, (shard + 1) % S, shard)
        named = unpack_slots(POOL_STEP_SLOTS, st)  # [S] column per slot
        for name in POOL_STEP_SLOTS:
            # shards run concurrently within a launch: rounds is the
            # max row; every other slot sums across shards
            red = named[name].max() if name == "rounds" else named[name].sum()
            agg[name] = agg[name] + red
        fa = jnp.zeros_like(free_active)  # frees apply on the first launch
        # early exit is an eager-mode optimization only: under jit
        # `pending` is a tracer and the loop simply runs all S launches
        if not isinstance(pending, jax.core.Tracer) and not bool(
            pending.any()
        ):
            break
    ok = nodes > 0
    agg["free_writes"] = agg["free_merged_writes"]  # legacy alias
    agg["overflows"] = (ok & (out_shard != home)).sum(dtype=jnp.int32)
    if pcfg.fastpath is None:
        fast_total = jnp.int32(0)
    else:
        fast = levels == fastpath.fp_level(pcfg.tree, pcfg.fastpath)
        fast_total = (active & fast).sum(dtype=jnp.int32)
    agg["fastpath_spills"] = fast_total - agg["fastpath_hits"]
    return trees, nodes, out_shard, ok, agg
