"""Pallas TPU kernel: wavefront NBBS allocation with the tree in VMEM.

The paper's hot path is the alloc/free critical section: on x86 each
climb step is an atomic RMW that takes a cache line exclusive (§III-D).
On TPU the equivalent cost model is HBM round-trips per tree-word
update.  This kernel removes them entirely: the whole tree state
lives in VMEM for the duration of a wavefront (a 2^19-node tree is
2 MiB of int32 unpacked; with `TreeConfig(layout=BUNCH_PACKED)` the
VMEM-resident state is the §III-D packed bunch words — ~1/7 the word
count, uint32 — and the merged climb touches ~B x fewer words, see
`core/layout.py`), and every arbitration round is a handful of
full-tree VPU passes:

  round =  top-down ancestor-OCC propagation        (d vector steps)
         + per-level rank/prefix-sum assignment      (d cumsums)
         + min-id conflict propagation up + down     (2d vector steps)
         + merged occupancy climb                    (d vector steps)

i.e. O(depth) (8,128)-lane vector ops per round regardless of how many
requests commit — the vector-width limit of the paper's "one CAS per
level per thread" cost model.  The round body is `alloc_round` /
`free_round` shared verbatim with `core/concurrent.py`, so the kernels
are layout-agnostic too: block shapes come from `cfg.n_state_words` /
`cfg.state_dtype`, and under `BunchPacked` the winner/freed commit
passes write bunch-leaf range masks into packed words instead of
per-node masks.

The mixed entry point (`wavefront_step_pallas`) prepends the merged
release pass (`free_round`): a whole burst of frees costs one O(depth)
sweep — no retry rounds, since meeting-point conflicts are resolved by
the bottom-up sub-tree-occupancy OR — before the allocation rounds run,
all while the tree stays VMEM-resident.

The pooled entry point (`pool_wavefront_step_pallas`) extends this to
the sharded pool of `core/pool.py`: the grid iterates over shards, each
program pulls exactly one shard's tree into VMEM (BlockSpec row slice of
the stacked [S, n_state_words] array) and runs the full mixed step for the
lanes routed to that shard (shard-membership masks computed in-kernel
from `pl.program_id`).  Overflow probing happens *between* kernel
launches (the `ops.nbbs_pool_wavefront_step` driver re-routes failed
lanes to the next shard in the pool's fixed probe order), so each
launch keeps the single-shard VMEM residency property; the in-graph
lockstep router of `core/pool.py` is the oracle whenever no overflow
occurs, and the attempt-granular linearization here is one of the pool's
legal linearizations otherwise.

Grid: a single program; rounds run as a bounded fori_loop inside the
kernel (conflict losers retry exactly like failed CAS).  BlockSpecs map
the full tree / request vectors into VMEM — the deliberate tiling
decision here is *no tiling*: climbs need random access to all levels,
which is precisely why the tree must be VMEM-resident (HBM-blocked
variants would pay a round-trip per level, reproducing the x86 cache
line ping-pong the paper fights).

Mosaic-lowering caveat (documented per docs/design.md §6): the round body
uses one scatter (winner commit) and K-length gathers (arbitration
reads); these lower on interpret mode (our validation path on this
CPU-only container) and current Mosaic dynamic-gather support; the
jnp reference (`core/concurrent.py`, shared verbatim via
`alloc_round`) is the fallback implementation on any backend.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core import fastpath
from repro.core.concurrent import TreeConfig, alloc_round, free_round
from repro.core.pool import PoolConfig
from repro.obs.schema import (
    POOL_STEP_SLOTS,
    WAVEFRONT_ALLOC_SLOTS,
    WAVEFRONT_STEP_SLOTS,
    pack_slots,
)

Array = jax.Array


def _wavefront_kernel(
    cfg: TreeConfig,
    max_rounds: int,
    tree_ref,
    levels_ref,
    active_ref,
    tree_out_ref,
    nodes_ref,
    stats_ref,
):
    tree = tree_ref[...]
    levels = levels_ref[...]
    pending = active_ref[...] != 0
    K = levels.shape[0]
    nodes = jnp.zeros((K,), dtype=jnp.int32)

    def body(_, carry):
        tree, nodes, pending, rounds, merged, logical = carry
        live = pending.any()

        def run(args):
            tree, nodes, pending, rounds, merged, logical = args
            tree, nodes, pending, m, l, _ = alloc_round(
                cfg, tree, levels, pending, nodes
            )
            return tree, nodes, pending, rounds + 1, merged + m, logical + l

        return lax.cond(
            live, run, lambda a: a, (tree, nodes, pending, rounds, merged, logical)
        )

    tree, nodes, pending, rounds, merged, logical = lax.fori_loop(
        0,
        max_rounds,
        body,
        (tree, nodes, pending, jnp.int32(0), jnp.int32(0), jnp.int32(0)),
    )
    tree_out_ref[...] = tree
    nodes_ref[...] = nodes
    # slot order is the schema's, not this file's (tests/test_obs.py)
    stats_ref[...] = pack_slots(WAVEFRONT_ALLOC_SLOTS, {
        "rounds": rounds,
        "merged_writes": merged,
        "logical_rmws": logical,
    })


def _wavefront_step_kernel(
    cfg: TreeConfig,
    max_rounds: int,
    tree_ref,
    free_nodes_ref,
    free_active_ref,
    levels_ref,
    active_ref,
    tree_out_ref,
    nodes_ref,
    stats_ref,
):
    """Mixed round: the merged release pass (one O(depth) sweep — frees
    never need retry rounds), then the allocation wavefront, all with the
    tree VMEM-resident for the whole step."""
    tree = tree_ref[...]
    tree, free_merged, free_logical, freed = free_round(
        cfg, tree, free_nodes_ref[...], free_active_ref[...] != 0
    )
    n_freed = freed.sum(dtype=jnp.int32)

    levels = levels_ref[...]
    pending = active_ref[...] != 0
    K = levels.shape[0]
    nodes = jnp.zeros((K,), dtype=jnp.int32)

    def body(_, carry):
        tree, nodes, pending, rounds, merged, logical = carry
        live = pending.any()

        def run(args):
            tree, nodes, pending, rounds, merged, logical = args
            tree, nodes, pending, m, l, _ = alloc_round(
                cfg, tree, levels, pending, nodes
            )
            return tree, nodes, pending, rounds + 1, merged + m, logical + l

        return lax.cond(
            live, run, lambda a: a, (tree, nodes, pending, rounds, merged, logical)
        )

    tree, nodes, pending, rounds, merged, logical = lax.fori_loop(
        0,
        max_rounds,
        body,
        (tree, nodes, pending, jnp.int32(0), jnp.int32(0), jnp.int32(0)),
    )
    tree_out_ref[...] = tree
    nodes_ref[...] = nodes
    stats_ref[...] = pack_slots(WAVEFRONT_STEP_SLOTS, {
        "rounds": rounds,
        "merged_writes": merged,
        "logical_rmws": logical,
        "free_merged_writes": free_merged,
        "free_logical_rmws": free_logical,
        "freed": n_freed,
    })


@functools.partial(
    jax.jit, static_argnames=("cfg", "max_rounds", "interpret")
)
def wavefront_step_pallas(
    cfg: TreeConfig,
    tree: Array,
    free_nodes: Array,
    free_active: Array,
    levels: Array,
    max_rounds: int = 64,
    *,
    active: Array | None = None,
    interpret: bool = True,
) -> Tuple[Array, Array, Array, Array]:
    """Mixed alloc+free Pallas entry point.

    Returns (tree, nodes, ok, stats[6]) with stats = [alloc_rounds,
    alloc_merged, alloc_logical, free_merged, free_logical, freed].
    """
    if active is None:
        active = jnp.ones(levels.shape, dtype=jnp.int32)
    else:
        active = active.astype(jnp.int32)
    K = levels.shape[0]
    F = free_nodes.shape[0]
    kernel = functools.partial(_wavefront_step_kernel, cfg, max_rounds)
    tree_out, nodes, stats = pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((cfg.n_state_words,), cfg.state_dtype),
            jax.ShapeDtypeStruct((K,), jnp.int32),
            jax.ShapeDtypeStruct((len(WAVEFRONT_STEP_SLOTS),), jnp.int32),
        ],
        in_specs=[
            pl.BlockSpec((cfg.n_state_words,), lambda: (0,)),  # tree state in VMEM
            pl.BlockSpec((F,), lambda: (0,)),
            pl.BlockSpec((F,), lambda: (0,)),
            pl.BlockSpec((K,), lambda: (0,)),
            pl.BlockSpec((K,), lambda: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((cfg.n_state_words,), lambda: (0,)),
            pl.BlockSpec((K,), lambda: (0,)),
            pl.BlockSpec((len(WAVEFRONT_STEP_SLOTS),), lambda: (0,)),
        ],
        grid=(),
        interpret=interpret,
    )(
        tree,
        free_nodes.astype(jnp.int32),
        free_active.astype(jnp.int32),
        levels.astype(jnp.int32),
        active,
    )
    return tree_out, nodes, nodes > 0, stats


def _pool_step_kernel(
    pcfg: PoolConfig,
    max_rounds: int,
    trees_ref,
    free_nodes_ref,
    free_shard_ref,
    free_active_ref,
    levels_ref,
    alloc_shard_ref,
    active_ref,
    trees_out_ref,
    nodes_ref,
    stats_ref,
):
    """One shard's mixed step (grid axis 0 = shard).  The program sees
    only its own tree (VMEM row slice) plus the full lane vectors, and
    masks lanes by shard membership — the Pallas analogue of the
    vmapped per-shard round in `core/pool.py`.

    With a fastpath configured the shard's slab bitmap words ride in
    the same VMEM row (appended after the tree state): frees route by
    node range before the merged tree release, and every alloc
    iteration probes the slab (single-RMW claim) before the buddy
    round, exactly like the reference pool."""
    s = pl.program_id(0)
    cfg = pcfg.tree
    fp = pcfg.fastpath
    TW = cfg.n_state_words
    row = trees_ref[0]
    tree, slab = row[:TW], row[TW:]
    fmask_all = (free_active_ref[...] != 0) & (free_shard_ref[...] == s)
    free_nodes = free_nodes_ref[...]
    if fp is not None:
        slab_leaf = fastpath.in_slab_leaf(cfg, fp, free_nodes)
        junk = fastpath.in_carved_junk(cfg, fp, free_nodes)
        slab, sl_freed, sl_merged, sl_logical = fastpath.slab_release(
            cfg, fp, slab, free_nodes, fmask_all & slab_leaf
        )
        fmask = fmask_all & ~slab_leaf & ~junk
    else:
        sl_freed = jnp.zeros_like(fmask_all)
        sl_merged = sl_logical = jnp.int32(0)
        fmask = fmask_all
    tree, free_merged, free_logical, freed = free_round(
        cfg, tree, free_nodes, fmask
    )
    n_freed = freed.sum(dtype=jnp.int32) + sl_freed.sum(dtype=jnp.int32)
    free_merged = free_merged + sl_merged
    free_logical = free_logical + sl_logical

    levels = levels_ref[...]
    pending = (active_ref[...] != 0) & (alloc_shard_ref[...] == s)
    K = levels.shape[0]
    nodes = jnp.zeros((K,), dtype=jnp.int32)

    def body(_, carry):
        tree, slab, nodes, pending, rounds, merged, logical, hits = carry
        live = pending.any()

        def run(args):
            tree, slab, nodes, pending, rounds, merged, logical, hits = args
            if fp is not None:
                want = pending & (levels == fastpath.fp_level(cfg, fp))
                slab, n_fp, got, m_fp, h = fastpath.slab_claim(
                    cfg, fp, slab, want
                )
                nodes = jnp.where(got, n_fp, nodes)
                pending = pending & ~got
                merged, logical = merged + m_fp, logical + h
                hits = hits + h
            tree, nodes, pending, m, l, _ = alloc_round(
                cfg, tree, levels, pending, nodes
            )
            return (
                tree, slab, nodes, pending,
                rounds + 1, merged + m, logical + l, hits,
            )

        return lax.cond(
            live, run, lambda a: a,
            (tree, slab, nodes, pending, rounds, merged, logical, hits),
        )

    tree, slab, nodes, pending, rounds, merged, logical, hits = lax.fori_loop(
        0,
        max_rounds,
        body,
        (
            tree, slab, nodes, pending,
            jnp.int32(0), jnp.int32(0), jnp.int32(0), jnp.int32(0),
        ),
    )
    trees_out_ref[0] = (
        jnp.concatenate([tree, slab]) if fp is not None else tree
    )
    nodes_ref[0] = nodes
    # the magazine slots are structurally zero here: magazines are
    # per-lane state shared across shards, so the claim/stash phases
    # run in the `ops.nbbs_pool_wavefront_step` driver around the
    # launches (that driver fills these slots in its aggregate row)
    stats_ref[0] = pack_slots(POOL_STEP_SLOTS, {
        "rounds": rounds,
        "merged_writes": merged,
        "logical_rmws": logical,
        "free_merged_writes": free_merged,
        "free_logical_rmws": free_logical,
        "freed": n_freed,
        "fastpath_hits": hits,
        "magazine_hits": jnp.int32(0),
        "magazine_spills": jnp.int32(0),
        "magazine_refills": jnp.int32(0),
    })


@functools.partial(
    jax.jit, static_argnames=("pcfg", "max_rounds", "interpret")
)
def pool_wavefront_step_pallas(
    pcfg: PoolConfig,
    trees: Array,
    free_nodes: Array,
    free_shard: Array,
    free_active: Array,
    levels: Array,
    alloc_shard: Array,
    max_rounds: int = 64,
    *,
    active: Array | None = None,
    interpret: bool = True,
) -> Tuple[Array, Array, Array, Array]:
    """Pooled mixed alloc+free Pallas entry point (grid over shards).

    Each lane allocates on `alloc_shard[k]` and each free lands on
    `free_shard[f]`; overflow re-routing across launches is the caller's
    job (`ops.nbbs_pool_wavefront_step`).  Returns (trees, nodes, ok,
    stats[S, len(POOL_STEP_SLOTS)]) with per-shard stats rows in
    POOL_STEP_SLOTS order — [alloc_rounds, alloc_merged, alloc_logical,
    free_merged, free_logical, freed, fastpath_hits, magazine_hits,
    magazine_spills, magazine_refills]; fastpath_hits is 0 without a
    configured fastpath and the magazine slots are always 0 (filled by
    the driver, see `_pool_step_kernel`).
    """
    if active is None:
        active = jnp.ones(levels.shape, dtype=jnp.int32)
    else:
        active = active.astype(jnp.int32)
    S = pcfg.n_shards
    K = levels.shape[0]
    F = free_nodes.shape[0]
    kernel = functools.partial(_pool_step_kernel, pcfg, max_rounds)
    trees_out, nodes_s, stats = pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((S, pcfg.n_state_words), pcfg.tree.state_dtype),
            jax.ShapeDtypeStruct((S, K), jnp.int32),
            jax.ShapeDtypeStruct((S, len(POOL_STEP_SLOTS)), jnp.int32),
        ],
        in_specs=[
            pl.BlockSpec((1, pcfg.n_state_words), lambda s: (s, 0)),  # own shard tree
            pl.BlockSpec((F,), lambda s: (0,)),
            pl.BlockSpec((F,), lambda s: (0,)),
            pl.BlockSpec((F,), lambda s: (0,)),
            pl.BlockSpec((K,), lambda s: (0,)),
            pl.BlockSpec((K,), lambda s: (0,)),
            pl.BlockSpec((K,), lambda s: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, pcfg.n_state_words), lambda s: (s, 0)),
            pl.BlockSpec((1, K), lambda s: (s, 0)),
            pl.BlockSpec((1, len(POOL_STEP_SLOTS)), lambda s: (s, 0)),
        ],
        grid=(S,),
        interpret=interpret,
    )(
        trees,
        free_nodes.astype(jnp.int32),
        free_shard.astype(jnp.int32),
        free_active.astype(jnp.int32),
        levels.astype(jnp.int32),
        alloc_shard.astype(jnp.int32),
        active,
    )
    # a lane is routed to exactly one shard, so at most one row is non-zero
    nodes = nodes_s.max(axis=0)
    return trees_out, nodes, nodes > 0, stats


@functools.partial(
    jax.jit, static_argnames=("cfg", "max_rounds", "interpret")
)
def wavefront_alloc_pallas(
    cfg: TreeConfig,
    tree: Array,
    levels: Array,
    max_rounds: int = 64,
    *,
    active: Array | None = None,
    interpret: bool = True,
) -> Tuple[Array, Array, Array, Array]:
    """Pallas entry point. Returns (tree, nodes, ok, stats[3]).

    `interpret=True` is the validation mode on CPU (kernel body executed
    in Python); on a TPU runtime pass interpret=False to lower via
    Mosaic.
    """
    if active is None:
        active = jnp.ones(levels.shape, dtype=jnp.int32)
    else:
        active = active.astype(jnp.int32)
    K = levels.shape[0]
    kernel = functools.partial(_wavefront_kernel, cfg, max_rounds)
    tree_out, nodes, stats = pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((cfg.n_state_words,), cfg.state_dtype),
            jax.ShapeDtypeStruct((K,), jnp.int32),
            jax.ShapeDtypeStruct((len(WAVEFRONT_ALLOC_SLOTS),), jnp.int32),
        ],
        in_specs=[
            pl.BlockSpec((cfg.n_state_words,), lambda: (0,)),  # tree state in VMEM
            pl.BlockSpec((K,), lambda: (0,)),
            pl.BlockSpec((K,), lambda: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((cfg.n_state_words,), lambda: (0,)),
            pl.BlockSpec((K,), lambda: (0,)),
            pl.BlockSpec((len(WAVEFRONT_ALLOC_SLOTS),), lambda: (0,)),
        ],
        grid=(),
        interpret=interpret,
    )(tree, levels.astype(jnp.int32), active)
    return tree_out, nodes, nodes > 0, stats
