"""memory substrate."""
