"""Paged KV-cache management on the non-blocking buddy system.

This is where the paper's contribution becomes a first-class framework
feature: the serving engine's KV page pool is managed by the NBBS
(host-side: the paper-faithful `NBBSRef`; burst admission: the jnp
wavefront — the same data structure, so both views stay coherent).

Design points (docs/design.md §2):
  * a sequence's KV cache is a list of buddy *runs* — power-of-two
    contiguous page spans.  Growth allocates a run of the current run
    size (doubling), so a sequence of T tokens holds O(log T) runs and
    its block table is a concatenation of contiguous id ranges (large
    DMA-friendly spans for the paged-attention kernel);
  * admission control is allocation success: when the buddy cannot
    serve a run, the scheduler queues the request instead of thrashing
    (fragmentation is visible in O(1) through the status-bit tree);
  * frees coalesce automatically (paper §III-C), so long-lived serving
    does not degrade — the property the Constant Occupancy benchmark
    measures;
  * with `n_shards > 1` the page pool is split across S replicated
    buddy trees (the host mirror of `core/pool.py`): a sequence's home
    shard is the Fibonacci hash of its id, admission probes shards in
    the fixed cyclic order home, home+1, …, and the serving shard is
    recorded in `SeqAlloc.shard` so a burst release frees per-shard —
    one `free_round`-equivalent burst per shard, never a cross-shard
    scan.

Invariants (deep-linked from docs/architecture.md):

  * page-id numbering: shard s owns the global page ids
    [s * pages_per_shard, (s+1) * pages_per_shard); each shard's
    `NBBSRef` is constructed with that `base_address`, so every address
    it returns is already a global page id and block tables are
    shard-agnostic;
  * a sequence's runs all live on its recorded shard (`SeqAlloc.shard`)
    — admission probes whole-sequence, growth never migrates — so
    `free_sequence(s)` is exactly one per-shard burst;
  * occupancy encoding inside each shard is the 5-bit status-bit tree
    of `core/bits.py`; occupancy/fragmentation introspection
    (`fragmentation`) is the per-shard O(tree) scan, reported per shard
    and pool-wide;
  * double frees cannot cross shards: a handle resolves through its own
    shard's index[] only (see `core/nbbs_jax.py` invariants for the
    arbitration rule on the device path).

Two host views live here (docs/design.md §8):

  * `PagedKVManager` — the run-granularity manager the host-driven
    `ServeEngine` allocates through (buddy runs, growth by doubling);
  * `PageOracle` — the page-granularity differential oracle of the
    *jit-resident* engine: per-shard `NBBSRef` trees driven through an
    exact host emulation of `core/pool.pool_wavefront_alloc`'s round
    semantics, handing out the same global page ids the device tables
    carry.  The jitted engine must match it bit-for-bit on page
    assignments and pool occupancy (tests/test_serving.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.bits import FIB_HASH, OCC  # host/device routing must agree
from repro.core.ref import NBBSRef, _ilog2


@dataclasses.dataclass
class SeqAlloc:
    seq_id: int
    runs: List[range]          # page-id ranges (global ids), in order
    n_tokens: int = 0
    shard: int = 0             # serving shard: all runs live here

    @property
    def n_pages(self) -> int:
        return sum(len(r) for r in self.runs)


class PagedKVManager:
    """Page-granularity KV allocator for the serving engine."""

    def __init__(
        self,
        num_pages: int,
        page_tokens: int,
        max_run_pages: Optional[int] = None,
        scattered: bool = True,
        n_shards: int = 1,
        layout: Optional[str] = None,
        fastpath: bool = False,
        fastpath_slab_level: int = 2,
        magazines: int = 0,
        magazine_refill: int = 0,
        mag_lanes: int = 16,
    ) -> None:
        if num_pages & (num_pages - 1):
            raise ValueError("num_pages must be a power of two")
        if n_shards < 1 or (n_shards & (n_shards - 1)):
            raise ValueError("n_shards must be a power of two >= 1")
        if num_pages % n_shards:
            raise ValueError("num_pages must divide evenly across shards")
        if layout not in (None, "unpacked", "bunch-packed"):
            raise ValueError(f"unknown tree layout {layout!r}")
        self.num_pages = num_pages
        self.page_tokens = page_tokens
        self.n_shards = n_shards
        # Device tree-state layout for the wavefront-backed admission
        # path (docs/design.md §3).  The host-side NBBSRef trees below
        # are layout-independent; this knob only shapes what
        # `device_pool_config()` exports, so handles — (shard, page id)
        # pairs — and the whole public API are unchanged.
        self.layout = layout or "unpacked"
        self.pages_per_shard = num_pages // n_shards
        self.max_run_pages = min(
            max_run_pages or num_pages, self.pages_per_shard
        )
        self.scattered = scattered
        # One allocation unit == one page; shard s serves global ids
        # [s * pages_per_shard, (s+1) * pages_per_shard) via base_address.
        self.buddies = [
            NBBSRef(
                self.pages_per_shard,
                1,
                max_size=self.max_run_pages,
                base_address=s * self.pages_per_shard,
            )
            for s in range(n_shards)
        ]
        # Per-lane magazines (host mirror of core/magazine.py): a
        # sequence group (`seq_id % mag_lanes`) keeps a small LIFO of
        # recently freed single pages and recycles them without
        # touching the slab or the tree.  Because this manager's
        # invariant is "a sequence's runs all live on its recorded
        # shard", the host magazines are *shard-local* stacks —
        # `_mags[lane][shard]`, capacity `magazines` each — a benign
        # divergence from the device's flat per-lane magazine
        # (docs/design.md §10): a cross-shard pop would migrate a run
        # off the sequence's shard.
        if magazines < 0 or magazine_refill < 0 or mag_lanes < 1:
            raise ValueError("bad magazine configuration")
        self.magazines = magazines
        self.magazine_refill = magazine_refill
        self.mag_lanes = mag_lanes
        self.magazine_hits = 0
        self.magazine_spills = 0
        self.magazine_refills = 0
        self._mags: List[List[List[int]]] = [
            [[] for _ in range(n_shards)] for _ in range(mag_lanes)
        ]
        # Fixed-size fast path (host mirror of core/fastpath.py): the
        # leftmost 1/2^slab_level of each shard is carved out of its
        # buddy tree at init and served as single pages from a bitmap.
        # Single-page runs claim a slab slot first and spill into the
        # buddy only when the slab is full; frees route by page-id
        # range.  Handles stay ordinary global page ids throughout.
        self.fastpath = fastpath
        self.fastpath_slab_level = fastpath_slab_level
        self.fastpath_hits = 0
        self.fastpath_spills = 0
        self._slab_free: List[np.ndarray] = []
        if fastpath:
            slab_pages = self.pages_per_shard >> fastpath_slab_level
            if slab_pages < 1:
                raise ValueError(
                    "fastpath slab_level too deep for "
                    f"{self.pages_per_shard} pages per shard"
                )
            self.slab_pages = slab_pages
            for s, buddy in enumerate(self.buddies):
                base = s * self.pages_per_shard
                got = 0
                while got < slab_pages:  # carve leftmost, contiguous
                    run = min(self.max_run_pages, slab_pages - got)
                    addr = buddy.nb_alloc(run, scattered=False)
                    assert addr == base + got, "carve must be leftmost"
                    got += run
                self._slab_free.append(np.ones(slab_pages, bool))
            self.device_pool_config()  # fail fast on bad slab geometry
        else:
            self.slab_pages = 0
        self.seqs: Dict[int, SeqAlloc] = {}

    def mag_lane(self, seq_id: int) -> int:
        """Magazine lane of a sequence (-1 with magazines off)."""
        return seq_id % self.mag_lanes if self.magazines else -1

    def mag_stashed(self) -> int:
        """Pages currently held across every magazine."""
        return sum(
            len(st) for lane in self._mags for st in lane
        )

    def _mag_spill_all(self) -> None:
        """Release every stashed page back to its shard (slab/tree
        routing) and empty the magazines — the host mirror of the
        pool's exhaustion spill-back burst."""
        for lane in self._mags:
            for s, stack in enumerate(lane):
                for p in stack:
                    self.magazine_spills += 1
                    local = p - s * self.pages_per_shard
                    if (
                        self.fastpath
                        and 0 <= local < self.slab_pages
                    ):
                        self._slab_free[s][local] = True
                    else:
                        self.buddies[s].nb_free(p)
                stack.clear()

    @property
    def buddy(self) -> NBBSRef:
        """The single tree of an unsharded pool (back-compat accessor)."""
        assert self.n_shards == 1, "sharded pool: use .buddies[s]"
        return self.buddies[0]

    def device_pool_config(self):
        """The device-side `core.pool.PoolConfig` mirroring this pool's
        geometry: S shards of a depth-log2(pages_per_shard) tree, one
        allocation unit per page, with the configured tree-state layout
        (`layout="bunch-packed"` gives the §III-D packed words — ~1/7
        the VMEM words, ~B x fewer climb writes; see `core/layout.py`).
        Burst admission through `core.nbbs_jax.nb_pool_alloc` /
        `kernels.ops.nbbs_pool_wavefront_step` on this config produces
        the same (shard, page) handles this host manager hands out."""
        from repro.core.concurrent import BUNCH_PACKED, TreeConfig, UNPACKED
        from repro.core.pool import PoolConfig

        from repro.core.fastpath import FastPathConfig

        tree = TreeConfig(
            depth=_ilog2(self.pages_per_shard),
            max_level=_ilog2(self.pages_per_shard // self.max_run_pages),
            layout=(
                BUNCH_PACKED if self.layout == "bunch-packed" else UNPACKED
            ),
        )
        fp = (
            FastPathConfig(level=None, slab_level=self.fastpath_slab_level)
            if self.fastpath
            else None
        )
        mcfg = None
        if self.magazines:
            from repro.core.magazine import MagazineConfig

            mcfg = MagazineConfig(
                mag_cap=self.magazines,
                refill_batch=self.magazine_refill,
            )
        return PoolConfig(tree, self.n_shards, fastpath=fp, magazines=mcfg)

    # ------------------------------------------------------------------
    def home_shard(self, seq_id: int) -> int:
        """Deterministic home shard of a sequence (Fibonacci hash, the
        same spread as `core/pool.home_shard` for device lanes)."""
        return ((seq_id * FIB_HASH) & 0xFFFFFFFF) % self.n_shards

    def pages_for_tokens(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.page_tokens))

    def _next_pow2(self, n: int) -> int:
        return 1 << (n - 1).bit_length()

    def _alloc_run(
        self, shard: int, run: int, mag_lane: int = -1
    ) -> Optional[range]:
        """One run on one shard: single-page runs pop the requester's
        magazine first (pure recycling, zero allocator work), then
        probe the fastpath slab, then take the buddy climb."""
        if self.magazines and run == 1 and mag_lane >= 0:
            stack = self._mags[mag_lane][shard]
            if not stack and self.magazine_refill:
                # Batched refill: pre-claim a burst of single pages
                # into the magazine so the next misses become pops
                # (one burst per refill, not one climb per page).
                room = min(
                    self.magazine_refill, self.magazines - len(stack)
                )
                for _ in range(room):
                    rr = self._alloc_run_raw(shard, 1)
                    if rr is None:
                        break
                    stack.append(rr.start)
                    self.magazine_refills += 1
            if stack:
                self.magazine_hits += 1
                page = stack.pop()
                return range(page, page + 1)
        return self._alloc_run_raw(shard, run)

    def _alloc_run_raw(self, shard: int, run: int) -> Optional[range]:
        """The magazine-oblivious slab-then-buddy path."""
        if self.fastpath and run == 1:
            free = np.flatnonzero(self._slab_free[shard])
            if len(free):
                slot = int(free[0])
                self._slab_free[shard][slot] = False
                self.fastpath_hits += 1
                page = shard * self.pages_per_shard + slot
                return range(page, page + 1)
            self.fastpath_spills += 1
        addr = self.buddies[shard].nb_alloc(run, scattered=self.scattered)
        if addr is None:
            return None
        return range(addr, addr + run)

    def _maybe_stash(self, shard: int, r: range, mag_lane: int) -> bool:
        """Try to park a single-page run in the requester's magazine
        instead of releasing it.  True = stashed (the page stays
        allocated in the slab/tree and is owned by the magazine); a
        full magazine counts a drop-through spill and falls back to
        the ordinary release routing."""
        if not self.magazines or mag_lane < 0 or len(r) != 1:
            return False
        stack = self._mags[mag_lane][shard]
        if len(stack) < self.magazines:
            stack.append(r.start)
            return True
        self.magazine_spills += 1
        return False

    def _free_run(self, shard: int, r: range, mag_lane: int = -1) -> None:
        """Release one run, routing by page-id range: single-page runs
        stash into the requester's magazine when there is room, pages
        under the shard's slab clear their bitmap bit, the rest free
        through the buddy (the host mirror of `pool_free_round_mag`'s
        stash-then-route)."""
        if self._maybe_stash(shard, r, mag_lane):
            return
        local = r.start - shard * self.pages_per_shard
        if self.fastpath and len(r) == 1 and 0 <= local < self.slab_pages:
            self._slab_free[shard][local] = True
            return
        self.buddies[shard].nb_free(r.start)

    def _try_admit_on(
        self, shard: int, need: int, mag_lane: int = -1
    ) -> Optional[List[range]]:
        """Allocate `need` pages worth of runs on one shard, or roll back
        and return None (an admission is all-on-one-shard or nothing).
        Rolled-back magazine-claimed pages go back to the *same lane's*
        magazine, leaving the tree untouched by the failed attempt."""
        runs: List[range] = []
        remaining = need
        while remaining:
            run = min(remaining, self.max_run_pages)
            r = self._alloc_run(shard, run, mag_lane)
            if r is None:
                for old in runs:  # roll back partial admission
                    self._free_run(shard, old, mag_lane)
                return None
            runs.append(r)
            remaining -= run
        return runs

    def add_sequence(self, seq_id: int, n_tokens: int) -> bool:
        """Admit a sequence with a prompt of n_tokens. False = pool full
        (the scheduler should queue/evict — admission control).

        Probes shards in the fixed order home, home+1, …, home+S-1: the
        first shard that can hold the whole sequence serves it (overflow
        routing, mirroring `core/pool.py`)."""
        assert seq_id not in self.seqs
        need = self._next_pow2(self.pages_for_tokens(max(n_tokens, 1)))
        if need > self.pages_per_shard:
            # Not "pool full" — the request exceeds the pool geometry
            # and no amount of waiting or probing can ever admit it.
            # Raising (instead of returning False) keeps an impossible
            # request from head-of-line blocking the scheduler forever.
            raise ValueError(
                f"sequence needs {need} pages but a shard holds only "
                f"{self.pages_per_shard} (num_pages={self.num_pages}, "
                f"n_shards={self.n_shards})"
            )
        home = self.home_shard(seq_id)
        lane = self.mag_lane(seq_id)
        for spill in range(2):
            for attempt in range(self.n_shards):
                shard = (home + attempt) % self.n_shards
                runs = self._try_admit_on(shard, need, lane)
                if runs is not None:
                    self.seqs[seq_id] = SeqAlloc(
                        seq_id, runs, n_tokens, shard=shard
                    )
                    return True
            # Every probe failed: pages parked in magazines may be the
            # only free capacity left.  Spill them all back (one burst)
            # and retry the probe sequence once — the host mirror of
            # the wavefront's exhaustion spill-back.
            if spill or not self.magazines or not self.mag_stashed():
                return False
            self._mag_spill_all()
        return False

    def append_tokens(self, seq_id: int, n_new: int = 1) -> bool:
        """Reserve space for n_new more tokens; grows by buddy doubling
        on the sequence's recorded shard (runs never migrate shards).
        On failure the sequence is left exactly as before the call: both
        n_tokens and any runs grown by earlier loop iterations are rolled
        back (a partially grown sequence would silently leak pages the
        token count never accounts for)."""
        s = self.seqs[seq_id]
        lane = self.mag_lane(seq_id)
        n_runs_before = len(s.runs)
        s.n_tokens += n_new
        while self.pages_for_tokens(s.n_tokens) > s.n_pages:
            grow = min(self._next_pow2(max(s.n_pages, 1)), self.max_run_pages)
            r = self._alloc_run(s.shard, grow, lane)
            if r is None:
                s.n_tokens -= n_new
                grown = s.runs[n_runs_before:]
                del s.runs[n_runs_before:]
                # Roll back to the *same lane's* magazine: a page that
                # was claimed from this sequence's magazine moments ago
                # must land back on it, not leak into the shared pool
                # (which would silently drain the lane's cache and
                # change the tree state of a failed, no-op call).
                self._free_runs(s.shard, grown, lane)
                return False
            s.runs.append(r)
        return True

    def _free_runs(
        self, shard: int, runs: List[range], mag_lane: int = -1
    ) -> None:
        """Release a burst of runs on one shard: single-page runs stash
        into the lane's magazine while it has room, slab pages clear
        their bitmap bits, the rest go back in one merged buddy burst."""
        buddy_addrs: List[int] = []
        for r in runs:
            if self._maybe_stash(shard, r, mag_lane):
                continue
            local = r.start - shard * self.pages_per_shard
            if (
                self.fastpath
                and len(r) == 1
                and 0 <= local < self.slab_pages
            ):
                self._slab_free[shard][local] = True
            else:
                buddy_addrs.append(r.start)
        if buddy_addrs:
            self.buddies[shard].nb_free_many(buddy_addrs)

    def free_sequence(self, seq_id: int) -> None:
        """Release a sequence: all of its runs go back in one burst call
        on its shard (one merged release pass on wavefront-backed pools);
        single-page runs recycle through the sequence's magazine lane."""
        s = self.seqs.pop(seq_id)
        self._free_runs(s.shard, s.runs, self.mag_lane(seq_id))

    def free_sequences(self, seq_ids: List[int]) -> None:
        """Batch eviction: release every run of every sequence, grouped
        by shard so each shard gets a single burst (one `free_round`
        each on wavefront-backed pools).  Validates the whole batch
        before mutating any state so an unknown id cannot strand
        already-popped sequences' pages."""
        unique = list(dict.fromkeys(seq_ids))
        missing = [i for i in unique if i not in self.seqs]
        if missing:
            raise KeyError(missing[0])
        per_shard: Dict[int, List[Tuple[range, int]]] = {}
        for seq_id in unique:
            s = self.seqs.pop(seq_id)
            lane = self.mag_lane(seq_id)
            per_shard.setdefault(s.shard, []).extend(
                (r, lane) for r in s.runs
            )
        for shard, pairs in per_shard.items():
            buddy_addrs: List[int] = []
            for r, lane in pairs:
                if self._maybe_stash(shard, r, lane):
                    continue
                local = r.start - shard * self.pages_per_shard
                if (
                    self.fastpath
                    and len(r) == 1
                    and 0 <= local < self.slab_pages
                ):
                    self._slab_free[shard][local] = True
                else:
                    buddy_addrs.append(r.start)
            if buddy_addrs:
                self.buddies[shard].nb_free_many(buddy_addrs)

    # ------------------------------------------------------------------
    def block_table(self, seq_id: int, max_pages: int) -> np.ndarray:
        """Flat page-id table, -1 padded, for the paged-attention kernel.
        Ids are global (shard base already folded in by `base_address`)."""
        s = self.seqs[seq_id]
        ids = [p for r in s.runs for p in r]
        used = self.pages_for_tokens(s.n_tokens)
        ids = ids[: max(used, 1)]
        out = np.full((max_pages,), -1, np.int32)
        out[: len(ids)] = ids
        return out

    def block_tables(self, seq_ids: List[int], max_pages: int) -> np.ndarray:
        return np.stack([self.block_table(s, max_pages) for s in seq_ids])

    # ------------------------------------------------------------------
    def free_pages(self) -> int:
        """Allocatable pages: slab + tree + magazine-stashed (a stashed
        page is allocated in the tree's eyes but instantly claimable,
        so capacity accounting must count it as free)."""
        slab = sum(int(f.sum()) for f in self._slab_free)
        return (
            slab
            + sum(b.free_bytes() for b in self.buddies)
            + self.mag_stashed()
        )

    def _mag_stashed_on(self, shard: int) -> int:
        return sum(len(lane[shard]) for lane in self._mags)

    def _largest_run_on(self, shard: int) -> int:
        best = _largest_free_run(self.buddies[shard], self.max_run_pages)
        if self.fastpath and self._slab_free[shard].any():
            best = max(best, 1)  # slab serves single pages only
        if self._mag_stashed_on(shard):
            best = max(best, 1)  # magazines serve single pages only
        return best

    def fragmentation(self) -> dict:
        """Occupancy + largest allocatable run (O(tree) introspection),
        pool-wide plus the per-shard breakdown."""
        free = self.free_pages()
        per_shard_largest = [
            self._largest_run_on(s) for s in range(self.n_shards)
        ]
        per_shard_free = [b.free_bytes() for b in self.buddies]
        if self.fastpath:
            per_shard_free = [
                n + int(f.sum())
                for n, f in zip(per_shard_free, self._slab_free)
            ]
        per_shard_free = [
            n + self._mag_stashed_on(s)
            for s, n in enumerate(per_shard_free)
        ]
        return {
            "free_pages": free,
            "used_pages": self.num_pages - free,
            "largest_run": max(per_shard_largest),
            "n_seqs": len(self.seqs),
            "runs_per_seq": (
                float(np.mean([len(s.runs) for s in self.seqs.values()]))
                if self.seqs
                else 0.0
            ),
            "per_shard_free": per_shard_free,
            "per_shard_largest_run": per_shard_largest,
            "fastpath_hits": self.fastpath_hits,
            "fastpath_spills": self.fastpath_spills,
            "magazine_hits": self.magazine_hits,
            "magazine_spills": self.magazine_spills,
            "magazine_refills": self.magazine_refills,
            "magazine_stashed": self.mag_stashed(),
        }

    def _occupied_ancestor(self, buddy: NBBSRef, n: int) -> bool:
        return _occupied_ancestor(buddy, n)


def _occupied_ancestor(buddy: NBBSRef, n: int) -> bool:
    from repro.core.bits import OCC

    n >>= 1
    while n >= 1:
        if buddy.tree[n] & OCC:
            return True
        n >>= 1
    return False


def _largest_free_run(buddy: NBBSRef, max_probe: int) -> int:
    """Largest allocatable run on one tree (non-destructive probe)."""
    from repro.core.bits import is_free

    probe = max_probe
    while probe >= 1:
        level = buddy.level_for_size(probe)
        base = 1 << level
        if any(
            is_free(buddy.tree[i]) and not _occupied_ancestor(buddy, i)
            for i in range(base, 2 * base)
        ):
            return probe
        probe //= 2
    return 0


# ---------------------------------------------------------------------------
# PageOracle: host differential oracle of the jit-resident engine pool
# ---------------------------------------------------------------------------


class PageOracle:
    """Leaf-only page allocator mirroring the jitted engine's in-graph
    pool, page by page.

    The jit-resident engine (`serve/jit_engine.py`) claims KV pages one
    leaf unit at a time through `pool_wavefront_alloc`.  This class
    drives per-shard `NBBSRef` trees through an *exact* host emulation
    of those pool rounds, so a host-driven replay of the same request
    trace must produce identical page ids and identical final trees:

      * each request's home shard is the Fibonacci hash of its lane id
        (`home_shard`, shared constant with `core/pool.py`);
      * per round, per shard, the routed requests allocate sequentially
        in lane order with first-fit leaf scans (`scattered=False`) —
        equivalent to the device round's rank/prefix-sum assignment,
        because allocating the rank-r allocatable leaf never changes the
        allocatability of leaves ranked above it;
      * a shard whose *first* attempted allocation of the round fails
        had zero allocatable leaves at round start — the device round's
        `exhausted` condition — so every request routed there advances
        its probe (`shard+1`, cyclic), failing after S probes.  A
        request that fails *after* wins on its shard merely lost
        arbitration and retries the same shard next round;
      * releases are burst frees grouped per shard (`nb_free_many`),
        the host mirror of `pool_free_round`.

    Page ids are global (`base_address` folds the shard base in), the
    same numbering the engine's device block tables carry.
    """

    def __init__(
        self,
        num_pages: int,
        page_tokens: int,
        n_shards: int = 1,
        max_rounds: int = 64,
        fastpath: bool = False,
        fastpath_slab_level: int = 2,
        magazines: int = 0,
        mag_lanes: int = 0,
    ) -> None:
        if num_pages & (num_pages - 1):
            raise ValueError("num_pages must be a power of two")
        if n_shards < 1 or (n_shards & (n_shards - 1)):
            raise ValueError("n_shards must be a power of two >= 1")
        if num_pages % n_shards:
            raise ValueError("num_pages must divide evenly across shards")
        self.num_pages = num_pages
        self.page_tokens = page_tokens
        self.n_shards = n_shards
        self.max_rounds = max_rounds
        self.pages_per_shard = num_pages // n_shards
        self.buddies = [
            NBBSRef(
                self.pages_per_shard,
                1,
                max_size=self.pages_per_shard,
                base_address=s * self.pages_per_shard,
            )
            for s in range(n_shards)
        ]
        # Fastpath mirror (core/fastpath.py): the leftmost
        # 1/2^slab_level of each shard is carved out of its tree at init
        # and served from a find-first-zero bitmap.  Every page request
        # probes the slab of its *current* shard before the tree scan —
        # the host linearization of the device round's slab claim, exact
        # because the claim's rank order over free slots equals lane
        # order and a slab page's id equals the leaf it replaced.
        self.fastpath = fastpath
        self.fastpath_slab_level = fastpath_slab_level
        self.fastpath_hits = 0
        self.fastpath_spills = 0
        self._slab_free: List[np.ndarray] = []
        if fastpath:
            slab_pages = self.pages_per_shard >> fastpath_slab_level
            if slab_pages < 1 or fastpath_slab_level < 1:
                raise ValueError(
                    "fastpath slab_level must carve a proper subtree of "
                    f"{self.pages_per_shard} pages per shard"
                )
            self.slab_pages = slab_pages
            for s, buddy in enumerate(self.buddies):
                addr = buddy.nb_alloc(slab_pages, scattered=False)
                assert addr == s * self.pages_per_shard, "carve is leftmost"
                self._slab_free.append(np.ones(slab_pages, bool))
        else:
            self.slab_pages = 0
        # Magazine mirror (core/magazine.py): per-lane LIFO stacks of
        # stashed global page ids.  A stashed page stays allocated in
        # the slab/tree; the stack end is the magazine top, so
        # list.pop()/append() in lane order reproduce the device
        # claim/stash rank assignment exactly.
        if magazines < 0 or mag_lanes < 0:
            raise ValueError("bad magazine configuration")
        self.magazines = magazines
        self.mag: List[List[int]] = [[] for _ in range(mag_lanes)]
        self.magazine_hits = 0
        self.magazine_spills = 0
        self.magazine_refills = 0

    def home_shard(self, lane_id: int) -> int:
        return ((lane_id * FIB_HASH) & 0xFFFFFFFF) % self.n_shards

    def mag_stashed(self) -> int:
        return sum(len(m) for m in self.mag)

    def _page_owned(self, page: int) -> bool:
        """The stash-phase ownership predicate: a page may be parked in
        a magazine only if the pool currently considers it allocated —
        its slab bit is claimed, or its tree leaf carries OCC (exactly
        the validity tests `slab_release`/`free_round` would apply)."""
        s = page // self.pages_per_shard
        local = page - s * self.pages_per_shard
        if self.fastpath and local < self.slab_pages:
            return not bool(self._slab_free[s][local])
        return bool(self.buddies[s].tree[self.pages_per_shard + local] & OCC)

    def _spill_all_magazines(self) -> int:
        """Release every stashed page back to the slab/tree, one merged
        burst per shard (the exhaustion spill-back), and empty the
        magazines.  Returns the number of pages spilled."""
        pages = [p for m in self.mag for p in m]
        for m in self.mag:
            m.clear()
        if pages:
            self.magazine_spills += len(pages)
            self.free_burst(pages)
        return len(pages)

    def alloc_wavefront(
        self, requests, mag_lanes=None
    ) -> Dict[int, Optional[int]]:
        """Emulate one `pool_wavefront_alloc` over `requests`, a list of
        (key, lane_id) pairs **in device lane order**.  Returns
        key -> global page id (None = failed after probing S shards).

        `mag_lanes` (parallel to `requests`; None or -1 entries opt
        out) routes each request through a magazine pop first — the
        device claim phase: pops resolve in lane order before any round
        runs, cost zero shared-state RMWs, and never count as overflow
        probes.  If every shard probe fails while magazines still hold
        pages, the whole stash spills back in one burst and the failed
        requests retry once from their home shards (the wavefront's
        exhaustion spill-back)."""
        out: Dict[int, Optional[int]] = {k: None for k, _ in requests}
        lanes = (
            list(mag_lanes)
            if mag_lanes is not None
            else [-1] * len(requests)
        )
        mag_claims = 0
        pend = []
        for (k, lid), ml in zip(requests, lanes):
            if (
                self.magazines
                and ml is not None
                and 0 <= ml < len(self.mag)
                and self.mag[ml]
            ):
                out[k] = self.mag[ml].pop()
                self.magazine_hits += 1
                mag_claims += 1
            else:
                pend.append((k, lid, self.home_shard(lid), 0))
        call_hits, failed = self._run_rounds(pend, out)
        if failed and self.magazines and self.mag_stashed():
            self._spill_all_magazines()
            retry = [
                (k, lid, self.home_shard(lid), 0) for k, lid in failed
            ]
            hits2, _ = self._run_rounds(retry, out)
            call_hits += hits2
        if self.fastpath:
            # device spill accounting: every fast-octave request that was
            # not served by a magazine pop or a slab claim — including
            # outright failures
            self.fastpath_spills += len(requests) - mag_claims - call_hits
        return out

    def _run_rounds(self, pend, out):
        """The round loop shared by the first pass and the post-spill
        retry.  Mutates `out` in place; returns (slab call hits, list
        of (key, lane_id) that failed after probing every shard)."""
        call_hits = 0
        failed: List[tuple] = []
        for _ in range(self.max_rounds):
            if not pend:
                break
            nxt = []
            for s in range(self.n_shards):
                entries = [e for e in pend if e[2] == s]
                if not entries:
                    continue
                exhausted = False
                won = 0
                for idx, (k, lid, sh, att) in enumerate(entries):
                    if exhausted:
                        # the slab was already empty when the tree ran
                        # dry (it serves the lane-order prefix first),
                        # so post-exhaustion entries skip both paths
                        if att + 1 < self.n_shards:
                            nxt.append(
                                (k, lid, (sh + 1) % self.n_shards, att + 1)
                            )
                        else:  # probed every shard: give up
                            failed.append((k, lid))
                        continue
                    if self.fastpath:
                        free = np.flatnonzero(self._slab_free[s])
                        if len(free):
                            slot = int(free[0])
                            self._slab_free[s][slot] = False
                            self.fastpath_hits += 1
                            call_hits += 1
                            out[k] = s * self.pages_per_shard + slot
                            continue
                    addr = self.buddies[s].nb_alloc(1, scattered=False)
                    if addr is not None:
                        out[k] = addr
                        won += 1
                    elif won:
                        # lost arbitration (rank >= cnt): the shard still
                        # had pages this round, so stay and retry it
                        nxt.extend(entries[idx:])
                        break
                    else:
                        exhausted = True
                        if att + 1 < self.n_shards:
                            nxt.append(
                                (k, lid, (sh + 1) % self.n_shards, att + 1)
                            )
                        else:
                            failed.append((k, lid))
            pend = nxt
        return call_hits, failed

    def free_burst(self, pages, stash_lanes=None) -> None:
        """Release global page ids, one merged burst per shard (the
        host mirror of the engine's in-graph `pool_free_round`).  With
        the fastpath on, ids under a shard's slab set their bitmap bit
        instead — a double free of a slab page is a silent no-op, the
        mirror of `slab_release`'s validity mask.

        `stash_lanes` (parallel to `pages`; None or -1 entries opt out)
        runs the device stash pre-pass first: the *first* occurrence of
        a page in the burst may park in its lane's magazine if the pool
        still owns the page and the magazine has room; every later
        occurrence of a stashed page is dropped from the burst (the
        device kills duplicates of stashed pages before the free
        round), and a full magazine counts a drop-through spill."""
        pages = list(pages)
        lanes = (
            list(stash_lanes)
            if stash_lanes is not None
            else [-1] * len(pages)
        )
        per_shard: Dict[int, List[int]] = {}
        first_seen: set = set()
        stashed: set = set()
        for p, ml in zip(pages, lanes):
            if p in stashed:
                continue  # duplicate of a stashed page: killed
            if (
                self.magazines
                and ml is not None
                and 0 <= ml < len(self.mag)
                and p not in first_seen
            ):
                first_seen.add(p)
                if self._page_owned(p):
                    if len(self.mag[ml]) < self.magazines:
                        self.mag[ml].append(p)
                        stashed.add(p)
                        continue
                    self.magazine_spills += 1
            else:
                first_seen.add(p)
            s = p // self.pages_per_shard
            local = p - s * self.pages_per_shard
            if self.fastpath and local < self.slab_pages:
                self._slab_free[s][local] = True
            else:
                per_shard.setdefault(s, []).append(p)
        for s, addrs in per_shard.items():
            self.buddies[s].nb_free_many(addrs)

    # -- occupancy ----------------------------------------------------
    def free_pages(self) -> int:
        slab = sum(int(f.sum()) for f in self._slab_free)
        return (
            slab
            + sum(b.free_bytes() for b in self.buddies)
            + self.mag_stashed()
        )

    def per_shard_free(self) -> List[int]:
        out = [b.free_bytes() for b in self.buddies]
        if self.fastpath:
            out = [n + int(f.sum()) for n, f in zip(out, self._slab_free)]
        for m in self.mag:
            for p in m:
                out[p // self.pages_per_shard] += 1
        return out

    def fragmentation(self) -> dict:
        per_shard_largest = [
            _largest_free_run(b, self.pages_per_shard) for b in self.buddies
        ]
        if self.fastpath:
            per_shard_largest = [
                max(n, 1) if f.any() else n
                for n, f in zip(per_shard_largest, self._slab_free)
            ]
        for m in self.mag:
            for p in m:  # a stashed page is claimable as a 1-run
                s = p // self.pages_per_shard
                per_shard_largest[s] = max(per_shard_largest[s], 1)
        free = self.free_pages()
        return {
            "free_pages": free,
            "used_pages": self.num_pages - free,
            "largest_run": max(per_shard_largest),
            "per_shard_free": self.per_shard_free(),
            "per_shard_largest_run": per_shard_largest,
        }

    def check_invariants(self) -> None:
        for b in self.buddies:
            b.check_invariants()
