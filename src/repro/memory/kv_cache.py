"""Paged KV-cache management on the non-blocking buddy system.

This is where the paper's contribution becomes a first-class framework
feature: the serving engine's KV page pool is managed by the NBBS
(host-side: the paper-faithful `NBBSRef`; burst admission: the jnp
wavefront — the same data structure, so both views stay coherent).

Design points (docs/design.md §2):
  * a sequence's KV cache is a list of buddy *runs* — power-of-two
    contiguous page spans.  Growth allocates a run of the current run
    size (doubling), so a sequence of T tokens holds O(log T) runs and
    its block table is a concatenation of contiguous id ranges (large
    DMA-friendly spans for the paged-attention kernel);
  * admission control is allocation success: when the buddy cannot
    serve a run, the scheduler queues the request instead of thrashing
    (fragmentation is visible in O(1) through the status-bit tree);
  * frees coalesce automatically (paper §III-C), so long-lived serving
    does not degrade — the property the Constant Occupancy benchmark
    measures;
  * with `n_shards > 1` the page pool is split across S replicated
    buddy trees (the host mirror of `core/pool.py`): a sequence's home
    shard is the Fibonacci hash of its id, admission probes shards in
    the fixed cyclic order home, home+1, …, and the serving shard is
    recorded in `SeqAlloc.shard` so a burst release frees per-shard —
    one `free_round`-equivalent burst per shard, never a cross-shard
    scan.

Invariants (deep-linked from docs/architecture.md):

  * page-id numbering: shard s owns the global page ids
    [s * pages_per_shard, (s+1) * pages_per_shard); each shard's
    `NBBSRef` is constructed with that `base_address`, so every address
    it returns is already a global page id and block tables are
    shard-agnostic;
  * a sequence's runs all live on its recorded shard (`SeqAlloc.shard`)
    — admission probes whole-sequence, growth never migrates — so
    `free_sequence(s)` is exactly one per-shard burst;
  * occupancy encoding inside each shard is the 5-bit status-bit tree
    of `core/bits.py`; occupancy/fragmentation introspection
    (`fragmentation`) is the per-shard O(tree) scan, reported per shard
    and pool-wide;
  * double frees cannot cross shards: a handle resolves through its own
    shard's index[] only (see `core/nbbs_jax.py` invariants for the
    arbitration rule on the device path).

Two host views live here (docs/design.md §8):

  * `PagedKVManager` — the run-granularity manager the host-driven
    `ServeEngine` allocates through (buddy runs, growth by doubling);
  * `PageOracle` — the page-granularity differential oracle of the
    *jit-resident* engine: per-shard `NBBSRef` trees driven through an
    exact host emulation of `core/pool.pool_wavefront_alloc`'s round
    semantics, handing out the same global page ids the device tables
    carry.  The jitted engine must match it bit-for-bit on page
    assignments and pool occupancy (tests/test_serving.py).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.bits import FIB_HASH  # host/device routing must agree
from repro.core.ref import NBBSRef, _ilog2


@dataclasses.dataclass
class SeqAlloc:
    seq_id: int
    runs: List[range]          # page-id ranges (global ids), in order
    n_tokens: int = 0
    shard: int = 0             # serving shard: all runs live here

    @property
    def n_pages(self) -> int:
        return sum(len(r) for r in self.runs)


class PagedKVManager:
    """Page-granularity KV allocator for the serving engine."""

    def __init__(
        self,
        num_pages: int,
        page_tokens: int,
        max_run_pages: Optional[int] = None,
        scattered: bool = True,
        n_shards: int = 1,
        layout: Optional[str] = None,
    ) -> None:
        if num_pages & (num_pages - 1):
            raise ValueError("num_pages must be a power of two")
        if n_shards < 1 or (n_shards & (n_shards - 1)):
            raise ValueError("n_shards must be a power of two >= 1")
        if num_pages % n_shards:
            raise ValueError("num_pages must divide evenly across shards")
        if layout not in (None, "unpacked", "bunch-packed"):
            raise ValueError(f"unknown tree layout {layout!r}")
        self.num_pages = num_pages
        self.page_tokens = page_tokens
        self.n_shards = n_shards
        # Device tree-state layout for the wavefront-backed admission
        # path (docs/design.md §3).  The host-side NBBSRef trees below
        # are layout-independent; this knob only shapes what
        # `device_pool_config()` exports, so handles — (shard, page id)
        # pairs — and the whole public API are unchanged.
        self.layout = layout or "unpacked"
        self.pages_per_shard = num_pages // n_shards
        self.max_run_pages = min(
            max_run_pages or num_pages, self.pages_per_shard
        )
        self.scattered = scattered
        # One allocation unit == one page; shard s serves global ids
        # [s * pages_per_shard, (s+1) * pages_per_shard) via base_address.
        self.buddies = [
            NBBSRef(
                self.pages_per_shard,
                1,
                max_size=self.max_run_pages,
                base_address=s * self.pages_per_shard,
            )
            for s in range(n_shards)
        ]
        self.seqs: Dict[int, SeqAlloc] = {}

    @property
    def buddy(self) -> NBBSRef:
        """The single tree of an unsharded pool (back-compat accessor)."""
        assert self.n_shards == 1, "sharded pool: use .buddies[s]"
        return self.buddies[0]

    def device_pool_config(self):
        """The device-side `core.pool.PoolConfig` mirroring this pool's
        geometry: S shards of a depth-log2(pages_per_shard) tree, one
        allocation unit per page, with the configured tree-state layout
        (`layout="bunch-packed"` gives the §III-D packed words — ~1/7
        the VMEM words, ~B x fewer climb writes; see `core/layout.py`).
        Burst admission through `core.nbbs_jax.nb_pool_alloc` /
        `kernels.ops.nbbs_pool_wavefront_step` on this config produces
        the same (shard, page) handles this host manager hands out."""
        from repro.core.concurrent import BUNCH_PACKED, TreeConfig, UNPACKED
        from repro.core.pool import PoolConfig

        tree = TreeConfig(
            depth=_ilog2(self.pages_per_shard),
            max_level=_ilog2(self.pages_per_shard // self.max_run_pages),
            layout=(
                BUNCH_PACKED if self.layout == "bunch-packed" else UNPACKED
            ),
        )
        return PoolConfig(tree, self.n_shards)

    # ------------------------------------------------------------------
    def home_shard(self, seq_id: int) -> int:
        """Deterministic home shard of a sequence (Fibonacci hash, the
        same spread as `core/pool.home_shard` for device lanes)."""
        return ((seq_id * FIB_HASH) & 0xFFFFFFFF) % self.n_shards

    def pages_for_tokens(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.page_tokens))

    def _next_pow2(self, n: int) -> int:
        return 1 << (n - 1).bit_length()

    def _try_admit_on(self, shard: int, need: int) -> Optional[List[range]]:
        """Allocate `need` pages worth of runs on one shard, or roll back
        and return None (an admission is all-on-one-shard or nothing)."""
        buddy = self.buddies[shard]
        runs: List[range] = []
        remaining = need
        while remaining:
            run = min(remaining, self.max_run_pages)
            addr = buddy.nb_alloc(run, scattered=self.scattered)
            if addr is None:
                for r in runs:  # roll back partial admission
                    buddy.nb_free(r.start)
                return None
            runs.append(range(addr, addr + run))
            remaining -= run
        return runs

    def add_sequence(self, seq_id: int, n_tokens: int) -> bool:
        """Admit a sequence with a prompt of n_tokens. False = pool full
        (the scheduler should queue/evict — admission control).

        Probes shards in the fixed order home, home+1, …, home+S-1: the
        first shard that can hold the whole sequence serves it (overflow
        routing, mirroring `core/pool.py`)."""
        assert seq_id not in self.seqs
        need = self._next_pow2(self.pages_for_tokens(max(n_tokens, 1)))
        if need > self.pages_per_shard:
            # Not "pool full" — the request exceeds the pool geometry
            # and no amount of waiting or probing can ever admit it.
            # Raising (instead of returning False) keeps an impossible
            # request from head-of-line blocking the scheduler forever.
            raise ValueError(
                f"sequence needs {need} pages but a shard holds only "
                f"{self.pages_per_shard} (num_pages={self.num_pages}, "
                f"n_shards={self.n_shards})"
            )
        home = self.home_shard(seq_id)
        for attempt in range(self.n_shards):
            shard = (home + attempt) % self.n_shards
            runs = self._try_admit_on(shard, need)
            if runs is not None:
                self.seqs[seq_id] = SeqAlloc(
                    seq_id, runs, n_tokens, shard=shard
                )
                return True
        return False

    def append_tokens(self, seq_id: int, n_new: int = 1) -> bool:
        """Reserve space for n_new more tokens; grows by buddy doubling
        on the sequence's recorded shard (runs never migrate shards).
        On failure the sequence is left exactly as before the call: both
        n_tokens and any runs grown by earlier loop iterations are rolled
        back (a partially grown sequence would silently leak pages the
        token count never accounts for)."""
        s = self.seqs[seq_id]
        buddy = self.buddies[s.shard]
        n_runs_before = len(s.runs)
        s.n_tokens += n_new
        while self.pages_for_tokens(s.n_tokens) > s.n_pages:
            grow = min(self._next_pow2(max(s.n_pages, 1)), self.max_run_pages)
            addr = buddy.nb_alloc(grow, scattered=self.scattered)
            if addr is None:
                s.n_tokens -= n_new
                grown = s.runs[n_runs_before:]
                del s.runs[n_runs_before:]
                buddy.nb_free_many(r.start for r in grown)
                return False
            s.runs.append(range(addr, addr + grow))
        return True

    def free_sequence(self, seq_id: int) -> None:
        """Release a sequence: all of its runs go back in one burst call
        on its shard (one merged release pass on wavefront-backed pools)."""
        s = self.seqs.pop(seq_id)
        self.buddies[s.shard].nb_free_many(r.start for r in s.runs)

    def free_sequences(self, seq_ids: List[int]) -> None:
        """Batch eviction: release every run of every sequence, grouped
        by shard so each shard gets a single burst (one `free_round`
        each on wavefront-backed pools).  Validates the whole batch
        before mutating any state so an unknown id cannot strand
        already-popped sequences' pages."""
        unique = list(dict.fromkeys(seq_ids))
        missing = [i for i in unique if i not in self.seqs]
        if missing:
            raise KeyError(missing[0])
        per_shard: Dict[int, List[int]] = {}
        for seq_id in unique:
            s = self.seqs.pop(seq_id)
            per_shard.setdefault(s.shard, []).extend(
                r.start for r in s.runs
            )
        for shard, addrs in per_shard.items():
            self.buddies[shard].nb_free_many(addrs)

    # ------------------------------------------------------------------
    def block_table(self, seq_id: int, max_pages: int) -> np.ndarray:
        """Flat page-id table, -1 padded, for the paged-attention kernel.
        Ids are global (shard base already folded in by `base_address`)."""
        s = self.seqs[seq_id]
        ids = [p for r in s.runs for p in r]
        used = self.pages_for_tokens(s.n_tokens)
        ids = ids[: max(used, 1)]
        out = np.full((max_pages,), -1, np.int32)
        out[: len(ids)] = ids
        return out

    def block_tables(self, seq_ids: List[int], max_pages: int) -> np.ndarray:
        return np.stack([self.block_table(s, max_pages) for s in seq_ids])

    # ------------------------------------------------------------------
    def free_pages(self) -> int:
        return sum(b.free_bytes() for b in self.buddies)  # unit == page

    def _largest_run_on(self, buddy: NBBSRef) -> int:
        return _largest_free_run(buddy, self.max_run_pages)

    def fragmentation(self) -> dict:
        """Occupancy + largest allocatable run (O(tree) introspection),
        pool-wide plus the per-shard breakdown."""
        free = self.free_pages()
        per_shard_largest = [self._largest_run_on(b) for b in self.buddies]
        per_shard_free = [b.free_bytes() for b in self.buddies]
        return {
            "free_pages": free,
            "used_pages": self.num_pages - free,
            "largest_run": max(per_shard_largest),
            "n_seqs": len(self.seqs),
            "runs_per_seq": (
                float(np.mean([len(s.runs) for s in self.seqs.values()]))
                if self.seqs
                else 0.0
            ),
            "per_shard_free": per_shard_free,
            "per_shard_largest_run": per_shard_largest,
        }

    def _occupied_ancestor(self, buddy: NBBSRef, n: int) -> bool:
        return _occupied_ancestor(buddy, n)


def _occupied_ancestor(buddy: NBBSRef, n: int) -> bool:
    from repro.core.bits import OCC

    n >>= 1
    while n >= 1:
        if buddy.tree[n] & OCC:
            return True
        n >>= 1
    return False


def _largest_free_run(buddy: NBBSRef, max_probe: int) -> int:
    """Largest allocatable run on one tree (non-destructive probe)."""
    from repro.core.bits import is_free

    probe = max_probe
    while probe >= 1:
        level = buddy.level_for_size(probe)
        base = 1 << level
        if any(
            is_free(buddy.tree[i]) and not _occupied_ancestor(buddy, i)
            for i in range(base, 2 * base)
        ):
            return probe
        probe //= 2
    return 0


# ---------------------------------------------------------------------------
# PageOracle: host differential oracle of the jit-resident engine pool
# ---------------------------------------------------------------------------


class PageOracle:
    """Leaf-only page allocator mirroring the jitted engine's in-graph
    pool, page by page.

    The jit-resident engine (`serve/jit_engine.py`) claims KV pages one
    leaf unit at a time through `pool_wavefront_alloc`.  This class
    drives per-shard `NBBSRef` trees through an *exact* host emulation
    of those pool rounds, so a host-driven replay of the same request
    trace must produce identical page ids and identical final trees:

      * each request's home shard is the Fibonacci hash of its lane id
        (`home_shard`, shared constant with `core/pool.py`);
      * per round, per shard, the routed requests allocate sequentially
        in lane order with first-fit leaf scans (`scattered=False`) —
        equivalent to the device round's rank/prefix-sum assignment,
        because allocating the rank-r allocatable leaf never changes the
        allocatability of leaves ranked above it;
      * a shard whose *first* attempted allocation of the round fails
        had zero allocatable leaves at round start — the device round's
        `exhausted` condition — so every request routed there advances
        its probe (`shard+1`, cyclic), failing after S probes.  A
        request that fails *after* wins on its shard merely lost
        arbitration and retries the same shard next round;
      * releases are burst frees grouped per shard (`nb_free_many`),
        the host mirror of `pool_free_round`.

    Page ids are global (`base_address` folds the shard base in), the
    same numbering the engine's device block tables carry.
    """

    def __init__(
        self,
        num_pages: int,
        page_tokens: int,
        n_shards: int = 1,
        max_rounds: int = 64,
    ) -> None:
        if num_pages & (num_pages - 1):
            raise ValueError("num_pages must be a power of two")
        if n_shards < 1 or (n_shards & (n_shards - 1)):
            raise ValueError("n_shards must be a power of two >= 1")
        if num_pages % n_shards:
            raise ValueError("num_pages must divide evenly across shards")
        self.num_pages = num_pages
        self.page_tokens = page_tokens
        self.n_shards = n_shards
        self.max_rounds = max_rounds
        self.pages_per_shard = num_pages // n_shards
        self.buddies = [
            NBBSRef(
                self.pages_per_shard,
                1,
                max_size=self.pages_per_shard,
                base_address=s * self.pages_per_shard,
            )
            for s in range(n_shards)
        ]

    def home_shard(self, lane_id: int) -> int:
        return ((lane_id * FIB_HASH) & 0xFFFFFFFF) % self.n_shards

    def alloc_wavefront(self, requests) -> Dict[int, Optional[int]]:
        """Emulate one `pool_wavefront_alloc` over `requests`, a list of
        (key, lane_id) pairs **in device lane order**.  Returns
        key -> global page id (None = failed after probing S shards)."""
        out: Dict[int, Optional[int]] = {k: None for k, _ in requests}
        pend = [
            (k, lid, self.home_shard(lid), 0) for k, lid in requests
        ]
        for _ in range(self.max_rounds):
            if not pend:
                break
            nxt = []
            for s in range(self.n_shards):
                entries = [e for e in pend if e[2] == s]
                if not entries:
                    continue
                exhausted = False
                won = 0
                for idx, (k, lid, sh, att) in enumerate(entries):
                    if exhausted:
                        if att + 1 < self.n_shards:
                            nxt.append(
                                (k, lid, (sh + 1) % self.n_shards, att + 1)
                            )
                        continue  # att+1 >= S: probed every shard, fail
                    addr = self.buddies[s].nb_alloc(1, scattered=False)
                    if addr is not None:
                        out[k] = addr
                        won += 1
                    elif won:
                        # lost arbitration (rank >= cnt): the shard still
                        # had pages this round, so stay and retry it
                        nxt.extend(entries[idx:])
                        break
                    else:
                        exhausted = True
                        if att + 1 < self.n_shards:
                            nxt.append(
                                (k, lid, (sh + 1) % self.n_shards, att + 1)
                            )
            pend = nxt
        return out

    def free_burst(self, pages) -> None:
        """Release global page ids, one merged burst per shard (the
        host mirror of the engine's in-graph `pool_free_round`)."""
        per_shard: Dict[int, List[int]] = {}
        for p in pages:
            per_shard.setdefault(p // self.pages_per_shard, []).append(p)
        for s, addrs in per_shard.items():
            self.buddies[s].nb_free_many(addrs)

    # -- occupancy ----------------------------------------------------
    def free_pages(self) -> int:
        return sum(b.free_bytes() for b in self.buddies)

    def per_shard_free(self) -> List[int]:
        return [b.free_bytes() for b in self.buddies]

    def fragmentation(self) -> dict:
        per_shard_largest = [
            _largest_free_run(b, self.pages_per_shard) for b in self.buddies
        ]
        free = self.free_pages()
        return {
            "free_pages": free,
            "used_pages": self.num_pages - free,
            "largest_run": max(per_shard_largest),
            "per_shard_free": self.per_shard_free(),
            "per_shard_largest_run": per_shard_largest,
        }

    def check_invariants(self) -> None:
        for b in self.buddies:
            b.check_invariants()
