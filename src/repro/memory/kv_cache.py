"""Paged KV-cache management on the non-blocking buddy system.

This is where the paper's contribution becomes a first-class framework
feature: the serving engine's KV page pool is managed by the NBBS
(host-side: the paper-faithful `NBBSRef`; burst admission: the jnp
wavefront — the same data structure, so both views stay coherent).

Design points (DESIGN.md §2):
  * a sequence's KV cache is a list of buddy *runs* — power-of-two
    contiguous page spans.  Growth allocates a run of the current run
    size (doubling), so a sequence of T tokens holds O(log T) runs and
    its block table is a concatenation of contiguous id ranges (large
    DMA-friendly spans for the paged-attention kernel);
  * admission control is allocation success: when the buddy cannot
    serve a run, the scheduler queues the request instead of thrashing
    (fragmentation is visible in O(1) through the status-bit tree);
  * frees coalesce automatically (paper §III-C), so long-lived serving
    does not degrade — the property the Constant Occupancy benchmark
    measures.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.core.ref import NBBSRef


@dataclasses.dataclass
class SeqAlloc:
    seq_id: int
    runs: List[range]          # page-id ranges, in order
    n_tokens: int = 0

    @property
    def n_pages(self) -> int:
        return sum(len(r) for r in self.runs)


class PagedKVManager:
    """Page-granularity KV allocator for the serving engine."""

    def __init__(
        self,
        num_pages: int,
        page_tokens: int,
        max_run_pages: Optional[int] = None,
        scattered: bool = True,
    ) -> None:
        if num_pages & (num_pages - 1):
            raise ValueError("num_pages must be a power of two")
        self.num_pages = num_pages
        self.page_tokens = page_tokens
        self.max_run_pages = max_run_pages or num_pages
        self.scattered = scattered
        # One allocation unit == one page.
        self.buddy = NBBSRef(num_pages, 1, max_size=self.max_run_pages)
        self.seqs: Dict[int, SeqAlloc] = {}

    # ------------------------------------------------------------------
    def pages_for_tokens(self, n_tokens: int) -> int:
        return max(1, -(-n_tokens // self.page_tokens))

    def _next_pow2(self, n: int) -> int:
        return 1 << (n - 1).bit_length()

    def add_sequence(self, seq_id: int, n_tokens: int) -> bool:
        """Admit a sequence with a prompt of n_tokens. False = pool full
        (the scheduler should queue/evict — admission control)."""
        assert seq_id not in self.seqs
        need = self._next_pow2(self.pages_for_tokens(max(n_tokens, 1)))
        runs: List[range] = []
        remaining = need
        while remaining:
            run = min(remaining, self.max_run_pages)
            addr = self.buddy.nb_alloc(run, scattered=self.scattered)
            if addr is None:
                for r in runs:  # roll back partial admission
                    self.buddy.nb_free(r.start)
                return False
            runs.append(range(addr, addr + run))
            remaining -= run
        self.seqs[seq_id] = SeqAlloc(seq_id, runs, n_tokens)
        return True

    def append_tokens(self, seq_id: int, n_new: int = 1) -> bool:
        """Reserve space for n_new more tokens; grows by buddy doubling.
        On failure the sequence is left exactly as before the call: both
        n_tokens and any runs grown by earlier loop iterations are rolled
        back (a partially grown sequence would silently leak pages the
        token count never accounts for)."""
        s = self.seqs[seq_id]
        n_runs_before = len(s.runs)
        s.n_tokens += n_new
        while self.pages_for_tokens(s.n_tokens) > s.n_pages:
            grow = min(self._next_pow2(max(s.n_pages, 1)), self.max_run_pages)
            addr = self.buddy.nb_alloc(grow, scattered=self.scattered)
            if addr is None:
                s.n_tokens -= n_new
                grown = s.runs[n_runs_before:]
                del s.runs[n_runs_before:]
                self.buddy.nb_free_many(r.start for r in grown)
                return False
            s.runs.append(range(addr, addr + grow))
        return True

    def free_sequence(self, seq_id: int) -> None:
        """Release a sequence: all of its runs go back in one burst call
        (one merged release pass on wavefront-backed pools)."""
        s = self.seqs.pop(seq_id)
        self.buddy.nb_free_many(r.start for r in s.runs)

    def free_sequences(self, seq_ids: List[int]) -> None:
        """Batch eviction: release every run of every sequence in a
        single burst.  Validates the whole batch before mutating any
        state so an unknown id cannot strand already-popped sequences'
        pages."""
        unique = list(dict.fromkeys(seq_ids))
        missing = [i for i in unique if i not in self.seqs]
        if missing:
            raise KeyError(missing[0])
        addrs = []
        for seq_id in unique:
            s = self.seqs.pop(seq_id)
            addrs.extend(r.start for r in s.runs)
        self.buddy.nb_free_many(addrs)

    # ------------------------------------------------------------------
    def block_table(self, seq_id: int, max_pages: int) -> np.ndarray:
        """Flat page-id table, -1 padded, for the paged-attention kernel."""
        s = self.seqs[seq_id]
        ids = [p for r in s.runs for p in r]
        used = self.pages_for_tokens(s.n_tokens)
        ids = ids[: max(used, 1)]
        out = np.full((max_pages,), -1, np.int32)
        out[: len(ids)] = ids
        return out

    def block_tables(self, seq_ids: List[int], max_pages: int) -> np.ndarray:
        return np.stack([self.block_table(s, max_pages) for s in seq_ids])

    # ------------------------------------------------------------------
    def free_pages(self) -> int:
        return self.buddy.free_bytes()  # unit == page

    def fragmentation(self) -> dict:
        """Occupancy + largest allocatable run (O(tree) introspection)."""
        free = self.free_pages()
        largest = 0
        probe = self.max_run_pages
        while probe >= 1:
            # non-destructive probe: scan the level for a free node
            level = self.buddy.level_for_size(probe)
            base = 1 << level
            from repro.core.bits import is_free

            anc_free = any(
                is_free(self.buddy.tree[i])
                and not self._occupied_ancestor(i)
                for i in range(base, 2 * base)
            )
            if anc_free:
                largest = probe
                break
            probe //= 2
        return {
            "free_pages": free,
            "used_pages": self.num_pages - free,
            "largest_run": largest,
            "n_seqs": len(self.seqs),
            "runs_per_seq": (
                float(np.mean([len(s.runs) for s in self.seqs.values()]))
                if self.seqs
                else 0.0
            ),
        }

    def _occupied_ancestor(self, n: int) -> bool:
        from repro.core.bits import OCC

        n >>= 1
        while n >= 1:
            if self.buddy.tree[n] & OCC:
                return True
            n >>= 1
        return False
