"""Production mesh construction.

A function (not a module-level constant) so importing never touches JAX
device state; `dryrun.py` sets the 512-placeholder-device XLA flag
before its first jax import and then calls this.

Version compatibility: `jax.sharding.AxisType` and `jax.set_mesh` only
exist from JAX 0.5/0.6 onwards.  On older runtimes (the pinned 0.4.x
toolchain) `make_mesh` simply omits `axis_types` (explicit-axis meshes
degrade to the default auto behaviour) and `use_mesh` falls back to the
classic `with mesh:` resource-env context.  All repo code and test
snippets must go through these helpers instead of touching the raw JAX
API.
"""

from __future__ import annotations

import jax


def _axis_types_kwargs(n_axes: int) -> dict:
    """{'axis_types': (Auto,)*n} when the running JAX supports it, else {}."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def use_mesh(mesh):
    """Context manager activating `mesh` (jax.set_mesh on new JAX, the
    Mesh resource-env context on 0.4.x)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) data x model single pod; (2,16,16) pod x data x model."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))


def dp_axes(multi_pod: bool) -> tuple:
    return ("pod", "data") if multi_pod else ("data",)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires forced host device count)."""
    return jax.make_mesh(shape, axes, **_axis_types_kwargs(len(axes)))
