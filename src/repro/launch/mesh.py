"""Production mesh construction.

A function (not a module-level constant) so importing never touches JAX
device state; `dryrun.py` sets the 512-placeholder-device XLA flag
before its first jax import and then calls this.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """(16,16) data x model single pod; (2,16,16) pod x data x model."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def dp_axes(multi_pod: bool) -> tuple:
    return ("pod", "data") if multi_pod else ("data",)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires forced host device count)."""
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )
