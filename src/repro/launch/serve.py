"""Serving launcher: continuous batching on NBBS-paged KV memory.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-3b --reduced \
      --requests 16 --max-new 8
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--num-pages", type=int, default=256)
    ap.add_argument("--page-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dtype = jnp.float32 if jax.default_backend() == "cpu" else jnp.bfloat16
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = ServeEngine(
        cfg,
        params,
        num_pages=args.num_pages,
        page_tokens=args.page_tokens,
        max_batch=args.max_batch,
        dtype=dtype,
    )
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(2, args.prompt_len + 1))
        eng.submit(
            Request(
                i,
                rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
                max_new_tokens=args.max_new,
            )
        )
    t0 = time.perf_counter()
    eng.run_to_completion()
    dt = time.perf_counter() - t0
    toks = sum(len(r.out_tokens) for r in eng.completed.values())
    print(
        json.dumps(
            {
                "completed": len(eng.completed),
                "generated_tokens": toks,
                "tokens_per_s": toks / dt,
                "engine_stats": eng.stats,
                "kv": eng.kv.fragmentation(),
            }
        )
    )


if __name__ == "__main__":
    main()
