"""Launch layer: mesh, dry-run, train/serve drivers."""
