import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST precede any jax-importing import (jax locks the
device count on first init); do not move them.

For each cell this driver:
  1. builds the production mesh ((16,16) or (2,16,16));
  2. constructs the jitted step (train_step / prefill / decode_step)
     with NamedSharding in/out specs from the model's partitioning
     rules (FSDP x TP params, DP batch, sequence-sharded KV);
  3. `.lower(**ShapeDtypeStructs).compile()` — nothing is allocated;
  4. records `memory_analysis()` (fits-per-device proof),
     `cost_analysis()` (XLA's numbers, loop bodies counted once), and
     the loop-aware roofline terms from `roofline.hlo_analysis` (trip-
     count-corrected flops / bytes / collective bytes per device);
  5. writes one JSON per cell under experiments/dryrun/.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama4-scout-17b-a16e \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, input_specs, ARCH_NAMES
from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch.mesh import dp_axes, make_production_mesh, use_mesh
from repro.models.sharding import MeshAxes, param_specs
from repro.models.transformer import decode_step, init_cache, init_params, prefill
from repro.roofline.hlo_analysis import HW_V5E, analyze_hlo, roofline_terms
from repro.train.trainer import TrainConfig, TrainState, make_train_step
from repro.optim import adamw


def _dp(axes_tuple):
    return axes_tuple if len(axes_tuple) > 1 else axes_tuple[0]


def batch_specs(batch_tree, dp, batch_divisible: bool):
    def one(leaf):
        if not batch_divisible:
            return P()
        return P(dp, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(one, batch_tree)


def cache_pspecs(cfg: ArchConfig, cache_tree, dp, tp, batch_divisible: bool):
    """KV caches: [sites, B, S, Hkv, D] -> B on dp, S on tp (flash-decode
    partial-softmax falls out of SPMD); batch-1 cells shard S over
    everything instead. States (mamba/rwkv): heads on tp."""

    def one(path, leaf):
        names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        last = names[-1] if names else ""
        if last in ("k", "v") and leaf.ndim == 5:
            if batch_divisible:
                return P(None, dp, tp, None, None)
            allaxes = (dp if isinstance(dp, tuple) else (dp,)) + (tp,)
            return P(None, None, allaxes, None, None)
        if last == "ssm" and leaf.ndim >= 4:  # [G,A,B,H,N,P]
            lead = leaf.ndim - 4
            return P(*([None] * lead), None if not batch_divisible else dp,
                     tp, None, None)
        if last == "conv" and leaf.ndim >= 3:  # [G,A,B,K-1,convdim]
            lead = leaf.ndim - 3
            return P(*([None] * lead), None if not batch_divisible else dp,
                     None, tp)
        if last == "wkv" and leaf.ndim == 5:  # [L,B,H,P,P]
            return P(None, dp if batch_divisible else None, tp, None, None)
        if last in ("tm_x", "cm_x") and leaf.ndim == 3:  # [L,B,d]
            return P(None, dp if batch_divisible else None, tp)
        return P()

    return jax.tree_util.tree_map_with_path(one, cache_tree)


def build_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, multi_pod: bool,
               variant: str = "baseline"):
    """Returns (lowered, meta) for one cell.

    variant='opt' enables the beyond-paper optimizations recorded in
    docs/experiments.md §Perf: block-local MoE dispatch aligned to the data
    shards, capacity 2.0 serving dispatch, bf16-once parameter casting
    (bf16 FSDP gathers + bf16 gradient wire), gradient sharding
    constraints (reduce-scatter), and bf16 serving weights."""
    dpa = dp_axes(multi_pod)
    axes = MeshAxes(dp=dpa, tp="model", fsdp=True)
    dp = _dp(dpa)
    dp_size = 1
    for a in dpa:
        dp_size *= mesh.shape[a]
    batch_div = shape.global_batch % dp_size == 0
    ns = lambda spec: NamedSharding(mesh, spec)
    flags = set(variant.split("+")) if variant != "baseline" else set()
    if "opt" in flags:
        flags = {"einsum", "servecf", "bf16serve"}
    if cfg.n_experts:
        group = cfg.dispatch_group
        for f in flags:
            if f.startswith("g") and f[1:].isdigit():
                group = int(f[1:])  # e.g. g512: einsum dispatch group size
        cfg = dataclasses.replace(
            cfg,
            dispatch_blocks=(dp_size if batch_div and "blocks" in flags else 1),
            serve_capacity_factor=(2.0 if "servecf" in flags else 0.0),
            dispatch_mode=("einsum" if "einsum" in flags else "scatter"),
            dispatch_group=group,
        )

    params_shape = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.ShapeDtypeStruct((2,), jnp.uint32)
    )
    if "bf16serve" in flags and shape.kind != "train":
        # bf16 serving weights (no f32 masters at inference)
        params_shape = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16)
            if a.dtype == jnp.float32 and len(a.shape) >= 2
            else a,
            params_shape,
        )
    pspecs = param_specs(axes, params_shape)

    if shape.kind == "train":
        tcfg = TrainConfig(
            microbatches=1, remat=True, dtype=jnp.bfloat16,
            cast_params_once="cast" in flags,
            constrain_grads="rsgrads" in flags,
        )
        step = make_train_step(cfg, tcfg, axes)
        opt_shape = jax.eval_shape(adamw.init, params_shape)
        state_shape = TrainState(params_shape, opt_shape, {})
        state_specs = param_specs(axes, state_shape)
        batch = input_specs(cfg, shape)
        bspecs = batch_specs(batch, dp, batch_div)
        fn = jax.jit(
            step,
            in_shardings=(
                jax.tree.map(ns, state_specs),
                jax.tree.map(ns, bspecs),
            ),
            donate_argnums=0,
        )
        lowered = fn.lower(state_shape, batch)
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "prefill":
        batch = input_specs(cfg, shape)
        bspecs = batch_specs(batch, dp, batch_div)

        def pf(params, batch):
            return prefill(
                cfg, params, batch, max_len=shape.seq_len, axes=axes,
                dtype=jnp.bfloat16,
            )

        cache_shape = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len)
        )
        cspecs = cache_pspecs(cfg, cache_shape, dp, "model", batch_div)
        fn = jax.jit(
            pf,
            in_shardings=(jax.tree.map(ns, pspecs), jax.tree.map(ns, bspecs)),
            out_shardings=(
                ns(P(dp if batch_div else None, "model")),
                jax.tree.map(ns, cspecs),
            ),
        )
        lowered = fn.lower(params_shape, batch)
        tokens = shape.global_batch * shape.seq_len
    elif shape.kind == "decode":
        cache_shape = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len)
        )
        cspecs = cache_pspecs(cfg, cache_shape, dp, "model", batch_div)
        toks = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)

        def dec(params, cache, tokens):
            return decode_step(
                cfg, params, cache, tokens, axes=axes, dtype=jnp.bfloat16
            )

        fn = jax.jit(
            dec,
            in_shardings=(
                jax.tree.map(ns, pspecs),
                jax.tree.map(ns, cspecs),
                ns(P(dp if batch_div else None)),
            ),
            out_shardings=(
                ns(P(dp if batch_div else None, "model")),
                jax.tree.map(ns, cspecs),
            ),
            donate_argnums=1,
        )
        lowered = fn.lower(params_shape, cache_shape, toks)
        tokens = shape.global_batch  # one token per sequence
    else:
        raise ValueError(shape.kind)
    return lowered, {"tokens": tokens}


def model_flops(cfg: ArchConfig, shape: ShapeSpec, tokens: int) -> float:
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * tokens
    return 2.0 * n_active * tokens


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             variant: str = "baseline") -> dict:
    cfg = get_config(arch)
    shapes = cfg.supported_shapes()
    if shape_name not in shapes:
        return {
            "arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "status": "skipped",
            "reason": "long_500k requires sub-quadratic attention "
                      "(docs/design.md §5)",
        }
    shape = shapes[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    with use_mesh(mesh):
        lowered, meta = build_cell(cfg, shape, mesh, multi_pod, variant)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        hlo = analyze_hlo(compiled.as_text())
    mf = model_flops(cfg, shape, meta["tokens"])
    terms = roofline_terms(hlo)
    per_dev_model_flops = mf / n_chips
    result = {
        "arch": arch,
        "variant": variant,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": n_chips,
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": {
            "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes_per_device": getattr(mem, "alias_size_in_bytes", None),
        },
        "xla_cost_analysis": {
            "flops": ca.get("flops"),
            "bytes accessed": ca.get("bytes accessed"),
            "note": "XLA counts while bodies once; see hlo_walk for "
                    "trip-count-corrected numbers",
        },
        "hlo_walk_per_device": {
            "flops": hlo["flops"],
            "bytes": hlo["bytes"],
            "collective_bytes": hlo["collective_bytes"],
            "per_collective": hlo["per_collective"],
            "warnings": hlo["warnings"],
        },
        "roofline": terms,
        "model_flops_global": mf,
        "model_flops_per_device": per_dev_model_flops,
        "useful_flops_ratio": (
            per_dev_model_flops / hlo["flops"] if hlo["flops"] else None
        ),
        "hw": HW_V5E,
    }
    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(
        out_dir,
        f"{arch.replace('/', '_')}__{shape_name}__"
        f"{'multi' if multi_pod else 'single'}.json",
    )
    with open(fn, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = ARCH_NAMES if args.all or args.arch is None else [args.arch]
    shape_names = (
        ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
        if args.shape is None
        else [args.shape]
    )
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for sn in shape_names:
            for mp in meshes:
                tag = f"{arch:28s} {sn:12s} {'2x16x16' if mp else '16x16 '}"
                fn = os.path.join(
                    args.out,
                    f"{arch.replace('/', '_')}__{sn}__"
                    f"{'multi' if mp else 'single'}.json",
                )
                if args.skip_existing and os.path.exists(fn):
                    with open(fn) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[cached ] {tag}")
                        continue
                try:
                    r = run_cell(arch, sn, mp, args.out, args.variant)
                    if r["status"] == "skipped":
                        print(f"[skipped] {tag} — {r['reason']}")
                    else:
                        tms = r["roofline"]
                        print(
                            f"[ok     ] {tag} compile={r['compile_s']:.0f}s "
                            f"dom={tms['dominant']:<12s} "
                            f"c/m/coll(ms)={tms['compute_s']*1e3:.1f}/"
                            f"{tms['memory_s']*1e3:.1f}/"
                            f"{tms['collective_s']*1e3:.1f}"
                        )
                except Exception as e:
                    failures += 1
                    print(f"[FAIL   ] {tag}: {e}")
                    traceback.print_exc()
                    os.makedirs(args.out, exist_ok=True)
                    with open(fn, "w") as f:
                        json.dump(
                            {"arch": arch, "shape": sn,
                             "mesh": "2x16x16" if mp else "16x16",
                             "status": "fail", "error": str(e)}, f)
    print(f"done; failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
