"""Training launcher.

Single-host CPU execution runs reduced configs end-to-end (the tiny-LM
example trains to decreasing loss); on a TPU pod the same driver builds
the production mesh and jits with the FSDP x TP shardings used by the
dry-run.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-3b \
      --reduced --steps 100 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.optim.adamw import AdamWConfig
from repro.runtime.supervisor import (
    FailureInjector,
    StragglerDetector,
    Supervisor,
)
from repro.train.trainer import TrainConfig, init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    dtype = jnp.float32 if jax.default_backend() == "cpu" else jnp.bfloat16
    tcfg = TrainConfig(
        microbatches=args.microbatches,
        remat=True,
        dtype=dtype,
        compress_grads=args.compress_grads,
        optimizer=AdamWConfig(
            peak_lr=args.lr, warmup_steps=20, total_steps=args.steps
        ),
    )
    data = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=args.seed)
    step_jit = jax.jit(make_train_step(cfg, tcfg), donate_argnums=0)
    key = jax.random.PRNGKey(args.seed)

    def make_state():
        return init_train_state(cfg, tcfg, key)

    def step_fn(state, idx):
        return step_jit(state, data.batch_at(idx))

    if args.ckpt_dir:
        ckpt = CheckpointManager(args.ckpt_dir)
        sup = Supervisor(
            make_state,
            step_fn,
            ckpt,
            ckpt_every=args.ckpt_every,
            failure_injector=FailureInjector(tuple(args.fail_at)),
            straggler=StragglerDetector(),
        )
        sup.run(args.steps)
        hist = sup.history
    else:
        state = make_state()
        hist = []
        for i in range(args.steps):
            state, m = step_fn(state, i)
            hist.append({"step": i, "loss": float(m["loss"])})
            if i % args.log_every == 0:
                print(f"step {i:5d} loss {float(m['loss']):.4f} "
                      f"lr {float(m['lr']):.2e}")
    print(json.dumps({"first_loss": hist[0]["loss"],
                      "last_loss": hist[-1]["loss"],
                      "steps": len(hist)}))


if __name__ == "__main__":
    main()
