"""Fault-tolerance supervisor: restart-on-failure, stragglers, elastic.

On a real cluster this logic runs in the job controller; here it is the
same control flow driven by injectable failures so every path is
testable on one host:

  * failure -> restore last complete checkpoint -> replay (the data
    pipeline is stateless/seekable, so "replay" is just re-seeking the
    step index — no data loss, no double-visit);
  * straggler detection: per-step EWMA mean/variance; a step slower
    than mean + k*sigma raises a mitigation event (on a pod: preemptive
    re-shard or hot-spare swap; here: recorded + hook invoked);
  * elastic rescale: save -> rebuild on the new mesh -> restore with
    the new shardings (checkpoints are global arrays, so any topology
    can pick them up).
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, List, Optional

import jax


class SimulatedFailure(RuntimeError):
    """Raised by failure injectors to model a node loss."""


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule: fail when step hits a listed value."""

    fail_at_steps: tuple = ()
    fired: set = dataclasses.field(default_factory=set)

    def __call__(self, step: int) -> None:
        if step in self.fail_at_steps and step not in self.fired:
            self.fired.add(step)
            raise SimulatedFailure(f"injected node failure at step {step}")


@dataclasses.dataclass
class StragglerDetector:
    """EWMA z-score step-time monitor."""

    alpha: float = 0.2
    threshold_sigma: float = 3.0
    warmup: int = 5
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    events: List[dict] = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            # prime the stats
            self.mean = dt if self.n == 1 else (
                (1 - self.alpha) * self.mean + self.alpha * dt
            )
            self.var = (1 - self.alpha) * self.var + self.alpha * (
                (dt - self.mean) ** 2
            )
            return False
        sigma = math.sqrt(max(self.var, 1e-12))
        is_straggler = dt > self.mean + self.threshold_sigma * sigma
        if is_straggler:
            self.events.append({"step": step, "dt": dt, "mean": self.mean})
        # update stats only with non-straggler samples (keep the
        # baseline clean)
        if not is_straggler:
            self.mean = (1 - self.alpha) * self.mean + self.alpha * dt
            self.var = (1 - self.alpha) * self.var + self.alpha * (
                (dt - self.mean) ** 2
            )
        return is_straggler


class Supervisor:
    """Wraps a step function with checkpoint/restart + monitoring.

    `make_state` rebuilds the initial state; `step_fn(state, step_idx)`
    advances one step and returns (state, metrics).  Data is derived
    from step_idx (stateless pipeline), so restarts resume exactly.
    """

    def __init__(
        self,
        make_state: Callable[[], object],
        step_fn: Callable[[object, int], tuple],
        ckpt_manager,
        ckpt_every: int = 10,
        failure_injector: Optional[Callable[[int], None]] = None,
        straggler: Optional[StragglerDetector] = None,
        max_restarts: int = 10,
        on_straggler: Optional[Callable[[int], None]] = None,
    ) -> None:
        self.make_state = make_state
        self.step_fn = step_fn
        self.ckpt = ckpt_manager
        self.ckpt_every = ckpt_every
        self.inject = failure_injector or (lambda s: None)
        self.straggler = straggler or StragglerDetector()
        self.max_restarts = max_restarts
        self.on_straggler = on_straggler
        self.restarts = 0
        self.history: List[dict] = []

    def _restore_or_init(self):
        state = self.make_state()
        latest = self.ckpt.latest_step()
        if latest is None:
            return state, 0
        state = self.ckpt.restore(latest, like=state)
        return state, latest

    def run(self, total_steps: int):
        state, step = self._restore_or_init()
        while step < total_steps:
            try:
                self.inject(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, step)
                jax.block_until_ready(jax.tree.leaves(metrics)[0])
                dt = time.perf_counter() - t0
                if self.straggler.observe(step, dt) and self.on_straggler:
                    self.on_straggler(step)
                self.history.append(
                    {"step": step, **{k: float(v) for k, v in metrics.items()}}
                )
                step += 1
                if step % self.ckpt_every == 0:
                    self.ckpt.save(step, state)
            except SimulatedFailure:
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise
                state, step = self._restore_or_init()
        self.ckpt.wait()
        return state
