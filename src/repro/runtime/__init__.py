"""runtime substrate."""
