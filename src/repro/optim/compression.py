"""Gradient compression: int8 quantized reduction with error feedback.

For cross-pod data parallelism the gradient all-reduce crosses the slow
inter-pod links; 4x volume reduction (f32 -> int8 payload + per-block
f32 scales, 1/256 overhead) with error feedback keeps convergence: the
quantization residual is re-injected into the next step's gradient.

Usage modes:
  * `ef_roundtrip` — pure-function wire simulation used by the trainer
    (and by the convergence tests: tiny-LM training with and without
    compression must reach comparable loss).
  * `compressed_psum` — a shard_map-compatible all-reduce: agree on a
    shared per-block scale (pmax, negligible traffic), quantize, psum
    the int8-valued payload in int32 (TPU collectives do not sum int8
    natively; int32 carries 16+-way sums without overflow), dequantize.
    Exact up to quantization granularity — no cross-shard scale skew.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

BLOCK = 256  # per-block scaling granularity


def _blocks(x: Array) -> Tuple[Array, tuple]:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), x.shape


def _unblocks(blocks: Array, shape) -> Array:
    size = 1
    for s in shape:
        size *= s
    return blocks.reshape(-1)[:size].reshape(shape)


def compress(g: Array) -> Tuple[Array, Array]:
    """f32 tensor -> (int8 payload [Nb, BLOCK], f32 scales [Nb])."""
    blocks, _ = _blocks(g)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127)
    return q.astype(jnp.int8), scale


def decompress(q: Array, scale: Array, shape) -> Array:
    return _unblocks(q.astype(jnp.float32) * scale[:, None], shape)


def ef_roundtrip(grads, error_buf):
    """Error-feedback compression round-trip.

    Returns (grads as they survive the wire, new error buffer)."""

    def one(g, e):
        ge = g.astype(jnp.float32) + e
        q, s = compress(ge)
        rec = decompress(q, s, g.shape)
        return rec.astype(g.dtype), ge - rec

    out = jax.tree.map(lambda g, e: one(g, e), grads, error_buf)
    rec = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return rec, err


def init_error_buf(grads_like):
    # .copy(): distinct buffers (donation-safe, see adamw.init)
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32).copy(), grads_like
    )


def compressed_psum(g: Array, axis_name: str) -> Array:
    """int8-on-the-wire psum (shard_map building block)."""
    blocks, shape = _blocks(g)
    bmax = jnp.max(jnp.abs(blocks), axis=1)
    scale = jax.lax.pmax(bmax, axis_name) / 127.0  # shared scale
    safe = jnp.where(scale == 0, 1.0, scale)
    q = jnp.clip(jnp.round(blocks / safe[:, None]), -127, 127).astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return _unblocks(qsum.astype(jnp.float32) * scale[:, None], shape)
