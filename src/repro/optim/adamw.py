"""AdamW with global-norm clipping and warmup-cosine schedule.

Functional, pytree-shaped like the params (so optimizer state inherits
the FSDP/TP parameter shardings — ZeRO-1/2 falls out of the sharding
rules rather than being a separate mechanism).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


class AdamWState(NamedTuple):
    step: Array
    m: object  # pytree like params
    v: object


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0


def schedule(cfg: AdamWConfig, step: Array) -> Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.peak_lr * warm * frac


def init(params) -> AdamWState:
    # .copy() forces distinct device buffers: XLA dedupes equal zero
    # constants, and aliased buffers break donation (double-donate)
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p).copy(), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros(), v=zeros())


def global_norm(tree) -> Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return (
        new_params,
        AdamWState(step=step, m=new_m, v=new_v),
        {"grad_norm": gnorm, "lr": lr},
    )
