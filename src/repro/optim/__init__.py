"""optim substrate."""
