"""In-graph event ring: a fixed-capacity device log of allocator events.

Counters say *how much*; the ring says *when and where*.  It is a
circular int32 buffer living inside the jitted state (e.g. a field of
the engine's `EngineState`), written by masked scatters from inside
`lax.scan`/`lax.while_loop` bodies — so per-step allocator events
(lanes won, overflowed, spilled, frees merged, occupancy after the
step) are recorded with **zero host synchronization**, and drained
host-side at chunk boundaries into structured records.

Semantics:

  * fixed capacity `cap` (static; part of the compiled shape).  `cap ==
    0` disables the ring: every push is a no-op on a [0, W] buffer
    (`mode="drop"` scatter), so telemetry-off engines pay nothing;
  * **drop-oldest**: pushes land at `count % cap`, so when producers
    outrun drains the oldest events are overwritten; `dropped(ring)`
    reports how many were lost (count - cap, clamped), and the drain
    returns the surviving window oldest -> newest;
  * masked pushes: a batch of candidate events with a bool mask writes
    only the masked-in rows (positions computed by an exclusive cumsum
    over the mask, exactly one slot per accepted event) — the scatter
    analogue of "only record rounds where something happened".

Every event is one int32 row of `EVENT_FIELDS`; `decode(rows)` names
them for export (`obs/trace_export.py` turns a drained window into
Chrome-trace counter tracks and spans).
"""

from __future__ import annotations

from typing import Dict, List, NamedTuple, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

# One row per event.  `kind` discriminates; unused fields stay 0.
EVENT_FIELDS: Tuple[str, ...] = (
    "step",        # engine/global step index
    "kind",        # EV_* discriminator
    "lanes_won",   # allocations committed this event
    "lanes_overflowed",  # lanes whose allocation failed (pool full)
    "lanes_spilled",     # fast-octave lanes that took the buddy climb
    "frees_merged",      # handles released by the merged burst
    "rounds",      # arbitration rounds the wavefront took
    "free_pages",  # pool-wide free units after the event
)

EV_STEP = 1     # one engine decode step (alloc + decode + retire)
EV_ADMIT = 2    # host-boundary admission burst
EV_RETIRE = 3   # retirement burst detail

KIND_NAMES = {EV_STEP: "step", EV_ADMIT: "admit", EV_RETIRE: "retire"}


class EventRing(NamedTuple):
    """Device-resident ring state (a pytree; thread it through jit)."""

    buf: Array    # int32[cap, len(EVENT_FIELDS)]
    count: Array  # int32 scalar: events ever pushed


def make_ring(capacity: int) -> EventRing:
    return EventRing(
        buf=jnp.zeros((capacity, len(EVENT_FIELDS)), jnp.int32),
        count=jnp.int32(0),
    )


def capacity(ring: EventRing) -> int:
    return int(ring.buf.shape[0])


def event(kind: int, **fields) -> Array:
    """Build one int32 event row by field name (unset fields 0)."""
    unknown = set(fields) - set(EVENT_FIELDS)
    if unknown:
        raise KeyError(f"unknown event fields {sorted(unknown)}")
    vals = [
        jnp.asarray(fields.get(f, 0), jnp.int32) for f in EVENT_FIELDS
    ]
    vals[EVENT_FIELDS.index("kind")] = jnp.int32(kind)
    return jnp.stack(vals)


def push(ring: EventRing, row: Array, mask=True) -> EventRing:
    """Append one event row when `mask` (device bool) is set.

    The write position is `count % cap`; a masked-out push scatters to
    an out-of-range row with `mode="drop"`, so the compiled step has no
    data-dependent control flow."""
    cap = ring.buf.shape[0]
    mask = jnp.asarray(mask, bool)
    if cap == 0:  # telemetry off: keep only the total count
        return EventRing(ring.buf, ring.count + mask.astype(jnp.int32))
    pos = jnp.where(mask, ring.count % cap, cap)
    buf = ring.buf.at[pos].set(row, mode="drop")
    return EventRing(buf, ring.count + mask.astype(jnp.int32))


def push_many(ring: EventRing, rows: Array, mask: Array) -> EventRing:
    """Append the masked-in rows of a [N, W] candidate batch, in row
    order, each to its own slot (exclusive-cumsum positions)."""
    cap = ring.buf.shape[0]
    mask = jnp.asarray(mask, bool)
    n = mask.sum(dtype=jnp.int32)
    if cap == 0:
        return EventRing(ring.buf, ring.count + n)
    rank = jnp.cumsum(mask.astype(jnp.int32)) - 1  # 0-based among accepted
    pos = jnp.where(mask, (ring.count + rank) % cap, cap)
    buf = ring.buf.at[pos].set(rows, mode="drop")
    return EventRing(buf, ring.count + n)


def dropped(ring: EventRing) -> Array:
    """Events overwritten before any drain could see them."""
    cap = ring.buf.shape[0]
    return jnp.maximum(ring.count - cap, 0)


def drain(ring: EventRing) -> List[Dict[str, int]]:
    """Host-side: the surviving window as dicts, oldest -> newest.

    This is the one deliberate sync of the telemetry plane — call it at
    chunk boundaries, never inside the hot loop."""
    cap = ring.buf.shape[0]
    buf, count = jax.device_get((ring.buf, ring.count))
    count = int(count)
    n = min(count, cap)
    if n == 0:
        return []
    start = count % cap if count > cap else 0
    order = [(start + i) % cap for i in range(n)]
    return [decode_row(buf[i]) for i in order]


def decode_row(row) -> Dict[str, int]:
    rec = {f: int(v) for f, v in zip(EVENT_FIELDS, row)}
    rec["kind_name"] = KIND_NAMES.get(rec["kind"], f"kind{rec['kind']}")
    return rec
