"""Device-resident telemetry plane: named metrics, event ring, export.

The observability subsystem of the stack (docs/observability.md):

  * `obs.schema` — the metric registry (`MetricSpec`) and the
    positional slot orders every kernel stat row is packed/unpacked
    with.  Import this from host-only tools; it pulls in no jax.
  * `obs.metrics` — schema-checked metric dict pytrees with functional
    accumulation (counters sum, gauges latest-win, fixed-bucket
    histograms) safe inside jitted loops.
  * `obs.ring` — the in-graph event ring (masked scatter writes,
    drop-oldest, host-side drain).
  * `obs.trace_export` — Chrome-trace/Perfetto rendering of drained
    snapshots (jax-free; `tools/obsdump.py` is the CLI).
"""

from repro.obs.schema import (  # noqa: F401
    ENGINE_METRICS,
    POOL_STEP_SLOTS,
    REGISTRY,
    WAVEFRONT_ALLOC_SLOTS,
    WAVEFRONT_STEP_SLOTS,
    MetricSpec,
    pack_slots,
    spec,
    unpack_slots,
)
