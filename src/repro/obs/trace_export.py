"""Chrome-trace / Perfetto export of a drained telemetry snapshot.

A *snapshot* is the host-side, JSON-serializable dump of the telemetry
plane at a drain boundary (`JitServeEngine.snapshot()` produces one;
`tools/obsdump.py --self-test` synthesizes one):

  {
    "obs_schema": 1,
    "source": "jit_engine",
    "config": {...engine geometry...},
    "metrics": {name: int | [int, ...]},       # schema-checked names
    "events": [{step, kind, kind_name, ...}],  # drained ring window
    "spans": [{"phase": "admit"|"decode"|"drain",
               "t0": s, "t1": s, "step0": n, "step1": n, ...}],
  }

`chrome_trace` renders it as a Chrome JSON trace (the array-of-events
format Perfetto and chrome://tracing both load):

  * host-loop track: one "X" span per recorded host phase (admission
    bursts, fused decode chunks, drains) at real wall-clock times;
  * engine-steps track: one span per ring `step` event.  Device steps
    carry no wall clock (that is the whole point of the in-graph
    plane), so step times are interpolated inside their enclosing
    decode chunk's measured window, and each step span is split into
    schematic alloc -> decode -> retire sub-spans (ordering is real,
    sub-durations are schematic; counts in args are exact);
  * counter tracks ("C" events) for free pages and active-lane
    occupancy over step time — the Fig. 11 occupancy factor as a
    scrubbable timeline.

This module is deliberately jax-free: exporters run host-side on
already-drained data (tools/obsdump.py imports it standalone).
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional

from repro.obs.schema import spec

SNAPSHOT_VERSION = 1

_PID = 1
_TID_HOST = 1
_TID_STEPS = 2

# schematic fractions of a step span (ordering real, widths schematic)
_SUBSPANS = (("alloc", 0.15), ("decode", 0.70), ("retire", 0.15))


def validate_snapshot(snap: Dict) -> None:
    """Structural check + metric-name check against the registry."""
    for key in ("obs_schema", "source", "metrics", "events", "spans"):
        if key not in snap:
            raise ValueError(f"snapshot missing {key!r}")
    if snap["obs_schema"] != SNAPSHOT_VERSION:
        raise ValueError(
            f"snapshot version {snap['obs_schema']} != {SNAPSHOT_VERSION}"
        )
    for name in snap["metrics"]:
        spec(name)  # raises on unregistered names
    for ev in snap["events"]:
        if "step" not in ev or "kind" not in ev:
            raise ValueError(f"malformed ring event {ev}")
    for sp in snap["spans"]:
        if sp["t1"] < sp["t0"]:
            raise ValueError(f"span ends before it starts: {sp}")


def _meta(name: str, tid: int, what: str) -> Dict:
    return {
        "ph": "M", "name": what, "pid": _PID, "tid": tid,
        "args": {"name": name},
    }


def _span(name, tid, t0_us, dur_us, args=None) -> Dict:
    ev = {
        "ph": "X", "name": name, "pid": _PID, "tid": tid,
        "ts": float(t0_us), "dur": float(max(dur_us, 0.1)),
        "cat": "engine",
    }
    if args:
        ev["args"] = args
    return ev


def _counter(name, t_us, value) -> Dict:
    return {
        "ph": "C", "name": name, "pid": _PID, "ts": float(t_us),
        "args": {name: value}, "cat": "engine",
    }


def _step_clock(spans: List[Dict]):
    """Map a device step index to interpolated wall time (us) using the
    decode chunks' measured [step0, step1] x [t0, t1] windows."""
    windows = [
        s for s in spans
        if s.get("phase") == "decode" and s.get("step1", 0) > s.get("step0", 0)
    ]

    def at(step: float) -> Optional[float]:
        for w in windows:
            if w["step0"] <= step <= w["step1"]:
                f = (step - w["step0"]) / (w["step1"] - w["step0"])
                return 1e6 * (w["t0"] + f * (w["t1"] - w["t0"]))
        return None

    return at


def chrome_trace(snap: Dict) -> Dict:
    """Render a snapshot as a Chrome JSON trace object."""
    validate_snapshot(snap)
    events: List[Dict] = [
        _meta("nbbs-serve", _TID_HOST, "process_name"),
        _meta("host loop", _TID_HOST, "thread_name"),
        _meta("engine steps (device)", _TID_STEPS, "thread_name"),
    ]

    for sp in snap["spans"]:
        t0, t1 = 1e6 * sp["t0"], 1e6 * sp["t1"]
        args = {
            k: v for k, v in sp.items() if k not in ("phase", "t0", "t1")
        }
        events.append(_span(sp["phase"], _TID_HOST, t0, t1 - t0, args))

    clock = _step_clock(snap["spans"])
    step_events = [e for e in snap["events"] if e["kind_name"] == "step"]
    for ev in step_events:
        t0 = clock(ev["step"])
        t1 = clock(ev["step"] + 1)
        if t0 is None or t1 is None:
            continue
        args = {k: v for k, v in ev.items() if k != "kind_name"}
        events.append(
            _span(f"step {ev['step']}", _TID_STEPS, t0, t1 - t0, args)
        )
        # schematic sub-spans: real ordering, exact counts, split widths
        cursor = t0
        detail = {
            "alloc": {"lanes_won": ev.get("lanes_won", 0),
                      "lanes_spilled": ev.get("lanes_spilled", 0),
                      "rounds": ev.get("rounds", 0)},
            "decode": {},
            "retire": {"frees_merged": ev.get("frees_merged", 0),
                       "lanes_overflowed": ev.get("lanes_overflowed", 0)},
        }
        for name, frac in _SUBSPANS:
            dur = frac * (t1 - t0)
            events.append(
                _span(name, _TID_STEPS, cursor, dur, detail[name])
            )
            cursor += dur
        events.append(_counter("free_pages", t0, ev.get("free_pages", 0)))
        events.append(
            _counter("lanes_won", t0, ev.get("lanes_won", 0))
        )

    events.sort(key=lambda e: e.get("ts", 0.0))
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "source": snap["source"],
            "obs_schema": snap["obs_schema"],
            "config": snap.get("config", {}),
        },
    }


def validate_trace(trace: Dict) -> None:
    """Sanity-check an exported trace object (the --self-test gate)."""
    if "traceEvents" not in trace:
        raise ValueError("trace missing traceEvents")
    last_ts = None
    for ev in trace["traceEvents"]:
        if ev["ph"] not in ("X", "C", "M", "B", "E", "i"):
            raise ValueError(f"unknown phase {ev['ph']!r}")
        if ev["ph"] == "M":
            continue
        if ev["ts"] < 0:
            raise ValueError("negative timestamp")
        if last_ts is not None and ev["ts"] < last_ts:
            raise ValueError("trace events not time-sorted")
        last_ts = ev["ts"]
        if ev["ph"] == "X" and ev["dur"] <= 0:
            raise ValueError("non-positive span duration")


def save_trace(snap: Dict, path: str) -> str:
    """Snapshot -> Perfetto-loadable .trace (Chrome JSON) file."""
    trace = chrome_trace(snap)
    validate_trace(trace)
    with open(path, "w") as f:
        json.dump(trace, f, indent=1)
        f.write("\n")
    return path
