"""The named-metrics registry: one catalogue for every layer's counters.

The paper's whole evaluation is built on *observables* — Fig. 7 counts
RMW instructions per operation, Fig. 11 shows those counts are stable
under fragmentation — and every layer of this stack used to re-invent
its own way of reporting them: a hand-maintained 7-wide positional stat
row in the Pallas kernels, a parallel `EngineStepStats` NamedTuple in
the jitted engine, and per-benchmark JSON shapes that drifted PR to PR.
This module is the fix: a flat registry of `MetricSpec`s (name, kind,
unit, paper anchor) that every producer sources its slot names — and,
for the positional kernel rows, its slot *order* — from.

Three consumers, one schema:

  * `core/pool.py` / `kernels/ops.py` / `kernels/nbbs_alloc.py` build
    their stats dicts and pack/unpack the kernel stat rows via
    `POOL_STEP_SLOTS` / `WAVEFRONT_STEP_SLOTS` (tests/test_obs.py fails
    if either side drifts from the schema);
  * `serve/jit_engine.py`'s per-step metrics are `ENGINE_METRICS` —
    a schema-checked dict pytree (see `obs/metrics.py`) instead of a
    positional struct;
  * benchmark JSON artifacts (BENCH_*.json) carry a `metrics` mapping
    per record whose keys must all be registered here
    (`tools/check_bench_schema.py` enforces it in CI).

Kinds:
  counter   — monotone count; accumulates by summation.
  gauge     — point-in-time level (occupancy, free pages); accumulation
              keeps the *latest* value, not the sum.
  histogram — fixed-bucket counts (int32 vector); accumulates by
              element-wise summation.  Bucket edges are static
              (`MetricSpec.buckets`), so in-graph observation is a
              searchsorted + one-hot add with no host sync.
  derived   — host-side ratio/summary computed from other metrics
              (never accumulated on device).

This module is deliberately dependency-free (no jax import): the
registry must be loadable by host-only tools (`tools/obsdump.py`,
`tools/check_bench_schema.py`) and by docs tests without pulling in the
device stack.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

KINDS = ("counter", "gauge", "histogram", "derived")


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One named observable.

    `paper` anchors the metric to the source-paper observable it
    reproduces (e.g. Fig. 7's per-operation RMW count); empty for
    framework metrics with no paper analogue."""

    name: str
    kind: str
    unit: str = ""
    desc: str = ""
    paper: str = ""
    buckets: Optional[Tuple[int, ...]] = None  # histogram edges (static)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown metric kind {self.kind!r}")
        if (self.kind == "histogram") != (self.buckets is not None):
            raise ValueError(
                f"{self.name}: buckets iff kind == 'histogram'"
            )
        if self.buckets is not None and list(self.buckets) != sorted(
            set(self.buckets)
        ):
            raise ValueError(f"{self.name}: buckets must be sorted, unique")

    @property
    def n_slots(self) -> int:
        """Device slots this metric occupies (histograms: one count per
        bucket plus the overflow bucket)."""
        return 1 if self.buckets is None else len(self.buckets) + 1


def _counter(name, desc, unit="ops", paper=""):
    return MetricSpec(name, "counter", unit, desc, paper)


def _gauge(name, desc, unit="units", paper=""):
    return MetricSpec(name, "gauge", unit, desc, paper)


def _derived(name, desc, unit="", paper=""):
    return MetricSpec(name, "derived", unit, desc, paper)


_SPECS = [
    # -- allocator core (the paper's Fig. 7 ledger) ---------------------
    _counter("rounds", "pool/tree arbitration rounds run", "rounds"),
    _counter("alloc_rounds", "arbitration rounds on the alloc side",
             "rounds"),
    _counter(
        "merged_writes",
        "alloc-side tree words actually written by the merged climb",
        "words",
        paper="Fig. 7 (merged)",
    ),
    _counter(
        "logical_rmws",
        "alloc-side RMWs a per-thread sequential climb would issue "
        "(one CAS per level per winner)",
        "rmws",
        paper="Fig. 7 (logical)",
    ),
    _counter(
        "free_merged_writes",
        "release-side words written by the merged O(depth) sweep",
        "words",
        paper="Fig. 7 (merged, release)",
    ),
    _counter(
        "free_logical_rmws",
        "release-side RMWs of sequential FREENODE/UNMARK climbs",
        "rmws",
        paper="Fig. 7 (logical, release)",
    ),
    _counter("free_writes", "alias of free_merged_writes (legacy rows)",
             "words"),
    _counter("freed", "handles released (junk/double frees excluded)"),
    _counter(
        "overflows",
        "allocations served off their home shard (probe distance > 0)",
    ),
    _counter("probe_overflows",
             "engine allocs served off their home shard"),
    _counter(
        "fastpath_hits",
        "fast-octave allocations served by the O(1) slab claim "
        "(admission + decode combined at the engine level)",
        paper="Blelloch & Wei O(1) front end",
    ),
    _counter("fastpath_spills",
             "fast-octave allocations that fell through to the climb"),
    _counter("admit_fastpath_hits",
             "slab hits on the host-driven admission path only"),
    _counter("admit_fastpath_spills",
             "slab spills on the host-driven admission path only"),
    _counter(
        "magazine_hits",
        "allocations served by a per-lane magazine pop "
        "(zero shared-state RMWs)",
        paper="scalloc span cache / SpeedMalloc local pool",
    ),
    _counter(
        "magazine_spills",
        "pages returned to the shared pool instead of a magazine "
        "(stash drop-through on a full magazine, plus exhaustion "
        "spill-back bursts)",
    ),
    _counter(
        "magazine_refills",
        "pages pre-claimed from the shared pool into magazines by the "
        "batched refill burst (one wavefront per refill, not per page)",
    ),
    _counter("admit_magazine_spills",
             "magazine spill-backs on the host-driven admission path "
             "only (also folded into magazine_spills)"),
    # -- jitted engine per-step metrics --------------------------------
    _counter("alloc_pages", "KV pages claimed in-graph", "pages"),
    _counter("freed_pages", "KV pages released by retirement bursts",
             "pages"),
    _counter("overflow_lanes",
             "lanes retired because page allocation failed", "lanes"),
    _counter("retired", "lanes retired (any reason)", "lanes"),
    _gauge("active_lanes", "lanes still decoding after the step",
           "lanes"),
    _gauge("free_pages", "pool-wide free pages", "pages",
           paper="Fig. 11 (occupancy factor)"),
    _gauge("largest_run",
           "largest allocatable run across shards (fragmentation)",
           "pages"),
    _gauge("free_pages_shard", "per-shard free pages (vector gauge)",
           "pages"),
    MetricSpec(
        "alloc_rounds_hist",
        "histogram",
        "steps",
        "decode steps bucketed by pool rounds-to-completion of their "
        "page-boundary wavefront",
        paper="Fig. 7 (rounds distribution)",
        buckets=(0, 1, 2, 4, 8, 16, 32),
    ),
    MetricSpec(
        "probe_distance_hist",
        "histogram",
        "allocs",
        "engine page allocations bucketed by overflow probe distance "
        "(0 = served on the home shard)",
        buckets=(0, 1, 2, 4, 8),
    ),
    # -- event ring ----------------------------------------------------
    _counter("ring_events", "events pushed into the device ring",
             "events"),
    _counter("ring_dropped",
             "ring events overwritten before a drain (drop-oldest)",
             "events"),
    # -- serving / scheduler counters (host shim + oracle) -------------
    _counter("steps", "decode steps driven", "steps"),
    _counter("admitted", "requests admitted", "requests"),
    _counter("queued_full", "admissions deferred: pool full",
             "requests"),
    _counter("rejected", "requests rejected: exceed geometry",
             "requests"),
    _counter("overflow_retired",
             "sequences retired by in-step alloc overflow", "requests"),
    _counter("tokens_out", "tokens generated", "tokens"),
    _counter("decode_steps", "decode-step clock at completion", "steps"),
    # -- benchmark outcome counters ------------------------------------
    _counter("ok", "requests satisfied in a burst"),
    _counter("ok_final", "requests satisfied at churn end"),
    _counter("demand_units", "units requested by the burst", "units"),
    _counter("rounds_total", "arbitration rounds across the workload",
             "rounds"),
    _counter("churn_allocs", "churn-phase allocations"),
    _counter("unpacked_merged_writes",
             "merged climb words, Unpacked layout", "words",
             paper="§III-D"),
    _counter("unpacked_logical_rmws",
             "logical RMWs, Unpacked layout", "rmws", paper="§III-D"),
    _counter("packed_merged_writes",
             "merged climb words, BunchPacked layout", "words",
             paper="§III-D"),
    _counter("packed_logical_rmws",
             "logical RMWs, BunchPacked layout", "rmws",
             paper="§III-D"),
    _counter("free_merged_per_shard",
             "release merged words, per shard (vector)", "words"),
    _counter("free_logical_per_shard",
             "release logical RMWs, per shard (vector)", "rmws"),
    # -- timing / throughput (host-measured) ---------------------------
    _gauge("seconds", "wall time of the measured section", "s"),
    _gauge("seconds_per_burst", "wall time per burst", "s"),
    _gauge("wall_s", "end-to-end wall time", "s"),
    _gauge("toks_per_s", "tokens per second over the whole run",
           "tok/s"),
    _gauge("steady_toks_per_s",
           "decode throughput over the 10%%-90%% completion window",
           "tok/s"),
    _gauge("p50_latency_steps", "median request sojourn", "steps"),
    _gauge("p99_latency_steps", "p99 request sojourn", "steps"),
    _gauge("p50_latency_s", "median request sojourn", "s"),
    _gauge("p99_latency_s", "p99 request sojourn", "s"),
    # -- derived ratios (host-side summaries) --------------------------
    _derived("free_ratio", "free merged/logical ratio",
             paper="Fig. 7"),
    _derived("merged_per_op", "merged words per operation",
             paper="Fig. 7"),
    _derived("logical_per_alloc", "logical RMWs per allocation",
             paper="Fig. 7"),
    _derived("rmws_per_op",
             "shared-state logical RMWs per alloc/free operation "
             "(alloc + release climbs over total ops; magazine churn "
             "drives this toward zero)", paper="Fig. 7"),
    _derived("merged_writes_per_alloc",
             "merged words per claimed page", paper="Fig. 7"),
    _derived("merged_reduction",
             "unpacked/packed merged-write ratio", paper="§III-D"),
    _derived("state_ratio",
             "packed/unpacked persistent state words", paper="§III-D"),
    _derived("telemetry_overhead",
             "steady throughput telemetry-off / telemetry-on"),
    _derived("jit_host_speedup",
             "jit/host steady decode throughput"),
]

REGISTRY: Dict[str, MetricSpec] = {s.name: s for s in _SPECS}
if len(REGISTRY) != len(_SPECS):  # pragma: no cover - authoring guard
    raise AssertionError("duplicate metric name in the registry")


def spec(name: str) -> MetricSpec:
    """Look up one metric, raising on unregistered names."""
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unregistered metric {name!r} — add a MetricSpec to "
            "repro/obs/schema.py (the single catalogue every stat row, "
            "engine metric and BENCH_*.json key must come from)"
        ) from None


# ---------------------------------------------------------------------------
# Positional slot orders for the Pallas kernel stat rows.
#
# The kernels write fixed-width int32 stat rows; these tuples are the
# ONLY definition of their slot order.  `kernels/nbbs_alloc.py` packs
# rows with `pack_slots` and `kernels/ops.py` / `core/pool.py` unpack
# with `unpack_slots`, so the layout cannot drift between producer and
# consumer (tests/test_obs.py locks the width and the names).
# ---------------------------------------------------------------------------

# single-tree alloc-only kernel (`wavefront_alloc_pallas`)
WAVEFRONT_ALLOC_SLOTS: Tuple[str, ...] = (
    "rounds", "merged_writes", "logical_rmws",
)

# single-tree mixed free+alloc kernel (`wavefront_step_pallas`)
WAVEFRONT_STEP_SLOTS: Tuple[str, ...] = (
    "rounds", "merged_writes", "logical_rmws",
    "free_merged_writes", "free_logical_rmws", "freed",
)

# pooled grid-over-shards kernel (`pool_wavefront_step_pallas`),
# one row per shard.  The magazine slots are zero in kernel-emitted
# rows (magazines are per-lane state that lives *outside* the per-shard
# VMEM row; the `ops.nbbs_pool_wavefront_step` driver fills them in
# after its claim/stash phases) but they are part of the row so the
# producer and every consumer share one slot order.
POOL_STEP_SLOTS: Tuple[str, ...] = WAVEFRONT_STEP_SLOTS + (
    "fastpath_hits", "magazine_hits", "magazine_spills",
    "magazine_refills",
)

for _slots in (WAVEFRONT_ALLOC_SLOTS, WAVEFRONT_STEP_SLOTS,
               POOL_STEP_SLOTS):
    for _name in _slots:
        spec(_name)  # every slot must be a registered metric


def pack_slots(slots: Tuple[str, ...], values: Dict[str, object]):
    """Stack a stats dict into the positional row the kernel emits.

    jnp-free at module level (jax imported lazily) so host tools can
    import the schema without the device stack."""
    import jax.numpy as jnp

    return jnp.stack([values[name] for name in slots])


def unpack_slots(slots: Tuple[str, ...], row) -> Dict[str, object]:
    """Name the entries of a positional kernel stat row."""
    if int(row.shape[-1]) != len(slots):
        raise ValueError(
            f"stat row width {row.shape[-1]} != {len(slots)} schema "
            f"slots {slots}"
        )
    return {name: row[..., i] for i, name in enumerate(slots)}


# The engine's per-step metric set (obs/metrics.py builds the dict
# pytree from this): name -> static vector length, where None means a
# scalar and "S" means one slot per pool shard (resolved at engine
# build time).  Order is the canonical reporting order.
ENGINE_METRICS: Tuple[str, ...] = (
    "alloc_pages",
    "freed_pages",
    "overflow_lanes",
    "probe_overflows",
    "retired",
    "active_lanes",
    "alloc_rounds",
    "merged_writes",
    "logical_rmws",
    "free_merged_writes",
    "free_logical_rmws",
    "free_pages",
    "largest_run",
    "fastpath_hits",
    "fastpath_spills",
    "magazine_hits",
    "magazine_spills",
    "magazine_refills",
    "free_pages_shard",
    "alloc_rounds_hist",
    "probe_distance_hist",
    "ring_events",
    "ring_dropped",
)

for _name in ENGINE_METRICS:
    spec(_name)
