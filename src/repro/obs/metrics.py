"""Schema-checked metric pytrees: functional accumulation on device.

A `Metrics` value is a plain `dict[str, jax.Array]` — deliberately not
a custom class, so it is a first-class jit/pytree citizen (donatable,
scannable, `jax.tree.map`-able) — whose key set is validated against
the registry in `obs/schema.py`.  All mutation is functional: `inc`,
`observe`, `merge` return new dicts, so metrics accumulate inside
`lax.scan`/`lax.while_loop` carries with zero host syncs; reading them
(`to_host`) is always the *caller's* sync.

Accumulation semantics come from each metric's registered kind:

  counter / histogram — element-wise sum;
  gauge               — latest value wins (occupancy levels, not
                        counts: summing free_pages over steps would be
                        meaningless).

Histograms are fixed-bucket int32 vectors (`spec.buckets` edges are
static), so `observe` lowers to a searchsorted + one-hot add — the
in-graph histogram trick that keeps distribution observability (alloc
rounds-to-completion, probe distance) inside the compiled step.
"""

from __future__ import annotations

from typing import Dict, Iterable, Mapping, Optional

import jax
import jax.numpy as jnp

from repro.obs import schema as _schema
from repro.obs.schema import REGISTRY, MetricSpec, spec

Array = jax.Array
Metrics = Dict[str, Array]


def validate(names: Iterable[str]) -> None:
    """Every name must be registered (raises KeyError with guidance)."""
    for name in names:
        spec(name)


def zeros(
    names: Iterable[str],
    vector_lens: Optional[Mapping[str, int]] = None,
) -> Metrics:
    """Fresh all-zero metrics for the given schema names.

    Scalars are int32 device scalars; histograms get their bucket-count
    vector; `vector_lens` sizes vector gauges/counters (e.g.
    free_pages_shard -> n_shards)."""
    vector_lens = dict(vector_lens or {})
    out: Metrics = {}
    for name in names:
        s = spec(name)
        if s.kind == "histogram":
            out[name] = jnp.zeros((s.n_slots,), jnp.int32)
        elif name in vector_lens:
            out[name] = jnp.zeros((vector_lens[name],), jnp.int32)
        else:
            out[name] = jnp.int32(0)
    return out


def inc(metrics: Metrics, name: str, value) -> Metrics:
    """metrics[name] += value (counters) / = value (gauges)."""
    s = spec(name)
    out = dict(metrics)
    if s.kind == "gauge":
        out[name] = jnp.asarray(value, metrics[name].dtype)
    else:
        out[name] = metrics[name] + jnp.asarray(
            value, metrics[name].dtype
        )
    return out


def observe(metrics: Metrics, name: str, value, count=1) -> Metrics:
    """Add `count` observations of scalar `value` into a histogram.

    Bucket i counts observations with value <= buckets[i] (last slot is
    the overflow bucket) — a one-hot scatter over static edges, safe
    inside any jitted loop."""
    s = spec(name)
    if s.kind != "histogram":
        raise ValueError(f"{name} is a {s.kind}, not a histogram")
    edges = jnp.asarray(s.buckets, jnp.int32)
    idx = jnp.searchsorted(edges, jnp.asarray(value, jnp.int32))
    out = dict(metrics)
    out[name] = metrics[name].at[idx].add(jnp.int32(count))
    return out


def observe_many(metrics: Metrics, name: str, values, mask) -> Metrics:
    """Histogram a vector of observations (masked lanes dropped)."""
    s = spec(name)
    if s.kind != "histogram":
        raise ValueError(f"{name} is a {s.kind}, not a histogram")
    edges = jnp.asarray(s.buckets, jnp.int32)
    idx = jnp.searchsorted(edges, jnp.asarray(values, jnp.int32))
    idx = jnp.where(mask, idx, s.n_slots)  # OOB -> dropped
    out = dict(metrics)
    out[name] = metrics[name].at[idx].add(jnp.int32(1), mode="drop")
    return out


def merge(acc: Metrics, new: Metrics) -> Metrics:
    """Accumulate `new` into `acc` by registered kind (counters and
    histograms sum; gauges take `new`'s value).  Key sets must match —
    a drift here is exactly the positional-row bug this module
    exists to kill, so it raises instead of guessing."""
    if set(acc) != set(new):
        raise ValueError(
            f"metric key drift: {sorted(set(acc) ^ set(new))}"
        )
    out: Metrics = {}
    for name, a in acc.items():
        if spec(name).kind == "gauge":
            out[name] = new[name]
        else:
            out[name] = a + new[name]
    return out


def reduce_trajectory(traj: Metrics) -> Metrics:
    """Collapse metrics stacked on a leading [T] axis (a `lax.scan`
    trajectory) to totals: counters/histograms sum over T, gauges keep
    the final step's value."""
    out: Metrics = {}
    for name, v in traj.items():
        if spec(name).kind == "gauge":
            out[name] = v[-1]
        else:
            out[name] = v.sum(axis=0, dtype=v.dtype)
    return out


def to_host(metrics: Metrics) -> Dict[str, object]:
    """One device_get; scalars -> int, vectors/histograms -> list."""
    vals = jax.device_get(metrics)
    out: Dict[str, object] = {}
    for name, v in vals.items():
        if getattr(v, "ndim", 0) == 0:
            out[name] = int(v)
        else:
            out[name] = [int(x) for x in v]
    return out


def host_counters(values: Mapping[str, int]) -> Dict[str, object]:
    """Lift host-side int counters into a Metrics-shaped dict (so host
    and device counters route through the same `merge`)."""
    validate(values.keys())
    return {k: jnp.int32(v) for k, v in values.items()}


def hist_summary(name: str, counts) -> Dict[str, int]:
    """Label histogram counts with their '<=edge' / 'inf' buckets."""
    s = spec(name)
    labels = [f"<={e}" for e in (s.buckets or ())] + ["inf"]
    return {lab: int(c) for lab, c in zip(labels, counts)}


__all__ = [
    "Metrics", "MetricSpec", "REGISTRY", "spec", "validate", "zeros",
    "inc", "observe", "observe_many", "merge", "reduce_trajectory",
    "to_host", "host_counters", "hist_summary",
]

# re-export the slot helpers next to the metric ops
pack_slots = _schema.pack_slots
unpack_slots = _schema.unpack_slots
