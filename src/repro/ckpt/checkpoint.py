"""Sharded, async, atomic checkpointing with elastic restore.

Format (self-contained, no external deps):
  <dir>/step_<N>/
    manifest.json   — pytree structure, per-leaf file/shape/dtype/crc32,
                      step, wall time
    leaf_<i>.npy    — one array per leaf (np.save)

Write protocol: everything lands in `step_<N>.tmp/` first and the
directory is atomically renamed on completion — a crash mid-write can
never produce a manifest without its data, so `latest_step` only ever
sees complete checkpoints (the restart path of runtime.supervisor).

Async: `save()` snapshots to host (device_get) synchronously —
optimizer state at step N must not be mutated by step N+1 while
serializing — then hands file I/O to a background executor.

Elastic restore: leaves are saved as *global* arrays; `restore` places
them with any sharding pytree for the *new* mesh, so restarting on a
different topology (e.g. 256 -> 128 chips after losing a pod slice)
is the same code path as a same-mesh restart.  On a multi-host cluster
each host would save its addressable shards and restore with
`jax.make_array_from_single_device_arrays`; the manifest format already
carries everything needed (per-leaf shapes/dtypes).
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import json
import os
import shutil
import time
import zlib
from typing import Any, Optional

import jax
import numpy as np

Array = jax.Array


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    async_io: bool = True

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._pool = (
            concurrent.futures.ThreadPoolExecutor(max_workers=1)
            if self.async_io
            else None
        )
        self._pending: Optional[concurrent.futures.Future] = None

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any) -> None:
        """Snapshot now, write in background (if async)."""
        self.wait()  # one in flight at a time
        host_leaves = [np.asarray(jax.device_get(x)) for x in
                       _flatten(tree)[0]]
        treedef = _flatten(tree)[1]
        if self._pool is not None:
            self._pending = self._pool.submit(
                self._write, step, host_leaves, str(treedef)
            )
        else:
            self._write(step, host_leaves, str(treedef))

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, leaves, treedef_str: str) -> None:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {
            "step": step,
            "time": time.time(),
            "treedef": treedef_str,
            "leaves": [],
        }
        for i, leaf in enumerate(leaves):
            fname = f"leaf_{i:05d}.npy"
            path = os.path.join(tmp, fname)
            np.save(path, leaf)
            with open(path, "rb") as f:
                crc = zlib.crc32(f.read())
            manifest["leaves"].append(
                {
                    "file": fname,
                    "shape": list(leaf.shape),
                    "dtype": str(leaf.dtype),
                    "crc32": crc,
                }
            )
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.replace(tmp, final) if not os.path.exists(final) else shutil.rmtree(tmp)
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"))

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: int,
        like: Any,
        shardings: Any = None,
        verify: bool = True,
    ) -> Any:
        """Restore into the structure of `like`, optionally placing each
        leaf with `shardings` (a matching pytree of Sharding) — elastic
        restores pass the NEW mesh's shardings here."""
        self.wait()
        d = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        like_leaves, treedef = _flatten(like)
        assert len(like_leaves) == len(manifest["leaves"]), (
            len(like_leaves),
            len(manifest["leaves"]),
        )
        shard_leaves = (
            _flatten(shardings)[0] if shardings is not None else
            [None] * len(like_leaves)
        )
        out = []
        for i, (meta, lk, sh) in enumerate(
            zip(manifest["leaves"], like_leaves, shard_leaves)
        ):
            path = os.path.join(d, meta["file"])
            if verify:
                with open(path, "rb") as f:
                    if zlib.crc32(f.read()) != meta["crc32"]:
                        raise IOError(f"checksum mismatch in {path}")
            arr = np.load(path)
            assert list(arr.shape) == meta["shape"]
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)
