"""ckpt substrate."""
