#!/usr/bin/env python3
"""Telemetry snapshot dumper: metrics tables, event logs, Perfetto traces.

Input is a *snapshot* JSON file — the host-side dump of the device
telemetry plane produced by `JitServeEngine.snapshot()` (benchmarks
write one next to their BENCH_*.json; see docs/observability.md for
the capture workflow).  This tool renders it three ways:

  python tools/obsdump.py SNAP.json                  # metric table
  python tools/obsdump.py SNAP.json --events         # ring event log
  python tools/obsdump.py SNAP.json --trace out.json # Perfetto trace

The emitted trace is Chrome JSON — load it at https://ui.perfetto.dev
or chrome://tracing to scrub the admission -> alloc -> decode -> retire
timeline with free-page/occupancy counter tracks.

`--self-test` synthesizes a small snapshot, exports it, and validates
the result (structure, metric names, span/timestamp invariants) — the
CI docs job runs it so the exporter can never rot silently.

Deliberately imports only the jax-free obs modules (schema +
trace_export): it must run on a host with no accelerator stack.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.obs.schema import spec  # noqa: E402
from repro.obs.trace_export import (  # noqa: E402
    SNAPSHOT_VERSION,
    chrome_trace,
    save_trace,
    validate_snapshot,
    validate_trace,
)


def dump_metrics(snap) -> None:
    print(f"source: {snap['source']}   config: {snap.get('config', {})}")
    print(f"{'metric':<28} {'kind':<10} {'unit':<8} value")
    for name in sorted(snap["metrics"]):
        s = spec(name)
        val = snap["metrics"][name]
        if isinstance(val, list) and s.kind == "histogram":
            edges = list(s.buckets or ())
            labels = [f"<={e}" for e in edges] + ["inf"]
            val = " ".join(
                f"{lab}:{c}" for lab, c in zip(labels, val) if c
            ) or "(empty)"
        print(f"{name:<28} {s.kind:<10} {s.unit:<8} {val}")


def dump_events(snap) -> None:
    events = snap["events"]
    print(f"{len(events)} ring events "
          f"(dropped: {snap['metrics'].get('ring_dropped', 0)})")
    for ev in events:
        detail = " ".join(
            f"{k}={v}" for k, v in ev.items()
            if k not in ("step", "kind", "kind_name") and v
        )
        print(f"  step {ev['step']:>6}  {ev['kind_name']:<7} {detail}")


def self_test() -> int:
    """Synthesize a snapshot -> export -> validate (the CI gate)."""
    snap = {
        "obs_schema": SNAPSHOT_VERSION,
        "source": "obsdump --self-test",
        "config": {"n_shards": 2, "num_pages": 64},
        "metrics": {
            "steps": 8, "alloc_pages": 6, "freed_pages": 6,
            "free_pages": 64, "active_lanes": 0,
            "merged_writes": 40, "logical_rmws": 66,
            "fastpath_hits": 3, "fastpath_spills": 1,
            "magazine_hits": 4, "magazine_spills": 1,
            "magazine_refills": 2,
            "ring_events": 8, "ring_dropped": 0,
            "alloc_rounds_hist": [2, 4, 2, 0, 0, 0, 0, 0],
        },
        "events": [
            {"step": i, "kind": 1, "kind_name": "step",
             "lanes_won": i % 2, "lanes_overflowed": 0,
             "lanes_spilled": 0, "frees_merged": 1, "rounds": 1,
             "free_pages": 64 - i}
            for i in range(8)
        ],
        "spans": [
            {"phase": "admit", "t0": 0.0, "t1": 0.01,
             "step0": 0, "step1": 0, "admitted": 2},
            {"phase": "decode", "t0": 0.01, "t1": 0.09,
             "step0": 0, "step1": 8, "n": 8, "fused": 1},
            {"phase": "drain", "t0": 0.09, "t1": 0.10,
             "step0": 8, "step1": 8, "drained": 2},
        ],
    }
    validate_snapshot(snap)
    trace = chrome_trace(snap)
    validate_trace(trace)
    n_steps = sum(
        1 for e in trace["traceEvents"]
        if e["ph"] == "X" and e["name"].startswith("step ")
    )
    assert n_steps == 8, f"expected 8 step spans, got {n_steps}"
    counters = [e for e in trace["traceEvents"] if e["ph"] == "C"]
    assert counters, "expected counter tracks"
    # the extended kernel stat slots (fastpath + magazine counters)
    # must be registered and render through the metric table
    import contextlib
    import io

    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        dump_metrics(snap)
    table = buf.getvalue()
    for name in ("fastpath_hits", "magazine_hits", "magazine_spills",
                 "magazine_refills"):
        spec(name)  # registered in the schema
        assert name in table, f"metric table missing {name}"
    print(f"self-test ok: {len(trace['traceEvents'])} trace events, "
          f"{n_steps} step spans, {len(counters)} counter samples, "
          f"magazine counters rendered")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("snapshot", nargs="?", help="snapshot JSON file")
    ap.add_argument("--events", action="store_true",
                    help="print the drained ring event log")
    ap.add_argument("--trace", metavar="OUT",
                    help="write a Perfetto-loadable Chrome trace")
    ap.add_argument("--self-test", action="store_true",
                    help="synthesize+export+validate (CI gate)")
    args = ap.parse_args(argv)

    if args.self_test:
        return self_test()
    if not args.snapshot:
        ap.error("a snapshot file is required (or --self-test)")
    with open(args.snapshot) as f:
        snap = json.load(f)
    validate_snapshot(snap)
    if args.trace:
        path = save_trace(snap, args.trace)
        n = len(chrome_trace(snap)["traceEvents"])
        print(f"wrote {path} ({n} events) — load at ui.perfetto.dev")
        return 0
    if args.events:
        dump_events(snap)
        return 0
    dump_metrics(snap)
    return 0


if __name__ == "__main__":
    sys.exit(main())
