#!/usr/bin/env python3
"""Benchmark-artifact schema checker (CI: the bench-smoke job).

Every committed BENCH_*.json must carry the standardized envelope
emitted by `benchmarks/common.py::bench_envelope`:

  {
    "schema_version": 1,
    "benchmark": "<name>",
    "config": {...workload geometry...},
    "records": [
      {"dims":    {axis: value, ...},      # what varies across records
       "metrics": {name: number | [..]}},  # names from repro.obs.schema
    ],
    ...extra top-level keys allowed (free-form summaries)
  }

The point is the `metrics` mapping: its keys must all be registered in
the single metric catalogue (`src/repro/obs/schema.py`), so a benchmark
cannot invent an ad-hoc counter name that drifts from the kernels' and
the engine's.  Values must be numbers (or lists of numbers, for
histogram/vector metrics).

Usage:
  python tools/check_bench_schema.py [FILE.json ...]
With no arguments, checks every BENCH_*.json in the repo root.

Exit code 0 = all files validate; 1 = at least one violation (listed).
"""

from __future__ import annotations

import glob
import json
import os
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "src"))

from repro.obs.schema import spec  # noqa: E402  (path set up above)
from repro.obs.trace_export import validate_snapshot  # noqa: E402

SCHEMA_VERSION = 1


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def check_record(rec, where: str):
    errors = []
    if not isinstance(rec, dict):
        return [f"{where}: record is not an object"]
    for key in ("dims", "metrics"):
        if key not in rec or not isinstance(rec[key], dict):
            errors.append(f"{where}: missing/invalid {key!r} mapping")
    for name, val in rec.get("metrics", {}).items():
        try:
            spec(name)
        except KeyError as e:
            errors.append(f"{where}: {e.args[0]}")
            continue
        if not (
            _is_num(val)
            or (isinstance(val, list) and all(_is_num(x) for x in val))
        ):
            errors.append(
                f"{where}: metric {name!r} value must be a number or "
                f"list of numbers, got {type(val).__name__}"
            )
    for axis, val in rec.get("dims", {}).items():
        if not isinstance(val, (str, int, float, bool)):
            errors.append(
                f"{where}: dim {axis!r} must be a scalar, got "
                f"{type(val).__name__}"
            )
    return errors


def check_file(path: str):
    errors = []
    try:
        with open(path) as f:
            data = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable JSON ({e})"]
    if not isinstance(data, dict):
        return [
            f"{path}: top level must be the envelope object, not "
            f"{type(data).__name__} (regenerate with bench_envelope)"
        ]
    if "obs_schema" in data:
        # engine telemetry snapshot (JitServeEngine.snapshot), not a
        # bench envelope — validate it as the trace exporter's input
        try:
            validate_snapshot(data)
        except (KeyError, ValueError, TypeError) as e:
            errors.append(f"{path}: invalid snapshot ({e})")
        return errors
    if data.get("schema_version") != SCHEMA_VERSION:
        errors.append(
            f"{path}: schema_version {data.get('schema_version')!r} "
            f"!= {SCHEMA_VERSION}"
        )
    if not isinstance(data.get("benchmark"), str) or not data.get(
        "benchmark"
    ):
        errors.append(f"{path}: missing 'benchmark' name")
    if not isinstance(data.get("config"), dict):
        errors.append(f"{path}: missing 'config' object")
    records = data.get("records")
    if not isinstance(records, list) or not records:
        errors.append(f"{path}: 'records' must be a non-empty list")
        records = []
    for i, rec in enumerate(records):
        errors.extend(check_record(rec, f"{path}[records/{i}]"))
    return errors


def main(argv) -> int:
    paths = argv or sorted(glob.glob(os.path.join(REPO, "BENCH_*.json")))
    if not paths:
        print("no BENCH_*.json artifacts found")
        return 1
    failed = 0
    for path in paths:
        errors = check_file(path)
        rel = os.path.relpath(path, REPO)
        if errors:
            failed += 1
            print(f"FAIL {rel}")
            for e in errors:
                print(f"  - {e}")
        else:
            with open(path) as f:
                data = json.load(f)
            if "obs_schema" in data:
                print(f"ok   {rel} (snapshot, "
                      f"{len(data.get('events', []))} events)")
            else:
                print(f"ok   {rel} ({len(data['records'])} records)")
    if failed:
        print(f"\n{failed} artifact(s) violate the bench schema")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
