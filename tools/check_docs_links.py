#!/usr/bin/env python3
"""Docs link checker (CI: the docs job).

Scans README.md and docs/*.md for markdown links and verifies that
every relative link resolves to an existing file, and that every
intra-file anchor (#heading) matches a heading slug in the target.
External (http/https/mailto) links are not fetched — only shape-checked.

Also scans the source trees (src/, tests/, benchmarks/, tools/,
examples/) for doc-file *citations* — `docs/design.md §3`,
`docs/architecture.md`, bare `DESIGN.md` — and fails when the cited
file does not exist (dangling citations rot silently: this repo once
carried a dozen references to a DESIGN.md that was never written).
When a citation carries a §N section marker, the target doc must
contain that `§N` literally.

Exit code 0 = all links resolve; 1 = at least one broken link (listed).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
# doc citations in source: "docs/design.md §3", "README.md", "FOO.md §2"
CITATION_RE = re.compile(r"([A-Za-z0-9_][A-Za-z0-9_./-]*\.md)(?:\s*(§\d+))?")
SOURCE_DIRS = ("src", "tests", "benchmarks", "tools", "examples")


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, strip punctuation, dashes."""
    text = re.sub(r"[`*_]", "", heading.strip().lower())
    text = re.sub(r"[^\w\s-]", "", text, flags=re.UNICODE)
    return re.sub(r"\s+", "-", text)


def anchors_of(path: Path) -> set:
    return {slugify(h) for h in HEADING_RE.findall(path.read_text())}


def check_file(md: Path, root: Path) -> list:
    errors = []
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        dest = (
            md if not path_part
            else (md.parent / path_part).resolve()
        )
        if not dest.exists():
            errors.append(f"{md.relative_to(root)}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md":
            if anchor not in anchors_of(dest):
                errors.append(
                    f"{md.relative_to(root)}: missing anchor -> {target}"
                )
    return errors


def check_source_citations(root: Path) -> list:
    """Every `<file>.md [§N]` citation in a source file must name a doc
    that exists (resolved against the repo root), and its §N section —
    when cited — must appear in that doc."""
    errors = []
    section_cache: dict = {}
    for d in SOURCE_DIRS:
        base = root / d
        if not base.is_dir():
            continue
        for py in sorted(base.rglob("*.py")):
            if py.resolve() == Path(__file__).resolve():
                continue  # this file's docstring shows example citations
            text = py.read_text()
            for m in CITATION_RE.finditer(text):
                target, section = m.group(1), m.group(2)
                dest = root / target
                rel = py.relative_to(root)
                line = text.count("\n", 0, m.start()) + 1
                if not dest.exists():
                    errors.append(
                        f"{rel}:{line}: citation of nonexistent doc "
                        f"-> {target}"
                    )
                    continue
                if section:
                    if dest not in section_cache:
                        # exact section tokens, so §1 never matches §10
                        section_cache[dest] = set(
                            re.findall(r"§\d+", dest.read_text())
                        )
                    if section not in section_cache[dest]:
                        errors.append(
                            f"{rel}:{line}: {target} has no section "
                            f"{section}"
                        )
    return errors


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    docs = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    docs = [d for d in docs if d.exists()]
    if not docs:
        print("no docs found", file=sys.stderr)
        return 1
    errors = []
    for md in docs:
        errors.extend(check_file(md, root))
    errors.extend(check_source_citations(root))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(docs)} files + source citations: "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
