#!/usr/bin/env python3
"""Docs link checker (CI: the docs job).

Scans README.md and docs/*.md for markdown links and verifies that
every relative link resolves to an existing file, and that every
intra-file anchor (#heading) matches a heading slug in the target.
External (http/https/mailto) links are not fetched — only shape-checked.

Exit code 0 = all links resolve; 1 = at least one broken link (listed).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)


def slugify(heading: str) -> str:
    """GitHub-style anchor slug: lowercase, strip punctuation, dashes."""
    text = re.sub(r"[`*_]", "", heading.strip().lower())
    text = re.sub(r"[^\w\s-]", "", text, flags=re.UNICODE)
    return re.sub(r"\s+", "-", text)


def anchors_of(path: Path) -> set:
    return {slugify(h) for h in HEADING_RE.findall(path.read_text())}


def check_file(md: Path, root: Path) -> list:
    errors = []
    for target in LINK_RE.findall(md.read_text()):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        dest = (
            md if not path_part
            else (md.parent / path_part).resolve()
        )
        if not dest.exists():
            errors.append(f"{md.relative_to(root)}: broken link -> {target}")
            continue
        if anchor and dest.suffix == ".md":
            if anchor not in anchors_of(dest):
                errors.append(
                    f"{md.relative_to(root)}: missing anchor -> {target}"
                )
    return errors


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    docs = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    docs = [d for d in docs if d.exists()]
    if not docs:
        print("no docs found", file=sys.stderr)
        return 1
    errors = []
    for md in docs:
        errors.extend(check_file(md, root))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"checked {len(docs)} files: "
          f"{'FAIL' if errors else 'ok'} ({len(errors)} broken)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
